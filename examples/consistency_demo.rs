//! Fig 5 walkthrough (paper §4.5.2): offline keeps every record version,
//! online keeps only `max(tuple(event_ts, creation_ts))` per entity —
//! including the late-arriving R3 case.
//!
//! ```bash
//! cargo run --release --example consistency_demo
//! ```

use std::sync::Arc;

use geofs::materialize::merge::{DualStoreMerger, FaultInjector};
use geofs::metadata::assets::MaterializationPolicy;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::types::{FeatureRecord, FeatureWindow};
use geofs::util::Clock;

fn show(offline: &OfflineStore, online: &OnlineStore, label: &str) {
    let rows = offline.scan("fset:1", FeatureWindow::new(0, 1_000));
    let mut versions: Vec<_> = rows.iter().map(|r| r.version()).collect();
    versions.sort();
    println!("{label}:");
    println!("  offline ({} records): {versions:?}", rows.len());
    match online.get("fset:1", 1, 1_000) {
        Some(r) => println!("  online  (1 record):   {:?} value={}", r.version(), r.values[0]),
        None => println!("  online  : empty"),
    }
}

fn main() {
    // The paper's example: t0 < t1 < t2 on the event timeline, and
    // creation order t0' < t1' < t2' < t3' with R3 a late recompute of
    // event t1.
    let (t0, t1, t2) = (100, 200, 300);
    let (c0, c1, c2, c3) = (110, 210, 310, 400);
    let r0 = FeatureRecord::new(1, t0, c0, vec![0.0]);
    let r1 = FeatureRecord::new(1, t1, c1, vec![1.0]);
    let r2 = FeatureRecord::new(1, t2, c2, vec![2.0]);
    let r3 = FeatureRecord::new(1, t1, c3, vec![3.0]); // late-arriving data for t1

    let offline = Arc::new(OfflineStore::new());
    let online = Arc::new(OnlineStore::new(2));
    let merger = DualStoreMerger::new(
        offline.clone(),
        online.clone(),
        FaultInjector::none(),
        Default::default(),
        Clock::fixed(0),
    );
    let policy = MaterializationPolicy::default();

    // T1: R0, R1, R2 materialized.
    for r in [&r0, &r1, &r2] {
        merger.merge("fset:1", std::slice::from_ref(r), &policy, r.creation_ts).unwrap();
    }
    show(&offline, &online, "at T1 (after R0, R1, R2)");
    assert_eq!(offline.scan("fset:1", FeatureWindow::new(0, 1_000)).len(), 3);
    assert_eq!(online.get("fset:1", 1, 1_000).unwrap().version(), (t2, c2));

    // T2: R3 (event t1, created t3') merges. Offline gains a 4th record;
    // online is *unchanged* — R2 still has the max event_ts.
    merger.merge("fset:1", std::slice::from_ref(&r3), &policy, c3).unwrap();
    show(&offline, &online, "at T2 (after late-arriving R3)");
    assert_eq!(offline.scan("fset:1", FeatureWindow::new(0, 1_000)).len(), 4);
    assert_eq!(online.get("fset:1", 1, 1_000).unwrap().version(), (t2, c2));

    println!("\nFig 5 semantics verified: offline keeps all 4 records; online kept R2.");
}
