//! End-to-end driver (DESIGN.md experiment E2E): the paper's customer
//! churn scenario on a real small workload, proving all layers compose —
//! Pallas-kernel-compiled HLO artifacts (L1/L2) executed from the Rust
//! coordinator (L3) under scheduled materialization, with PIT-correct
//! training retrieval, online serving from four regions, and a logistic-
//! regression churn model trained on the produced frame.
//!
//! ```bash
//! make artifacts && cargo run --release --example churn_pipeline
//! ```
//!
//! Results of a reference run are recorded in EXPERIMENTS.md.

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::query::pit::PitConfig;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::{fmt_secs, DAY};
use geofs::util::hist::Histogram;
use geofs::util::init_logging;

fn main() -> anyhow::Result<()> {
    init_logging();
    let t_start = std::time::Instant::now();

    // ---- 1. Open a 4-region managed deployment -------------------------
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { geo_replication: true, ..Default::default() },
    )?;
    let days = 21i64;
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 128, days, seed: 42, ..Default::default() },
    )?;
    println!("== churn pipeline: {} customers, {days} days, 4 regions ==", w.cfg.customers);

    // ---- 2. Scheduled materialization, day by day ----------------------
    let t0 = std::time::Instant::now();
    let mut total_jobs = 0;
    let mut total_records = 0u64;
    for day in 1..=days {
        fs.clock.set(day * DAY);
        for table in [&w.txn_table, &w.interactions_table] {
            let outcomes = fs.materialize_tick(table)?;
            total_jobs += outcomes.len();
            total_records += outcomes.iter().map(|o| o.records).sum::<u64>();
        }
    }
    let mat_dt = t0.elapsed();
    println!(
        "materialization: {total_jobs} jobs, {total_records} records in {mat_dt:.2?} \
         ({:.0} records/s)",
        total_records as f64 / mat_dt.as_secs_f64()
    );
    for table in [&w.txn_table, &w.interactions_table] {
        let f = fs.table_freshness(table).unwrap();
        println!(
            "  {table}: offline_rows={} staleness={} within_sla={}",
            fs.offline.row_count(table),
            fmt_secs(f.staleness_secs),
            f.within_sla
        );
    }

    // ---- 3. PIT-correct training frame ----------------------------------
    let spine = w.observation_spine(2_000);
    let observations: Vec<(String, i64)> =
        spine.iter().map(|(k, ts, _)| (k.clone(), *ts)).collect();
    let labels: Vec<bool> = spine.iter().map(|(_, _, l)| *l).collect();
    let t0 = std::time::Instant::now();
    let frame = fs.get_training_frame(
        &w.principal,
        Some(geofs::lineage::ModelId { name: "churn".into(), version: 1 }),
        &observations,
        &w.model_features(),
        PitConfig::default(),
        fs.config.home_region(),
    )?;
    let pit_dt = t0.elapsed();
    println!(
        "training frame: {} rows × {} cols in {pit_dt:.2?} ({:.0} rows/s), fill_rate={:.3}",
        frame.len(),
        frame.columns.len(),
        frame.len() as f64 / pit_dt.as_secs_f64(),
        frame.fill_rate()
    );

    // ---- 4. Train a tiny logistic-regression churn model ----------------
    let (weights, train_acc) = train_logreg(&frame, &labels);
    println!("churn model: train_acc={train_acc:.3} weights={weights:?}");

    // ---- 5. Online serving from all four regions ------------------------
    fs.pump_replication(); // deliver replicated data (clock already late)
    fs.clock.advance(600); // let replication lag elapse
    fs.pump_replication();
    let trace = w.serving_trace(4_000, &fs.config.regions.clone());
    let mut hist_by_mech: std::collections::BTreeMap<&'static str, Histogram> =
        Default::default();
    let mut hits = 0u64;
    let t0 = std::time::Instant::now();
    for (key, region) in &trace {
        let out = fs.get_online(&w.principal, &w.txn_table, key, region)?;
        if out.record.is_some() {
            hits += 1;
        }
        let mech = match out.mechanism {
            geofs::geo::access::AccessMechanism::Local => "local",
            geofs::geo::access::AccessMechanism::CrossRegion => "xregion",
            geofs::geo::access::AccessMechanism::Replica => "replica",
        };
        hist_by_mech.entry(mech).or_default().record(out.latency_us);
    }
    let serve_dt = t0.elapsed();
    println!(
        "serving: {} lookups in {serve_dt:.2?} ({:.0}/s), hit_rate={:.3}",
        trace.len(),
        trace.len() as f64 / serve_dt.as_secs_f64(),
        hits as f64 / trace.len() as f64
    );
    for (mech, h) in &hist_by_mech {
        println!("  {mech:<8} {}", h.summary(1.0, "µs"));
    }

    // ---- 6. Lineage + governance surface --------------------------------
    println!(
        "lineage: churn model uses {} features; global view: {:?}",
        fs.lineage
            .features_of(&geofs::lineage::ModelId { name: "churn".into(), version: 1 })
            .len(),
        fs.lineage.global_view()
    );
    println!("audit log entries: {}", fs.rbac.audit_log().len());
    println!("total wall time: {:.2?}", t_start.elapsed());
    Ok(())
}

/// Minimal logistic regression (GD, standardized features) — enough to
/// prove the training frame is learnable, not a benchmark.
fn train_logreg(
    frame: &geofs::query::offline::TrainingFrame,
    labels: &[bool],
) -> (Vec<f32>, f64) {
    let n_feat = frame.columns.len();
    let rows: Vec<(Vec<f32>, f32)> = frame
        .rows()
        .zip(labels)
        .map(|(r, &l)| {
            let x: Vec<f32> = r.features.iter().map(|f| f.unwrap_or(0.0)).collect();
            (x, if l { 1.0 } else { 0.0 })
        })
        .collect();
    // Standardize.
    let mut mean = vec![0.0f32; n_feat];
    let mut var = vec![0.0f32; n_feat];
    for (x, _) in &rows {
        for (j, v) in x.iter().enumerate() {
            mean[j] += v;
        }
    }
    mean.iter_mut().for_each(|m| *m /= rows.len() as f32);
    for (x, _) in &rows {
        for (j, v) in x.iter().enumerate() {
            var[j] += (v - mean[j]).powi(2);
        }
    }
    var.iter_mut().for_each(|v| *v = (*v / rows.len() as f32).max(1e-6));
    let std: Vec<f32> = var.iter().map(|v| v.sqrt()).collect();

    let mut wgt = vec![0.0f32; n_feat + 1];
    for _epoch in 0..200 {
        let mut grad = vec![0.0f32; n_feat + 1];
        for (x, y) in &rows {
            let mut z = wgt[n_feat];
            for j in 0..n_feat {
                z += wgt[j] * (x[j] - mean[j]) / std[j];
            }
            let p = 1.0 / (1.0 + (-z).exp());
            let err = p - y;
            for j in 0..n_feat {
                grad[j] += err * (x[j] - mean[j]) / std[j];
            }
            grad[n_feat] += err;
        }
        for j in 0..=n_feat {
            wgt[j] -= 0.1 * grad[j] / rows.len() as f32;
        }
    }
    let correct = rows
        .iter()
        .filter(|(x, y)| {
            let mut z = wgt[n_feat];
            for j in 0..n_feat {
                z += wgt[j] * (x[j] - mean[j]) / std[j];
            }
            (z > 0.0) == (*y > 0.5)
        })
        .count();
    (wgt, correct as f64 / rows.len() as f64)
}
