//! Region failover walkthrough (paper §3.1.2): a region dies
//! mid-deployment; a standby restores the checkpoint and resumes
//! scheduled materialization from the exact high-water mark — no data
//! loss, no double work.
//!
//! ```bash
//! make artifacts && cargo run --release --example geo_failover
//! ```

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::geo::failover::FailoverManager;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::FeatureWindow;
use geofs::util::init_logging;

fn main() -> anyhow::Result<()> {
    init_logging();
    let data_dir = std::env::temp_dir().join(format!("geofs-failover-{}", std::process::id()));

    // ---- primary region operates for a week ---------------------------
    let fs = FeatureStore::open(Config::default_geo(), OpenOptions::default())?;
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 48, days: 7, seed: 9, ..Default::default() },
    )?;
    for day in 1..=7 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table)?;
    }
    let rows_before = fs.offline.row_count(&w.txn_table);
    println!("primary (eastus): {} offline rows across 7 days", rows_before);

    // Periodic checkpoint (the HA loop would do this continuously).
    let checkpoint = fs.checkpoint(data_dir.clone())?;
    println!(
        "checkpoint taken at t={} covering {:?}",
        checkpoint.taken_at,
        fs.scheduler.coverage(&w.txn_table)
    );

    // ---- region goes down ----------------------------------------------
    fs.topology.set_down("eastus", true);
    println!("\n!! eastus is down");

    // ---- standby takes over ---------------------------------------------
    let standby = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { with_engine: true, ..Default::default() },
    )?;
    let w2 = ChurnWorkload::install(
        &standby,
        ChurnWorkloadConfig { customers: 48, days: 7, seed: 9, ..Default::default() },
    )?;
    standby.topology.set_down("eastus", true);
    let fm = FailoverManager::new(standby.topology.clone());
    let promoted = fm.failover(&checkpoint, &standby.scheduler, 8, 8 * DAY)?;
    let (offline, online) = (&promoted.offline, &promoted.online);
    println!(
        "failover → {}: restored {} offline rows, {} online entities",
        promoted.region,
        offline.row_count(&w2.txn_table),
        online.len()
    );
    assert_eq!(offline.row_count(&w2.txn_table), rows_before, "no data loss");

    // Import restored durable state into the standby deployment.
    let restored = offline.scan(&w2.txn_table, FeatureWindow::new(0, 8 * DAY));
    standby.offline.merge(&w2.txn_table, &restored);
    standby.bootstrap_online_from_offline(&w2.txn_table);

    // ---- standby resumes the schedule where the primary stopped ---------
    standby.clock.set(9 * DAY);
    let outcomes = standby.materialize_tick(&w2.txn_table)?;
    println!(
        "standby resumed: {} new job(s) covering {:?} (no re-materialization of days 0–7)",
        outcomes.len(),
        outcomes.iter().map(|o| o.window).collect::<Vec<_>>()
    );
    assert!(outcomes.iter().all(|o| o.window.start >= 7 * DAY), "must resume, not redo");

    let _ = std::fs::remove_dir_all(&data_dir);
    println!("\nfailover complete: resumed from checkpoint without loss or re-work.");
    Ok(())
}
