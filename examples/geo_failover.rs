//! Region failover walkthrough (paper §3.1.2): a region dies
//! mid-deployment; a standby restores the checkpoint, replays the
//! replication fabric's record log (acked writes newer than the
//! checkpoint are not lost), and resumes scheduled materialization from
//! the exact high-water mark — no data loss, no double work.
//!
//! ```bash
//! make artifacts && cargo run --release --example geo_failover
//! ```

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::geo::failover::FailoverManager;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::FeatureWindow;
use geofs::util::{init_logging, Clock};

fn main() -> anyhow::Result<()> {
    init_logging();
    let data_dir = std::env::temp_dir().join(format!("geofs-failover-{}", std::process::id()));

    // ---- primary region operates for a week ---------------------------
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { geo_replication: true, ..Default::default() },
    )?;
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 48, days: 8, seed: 9, ..Default::default() },
    )?;
    for day in 1..=7 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table)?;
    }
    println!(
        "primary (eastus): {} offline rows across 7 days",
        fs.offline.row_count(&w.txn_table)
    );

    // Periodic checkpoint (the HA loop would do this continuously).
    let checkpoint = fs.checkpoint(data_dir.clone())?;
    println!(
        "checkpoint taken at t={} covering {:?}",
        checkpoint.taken_at,
        fs.scheduler.coverage(&w.txn_table)
    );

    // One more day of writes lands AFTER the checkpoint: merged at home
    // and appended to the replication fabric, but not yet replicated
    // (the 30 s lag has not elapsed) and not in any checkpoint.
    fs.clock.set(8 * DAY);
    fs.materialize_tick(&w.txn_table)?;
    let rows_acked = fs.offline.row_count(&w.txn_table);
    println!("day 8 acked post-checkpoint: {} offline rows total", rows_acked);

    // ---- region goes down ----------------------------------------------
    fs.topology.set_down("eastus", true);
    println!("\n!! eastus is down (day-8 writes never replicated)");

    // ---- standby takes over ---------------------------------------------
    let standby = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { with_engine: true, ..Default::default() },
    )?;
    let w2 = ChurnWorkload::install(
        &standby,
        ChurnWorkloadConfig { customers: 48, days: 8, seed: 9, ..Default::default() },
    )?;
    standby.topology.set_down("eastus", true);
    let fm = FailoverManager::new(standby.topology.clone());
    // Promote with the fabric: the standby's replica store is promoted
    // in place and the retained log is replayed into both restored
    // stores, so the day-8 acked writes survive the outage.
    let promoted = fm.failover_with(
        &checkpoint,
        &standby.scheduler,
        8,
        9 * DAY,
        fs.fabric.as_ref(),
        Clock::fixed(9 * DAY),
        Some(standby.metrics.clone()),
    )?;
    let (offline, online) = (&promoted.offline, &promoted.online);
    println!(
        "failover → {}: restored {} offline rows, {} online entities, replicating to {:?}",
        promoted.region,
        offline.row_count(&w2.txn_table),
        online.len(),
        promoted.fabric.as_ref().map(|f| f.regions()).unwrap_or_default()
    );
    assert_eq!(
        offline.row_count(&w2.txn_table),
        rows_acked,
        "fabric replay must recover acked writes newer than the checkpoint"
    );

    // Import restored durable state into the standby deployment.
    let restored = offline.scan(&w2.txn_table, FeatureWindow::new(0, 9 * DAY));
    standby.offline.merge(&w2.txn_table, &restored);
    standby.bootstrap_online_from_offline(&w2.txn_table);

    // ---- standby resumes the schedule where the primary stopped ---------
    standby.clock.set(9 * DAY);
    let outcomes = standby.materialize_tick(&w2.txn_table)?;
    println!(
        "standby resumed: {} new job(s) covering {:?} (no re-materialization of days 0–7)",
        outcomes.len(),
        outcomes.iter().map(|o| o.window).collect::<Vec<_>>()
    );
    assert!(outcomes.iter().all(|o| o.window.start >= 7 * DAY), "must resume, not redo");

    let _ = std::fs::remove_dir_all(&data_dir);
    println!("\nfailover complete: resumed from checkpoint + fabric replay without loss or re-work.");
    Ok(())
}
