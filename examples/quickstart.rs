//! Quickstart: define → materialize → retrieve, in ~60 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::governance::rbac::{Grant, Principal, Role};
use geofs::metadata::assets::{EntitySpec, FeatureSetSpec, SourceSpec};
use geofs::query::pit::PitConfig;
use geofs::query::spec::FeatureRef;
use geofs::source::synthetic::SyntheticSource;
use geofs::types::time::{Granularity, DAY};
use geofs::util::init_logging;

fn main() -> anyhow::Result<()> {
    init_logging();

    // 1. Open a local ("one box", §2.1) deployment and create the store.
    let fs = FeatureStore::open(Config::default_local(), OpenOptions::default())?;
    fs.create_store("quickstart-fs")?;

    // 2. Define assets: an entity and a 30-day rolling feature set.
    fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"]))?;
    let spec = FeatureSetSpec::rolling(
        "txn_30d",
        1,
        "customer",
        SourceSpec::synthetic(7),
        Granularity::daily(),
        30,
    );
    let source = Arc::new(SyntheticSource::new(7, 16).with_rate(0.4));
    let table = fs.register_feature_set(spec, source, 0)?;

    // 3. Grant ourselves access.
    let me = Principal("quickstart".into());
    fs.rbac.grant(Grant {
        principal: me.clone(),
        store: "quickstart-fs".into(),
        role: Role::Admin,
        workspace: "dev".into(),
        workspace_region: "local".into(),
    });

    // 4. Materialize a week of history, one scheduled tick per day.
    for day in 1..=7 {
        fs.clock.set(day * DAY);
        let outcomes = fs.materialize_tick(&table)?;
        println!("day {day}: {} job(s) materialized", outcomes.len());
    }

    // 5. Online retrieval (inference path).
    let hit = fs.get_online(&me, &table, "cust_00003", "local")?;
    println!(
        "online cust_00003 → {:?} (latency {}µs)",
        hit.record.as_ref().map(|r| r.values[0]),
        hit.latency_us
    );

    // 6. Offline point-in-time retrieval (training path).
    let frame = fs.get_training_frame(
        &me,
        None,
        &[("cust_00003".into(), 6 * DAY), ("cust_00004".into(), 5 * DAY)],
        &[FeatureRef::parse("txn_30d:1:720h_sum")?, FeatureRef::parse("txn_30d:1:720h_cnt")?],
        PitConfig::default(),
        "local",
    )?;
    for row in frame.rows() {
        println!("obs@{} → {:?}", row.observation.ts, row.features);
    }
    println!("fill rate: {:.2}", frame.fill_rate());
    Ok(())
}
