"""Layer-2 JAX graph: the feature-set transformation compute.

The paper's feature calculation (Algorithm 1) applies a transformation to
the source window ``[feature_window_start - source_lookback,
feature_window_end)`` and trims to the feature window.  The Rust
coordinator does the timestamp arithmetic, event binning, and trimming;
this module is the dense compute in the middle: per-bin partial
aggregates in, rolling feature columns out.

Two plan variants are lowered for every shape (paper §3.1.6):

* ``dsl``   — the optimized plan: one fused pass via the Pallas kernel
              (kernels/rolling.py).  This is what the feature store emits
              when the transformation is declared in the DSL.
* ``naive`` — the UDF-as-black-box baseline: per-output-bin recompute
              with ``lax.map`` + ``dynamic_slice``, the plan shape you
              get when the engine cannot see inside the transformation.

Both return the same 5-tuple ``(sum, cnt, mean, min, max)`` of
``f32[E, T]`` and are oracle-checked against ``kernels/ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.rolling import rolling_aggregate

AGG_NAMES = ("sum", "cnt", "mean", "min", "max")


def feature_graph_dsl(bin_sum, bin_cnt, bin_min, bin_max, *, window: int,
                      entity_block: int = 8):
    """Optimized plan: cast to f32, run the Pallas rolling kernel."""
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    return rolling_aggregate(
        f32(bin_sum), f32(bin_cnt), f32(bin_min), f32(bin_max),
        window=window, entity_block=entity_block)


def feature_graph_naive(bin_sum, bin_cnt, bin_min, bin_max, *, window: int):
    """Black-box-UDF baseline: recompute every window from scratch.

    ``lax.map`` over output bins, each doing a ``dynamic_slice`` gather +
    full reduce — O(T·W) unfusable-by-construction work, mirroring what a
    per-row UDF costs the engine.
    """
    bin_sum = jnp.asarray(bin_sum, jnp.float32)
    bin_cnt = jnp.asarray(bin_cnt, jnp.float32)
    bin_min = jnp.asarray(bin_min, jnp.float32)
    bin_max = jnp.asarray(bin_max, jnp.float32)
    e, t_pad = bin_sum.shape
    out_t = t_pad - (window - 1)

    def one_bin(t):
        s = jax.lax.dynamic_slice(bin_sum, (0, t), (e, window)).sum(axis=1)
        c = jax.lax.dynamic_slice(bin_cnt, (0, t), (e, window)).sum(axis=1)
        mn = jax.lax.dynamic_slice(bin_min, (0, t), (e, window)).min(axis=1)
        mx = jax.lax.dynamic_slice(bin_max, (0, t), (e, window)).max(axis=1)
        mean = jnp.where(c > 0, s / jnp.maximum(c, 1.0), 0.0)
        return s, c, mean, mn, mx

    cols = jax.lax.map(one_bin, jnp.arange(out_t))
    # lax.map stacks along axis 0 → [T, E]; transpose to [E, T].
    return tuple(col.T for col in cols)


def build_fn(variant: str, window: int, entity_block: int = 8):
    """Return the jit-able graph fn for a variant ('dsl' | 'naive')."""
    if variant == "dsl":
        return functools.partial(feature_graph_dsl, window=window,
                                 entity_block=entity_block)
    if variant == "naive":
        return functools.partial(feature_graph_naive, window=window)
    raise ValueError(f"unknown variant {variant!r}")
