"""AOT lowering: L2 graphs → HLO text artifacts + manifest.

Emits HLO *text*, not ``lowered.compile().serialize()``: jax ≥ 0.5 writes
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust ``xla`` 0.1.6 crate) rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Run from ``python/``:  ``python -m compile.aot --out ../artifacts``
(the Makefile's ``make artifacts`` target).  Python never runs again
after this — the Rust binary loads the artifacts at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import AGG_NAMES, build_fn

# (name, E, T, W, entity_block).  Shapes are static in HLO; the Rust
# runtime pads any workload up to the smallest fitting variant.
#   small  — unit tests / tiny feature sets
#   hourly — a week of hourly bins, 24 h (1-day) rolling window
#   daily  — ~3 months of daily bins, 30-day window (the paper's
#            30day_transactions_sum churn features)
# entity_block tuning (EXPERIMENTS.md §Perf L1): the interpret-mode grid
# loop lowers to an XLA while-loop, so fewer/larger blocks win until the
# block stops fitting cache. Measured through the Rust PJRT runtime
# (xla_extension 0.5.1 CPU), daily 256x96 w30: eb=8 → 5.4 ms, eb=16 →
# 3.3 ms, eb=32 → 2.7 ms (best), eb=64 → 2.9 ms. VMEM check for a real
# TPU (worst shape, eb=32): (4 in + 5 out) planes × 32 × 125 × 4 B ≈
# 140 KiB ≪ 16 MiB — the same schedule is VMEM-feasible on hardware.
SHAPES = [
    ("small", 16, 32, 4, 16),
    ("hourly", 64, 168, 24, 32),
    ("daily", 256, 96, 30, 32),
]
VARIANTS = ("dsl", "naive")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_variant(variant: str, e: int, t: int, w: int, eb: int) -> str:
    fn = build_fn(variant, window=w, entity_block=eb)
    spec = jax.ShapeDtypeStruct((e, t + w - 1), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec, spec, spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for name, e, t, w, eb in SHAPES:
        for variant in VARIANTS:
            text = lower_variant(variant, e, t, w, eb)
            fname = f"rolling_{name}_{variant}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append({
                "name": f"{name}_{variant}",
                "shape": name,
                "variant": variant,
                "file": fname,
                "entities": e,
                "time_bins": t,
                "window": w,
                "entity_block": eb,
                "inputs": ["bin_sum", "bin_cnt", "bin_min", "bin_max"],
                "outputs": list(AGG_NAMES),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"wrote {path} ({len(text)} chars)")

    manifest = {"format": 1, "dtype": "f32", "artifacts": entries}
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
