"""Layer-1 Pallas kernel: trailing rolling-window aggregation.

The compute hot-spot of the feature store (paper §3.1.6: "a common case is
rolling window aggregation"; §1's motivating features are
``30day_transactions_sum`` etc.).

Contract
--------
Inputs are *per-bin partial aggregates* for ``E`` entities over
``T + W - 1`` time bins.  The leading ``W - 1`` bins are the halo — the
paper's ``source_lookback`` from Algorithm 1 — so that output bin ``t``
aggregates input bins ``[t, t + W)`` on the padded axis, i.e. the trailing
window ending at output bin ``t``.

    bin_sum : f32[E, T + W - 1]   sum of event values in the bin
    bin_cnt : f32[E, T + W - 1]   number of events in the bin
    bin_min : f32[E, T + W - 1]   min event value (+inf when empty)
    bin_max : f32[E, T + W - 1]   max event value (-inf when empty)

Outputs, each ``f32[E, T]``:

    roll_sum, roll_cnt, roll_mean, roll_min, roll_max

Empty-window semantics: ``sum = 0``, ``cnt = 0``, ``mean = 0`` (masked,
not NaN), ``min = +inf``, ``max = -inf``.  The Rust side turns
``cnt == 0`` into "no feature value" when writing records.

TPU shaping
-----------
Grid over entity blocks; each invocation keeps one ``[BE, T + W - 1]``
halo slab per input in VMEM and emits ``[BE, T]`` slices.  The rolling
reduction is a W-step shifted accumulation over static slices — pure VPU
element-wise work, fully vectorized along T.  ``interpret=True`` is
required on CPU PJRT (real-TPU lowering emits a Mosaic custom-call the CPU
plugin cannot execute); the block structure is what matters for TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rolling_kernel(sum_ref, cnt_ref, min_ref, max_ref,
                    osum_ref, ocnt_ref, omean_ref, omin_ref, omax_ref,
                    *, window: int, out_t: int):
    """One entity block: shifted-accumulation rolling reduce.

    All refs hold f32.  Input refs are [BE, T + W - 1]; output refs are
    [BE, T].  ``window`` and ``out_t`` are compile-time constants so every
    slice below is static — the whole body is W fused element-wise ops.
    """
    s = sum_ref[:, 0:out_t]
    c = cnt_ref[:, 0:out_t]
    mn = min_ref[:, 0:out_t]
    mx = max_ref[:, 0:out_t]
    for w in range(1, window):
        s = s + sum_ref[:, w:w + out_t]
        c = c + cnt_ref[:, w:w + out_t]
        mn = jnp.minimum(mn, min_ref[:, w:w + out_t])
        mx = jnp.maximum(mx, max_ref[:, w:w + out_t])
    osum_ref[...] = s
    ocnt_ref[...] = c
    # Masked mean: 0 where the window is empty (cnt == 0).
    omean_ref[...] = jnp.where(c > 0, s / jnp.maximum(c, 1.0), 0.0)
    omin_ref[...] = mn
    omax_ref[...] = mx


def rolling_aggregate(bin_sum, bin_cnt, bin_min, bin_max, *,
                      window: int, entity_block: int = 8,
                      interpret: bool = True):
    """Rolling (sum, cnt, mean, min, max) over trailing ``window`` bins.

    Inputs are f32[E, T + W - 1] with the left halo already attached
    (Algorithm 1's source lookback).  Returns a 5-tuple of f32[E, T].
    """
    e, t_pad = bin_sum.shape
    out_t = t_pad - (window - 1)
    if out_t <= 0:
        raise ValueError(
            f"padded time axis {t_pad} shorter than window halo {window - 1}")
    if e % entity_block != 0:
        raise ValueError(f"E={e} not divisible by entity_block={entity_block}")

    kernel = functools.partial(_rolling_kernel, window=window, out_t=out_t)
    in_spec = pl.BlockSpec((entity_block, t_pad), lambda i: (i, 0))
    out_spec = pl.BlockSpec((entity_block, out_t), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((e, out_t), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(e // entity_block,),
        in_specs=[in_spec] * 4,
        out_specs=[out_spec] * 5,
        out_shape=[out_shape] * 5,
        interpret=interpret,
    )(bin_sum, bin_cnt, bin_min, bin_max)
