"""Pure-numpy oracle for the rolling-window aggregation kernel.

Deliberately written as the most literal possible transcription of the
spec — an explicit python loop over output bins, each recomputing its
window from scratch — so that any cleverness in the Pallas kernel or the
L2 variants is checked against something with no shared structure.
"""

from __future__ import annotations

import numpy as np


def rolling_aggregate_ref(bin_sum, bin_cnt, bin_min, bin_max, *, window: int):
    """Reference rolling (sum, cnt, mean, min, max).

    Inputs: float arrays [E, T + W - 1] (left halo attached).
    Returns a 5-tuple of float32 ndarrays [E, T].
    """
    bin_sum = np.asarray(bin_sum, dtype=np.float64)
    bin_cnt = np.asarray(bin_cnt, dtype=np.float64)
    bin_min = np.asarray(bin_min, dtype=np.float64)
    bin_max = np.asarray(bin_max, dtype=np.float64)
    e, t_pad = bin_sum.shape
    out_t = t_pad - (window - 1)
    assert out_t > 0

    osum = np.zeros((e, out_t), dtype=np.float64)
    ocnt = np.zeros((e, out_t), dtype=np.float64)
    omean = np.zeros((e, out_t), dtype=np.float64)
    omin = np.zeros((e, out_t), dtype=np.float64)
    omax = np.zeros((e, out_t), dtype=np.float64)
    for t in range(out_t):
        w_sum = bin_sum[:, t:t + window]
        w_cnt = bin_cnt[:, t:t + window]
        w_min = bin_min[:, t:t + window]
        w_max = bin_max[:, t:t + window]
        osum[:, t] = w_sum.sum(axis=1)
        ocnt[:, t] = w_cnt.sum(axis=1)
        c = ocnt[:, t]
        omean[:, t] = np.where(c > 0, osum[:, t] / np.maximum(c, 1.0), 0.0)
        omin[:, t] = w_min.min(axis=1)
        omax[:, t] = w_max.max(axis=1)
    return (osum.astype(np.float32), ocnt.astype(np.float32),
            omean.astype(np.float32), omin.astype(np.float32),
            omax.astype(np.float32))
