"""AOT path: lowering produces loadable HLO text; executing the lowered
module (via jax's own HLO round-trip) matches the eager graph; manifest
metadata is consistent with the lowered programs."""

import json
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import SHAPES, VARIANTS, lower_variant, to_hlo_text
from compile.model import build_fn


def test_hlo_text_structure():
    text = lower_variant("dsl", 16, 32, 4, 8)
    assert "ENTRY" in text and "HloModule" in text
    # 4 f32[16,35] params (T + W - 1 = 35).
    assert text.count("f32[16,35]") >= 4
    # Tuple of 5 outputs of shape [16,32].
    assert "f32[16,32]" in text


def test_hlo_deterministic():
    a = lower_variant("naive", 16, 32, 4, 8)
    b = lower_variant("naive", 16, 32, 4, 8)
    assert a == b


@pytest.mark.parametrize("variant", VARIANTS)
def test_lowered_matches_eager(variant):
    """Compile the stablehlo and execute — the exact artifact numerics."""
    e, t, w, eb = 16, 32, 4, 8
    fn = build_fn(variant, window=w, entity_block=eb)
    rng = np.random.default_rng(1)
    args = [jnp.asarray(rng.normal(size=(e, t + w - 1)), jnp.float32)
            for _ in range(4)]
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    got = compiled(*args)
    want = fn(*args)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wv),
                                   rtol=1e-5, atol=1e-5)


def test_manifest_shapes_cover_paper_workloads():
    """The daily shape must fit the paper's 30-day churn window."""
    by_name = {name: (e, t, w, eb) for name, e, t, w, eb in SHAPES}
    assert by_name["daily"][2] == 30
    assert by_name["hourly"][2] == 24
    for name, e, t, w, eb in SHAPES:
        assert e % eb == 0, f"{name}: E not divisible by entity_block"
        assert t >= 1 and w >= 1


def test_aot_cli_writes_manifest(tmp_path):
    """Run the real AOT entrypoint in-process and validate the manifest."""
    out = tmp_path / "artifacts"
    from compile import aot
    old_argv = sys.argv
    sys.argv = ["aot", "--out", str(out)]
    try:
        aot.main()
    finally:
        sys.argv = old_argv
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == 1
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {f"{s}_{v}" for s, *_ in SHAPES for v in VARIANTS}
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "ENTRY" in text
        assert len(text) > 200
