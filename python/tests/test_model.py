"""L2 correctness: dsl and naive plan variants agree with each other and
with the oracle; shape and masking invariants hold."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.ref import rolling_aggregate_ref
from compile.model import AGG_NAMES, build_fn, feature_graph_dsl, \
    feature_graph_naive


def _mk(rng, e, t_pad, density=0.6):
    occupied = rng.random((e, t_pad)) < density
    cnt = np.where(occupied, rng.integers(1, 4, (e, t_pad)), 0)
    vals = rng.normal(0, 5, (e, t_pad))
    return (np.where(occupied, vals * cnt, 0).astype(np.float32),
            cnt.astype(np.float32),
            np.where(occupied, vals, np.inf).astype(np.float32),
            np.where(occupied, vals, -np.inf).astype(np.float32))


@given(out_t=st.integers(1, 24), window=st.integers(1, 12),
       seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_dsl_equals_naive_equals_ref(out_t, window, seed):
    rng = np.random.default_rng(seed)
    e, t_pad = 16, out_t + window - 1
    parts = _mk(rng, e, t_pad)
    jparts = [jnp.asarray(p) for p in parts]
    got_dsl = feature_graph_dsl(*jparts, window=window)
    got_naive = feature_graph_naive(*jparts, window=window)
    want = rolling_aggregate_ref(*parts, window=window)
    for name, d, n, w in zip(AGG_NAMES, got_dsl, got_naive, want):
        np.testing.assert_allclose(np.asarray(d), w, rtol=1e-5, atol=1e-5,
                                   err_msg=f"dsl {name}")
        np.testing.assert_allclose(np.asarray(n), w, rtol=1e-5, atol=1e-5,
                                   err_msg=f"naive {name}")


def test_build_fn_variants():
    fn_d = build_fn("dsl", window=4)
    fn_n = build_fn("naive", window=4)
    rng = np.random.default_rng(0)
    parts = [jnp.asarray(p) for p in _mk(rng, 8, 11)]
    outs_d = jax.jit(fn_d)(*parts)
    outs_n = jax.jit(fn_n)(*parts)
    assert len(outs_d) == len(AGG_NAMES) == len(outs_n)
    for d, n in zip(outs_d, outs_n):
        assert d.shape == (8, 8) and n.shape == (8, 8)
        np.testing.assert_allclose(np.asarray(d), np.asarray(n),
                                   rtol=1e-5, atol=1e-5)


def test_build_fn_rejects_unknown():
    import pytest
    with pytest.raises(ValueError):
        build_fn("spark", window=4)


def test_mean_is_sum_over_cnt_where_nonempty():
    rng = np.random.default_rng(42)
    parts = [jnp.asarray(p) for p in _mk(rng, 8, 20, density=0.9)]
    s, c, m, _, _ = feature_graph_dsl(*parts, window=5)
    s, c, m = map(np.asarray, (s, c, m))
    nz = c > 0
    np.testing.assert_allclose(m[nz], (s / np.maximum(c, 1))[nz],
                               rtol=1e-5, atol=1e-6)
    assert np.all(m[~nz] == 0.0)
