"""L1 correctness: Pallas rolling kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes, window sizes, dtypes, and data regimes
(including empty bins carrying the +/-inf sentinels) — the CORE
correctness signal for the compute hot path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import rolling_aggregate_ref
from compile.kernels.rolling import rolling_aggregate

INF = np.float32(np.inf)


def make_bins(rng, e, t_pad, density=0.7, dtype=np.float32):
    """Random per-bin partials with some empty bins (cnt=0, ±inf sentinels)."""
    occupied = rng.random((e, t_pad)) < density
    cnt = np.where(occupied, rng.integers(1, 5, (e, t_pad)), 0).astype(dtype)
    vals = rng.normal(0.0, 10.0, (e, t_pad)).astype(dtype)
    bsum = np.where(occupied, vals * cnt, 0).astype(dtype)
    bmin = np.where(occupied, vals - 1.0, INF).astype(dtype)
    bmax = np.where(occupied, vals + 1.0, -INF).astype(dtype)
    return bsum, cnt, bmin, bmax


def check_against_ref(bsum, bcnt, bmin, bmax, window, entity_block,
                      rtol=1e-5, atol=1e-5):
    got = rolling_aggregate(
        jnp.asarray(bsum, jnp.float32), jnp.asarray(bcnt, jnp.float32),
        jnp.asarray(bmin, jnp.float32), jnp.asarray(bmax, jnp.float32),
        window=window, entity_block=entity_block)
    want = rolling_aggregate_ref(bsum, bcnt, bmin, bmax, window=window)
    for name, g, w in zip(("sum", "cnt", "mean", "min", "max"), got, want):
        np.testing.assert_allclose(
            np.asarray(g), w, rtol=rtol, atol=atol,
            err_msg=f"agg {name} mismatch (window={window})")


@given(
    e_blocks=st.integers(1, 4),
    entity_block=st.sampled_from([1, 2, 8]),
    out_t=st.integers(1, 40),
    window=st.integers(1, 16),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**32 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kernel_matches_ref_hypothesis(e_blocks, entity_block, out_t,
                                       window, density, seed):
    rng = np.random.default_rng(seed)
    e = e_blocks * entity_block
    t_pad = out_t + window - 1
    bsum, bcnt, bmin, bmax = make_bins(rng, e, t_pad, density)
    check_against_ref(bsum, bcnt, bmin, bmax, window, entity_block)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    bsum, bcnt, bmin, bmax = make_bins(rng, 16, 24 + 7, dtype=np.float32)
    # Cast inputs through the target dtype; tolerance loosened for bf16.
    arrs = [jnp.asarray(a, dtype).astype(jnp.float32)
            for a in (bsum, bcnt, bmin, bmax)]
    tol = 1e-5 if dtype == np.float32 else 0.15
    check_against_ref(*[np.asarray(a) for a in arrs], window=8,
                      entity_block=8, rtol=tol, atol=tol)


def test_window_one_is_identity():
    rng = np.random.default_rng(3)
    bsum, bcnt, bmin, bmax = make_bins(rng, 8, 16)
    out = rolling_aggregate(
        *(jnp.asarray(a, jnp.float32) for a in (bsum, bcnt, bmin, bmax)),
        window=1, entity_block=8)
    np.testing.assert_allclose(np.asarray(out[0]), bsum, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), bcnt, rtol=1e-6)


def test_all_empty_bins():
    e, out_t, w = 8, 10, 4
    t_pad = out_t + w - 1
    z = np.zeros((e, t_pad), np.float32)
    out = rolling_aggregate(
        jnp.asarray(z), jnp.asarray(z),
        jnp.full((e, t_pad), INF), jnp.full((e, t_pad), -INF),
        window=w, entity_block=8)
    assert np.all(np.asarray(out[0]) == 0)          # sum
    assert np.all(np.asarray(out[1]) == 0)          # cnt
    assert np.all(np.asarray(out[2]) == 0)          # mean masked to 0
    assert np.all(np.isposinf(np.asarray(out[3])))  # min = +inf
    assert np.all(np.isneginf(np.asarray(out[4])))  # max = -inf


def test_rejects_bad_shapes():
    z = jnp.zeros((8, 4), jnp.float32)
    with pytest.raises(ValueError):
        rolling_aggregate(z, z, z, z, window=8, entity_block=8)
    z2 = jnp.zeros((6, 16), jnp.float32)
    with pytest.raises(ValueError):
        rolling_aggregate(z2, z2, z2, z2, window=4, entity_block=8)


def test_halo_is_trailing_window():
    """Output bin t must aggregate padded bins [t, t+W) — i.e. the halo is
    *history*, and the last output bin sees the last input bin."""
    e, out_t, w = 8, 6, 3
    t_pad = out_t + w - 1
    bsum = np.zeros((e, t_pad), np.float32)
    bcnt = np.zeros((e, t_pad), np.float32)
    bsum[:, -1] = 5.0   # single event in the newest bin
    bcnt[:, -1] = 1.0
    out = rolling_aggregate(
        jnp.asarray(bsum), jnp.asarray(bcnt),
        jnp.full((e, t_pad), INF), jnp.full((e, t_pad), -INF),
        window=w, entity_block=8)
    s = np.asarray(out[0])
    assert np.all(s[:, -1] == 5.0)          # newest window includes it
    assert np.all(s[:, :-1] == 0.0)         # earlier windows do not
