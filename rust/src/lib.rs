//! # geofs — managed geo-distributed feature store
//!
//! Reproduction of *"Managed Geo-Distributed Feature Store: Architecture
//! and System Design"* (Microsoft, 2023) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the managed control plane: asset catalog,
//!   context-aware scheduler, materialization engine, offline/online
//!   stores, point-in-time query engine, geo topology, serving router,
//!   lineage, monitoring and governance.
//! * **Layer 2 (python/compile/model.py)** — the feature transformation
//!   graph in JAX, AOT-lowered to HLO text artifacts at build time.
//! * **Layer 1 (python/compile/kernels/rolling.py)** — the rolling-window
//!   aggregation Pallas kernel inside that graph.
//!
//! Python never runs at request time: [`runtime`] loads the AOT artifacts
//! via PJRT and executes them from the materialization hot path.
//!
//! Start with [`coordinator::FeatureStore`] (see `examples/quickstart.rs`).

pub mod benchkit;
pub mod exec;
pub mod testkit;
pub mod types;
pub mod util;

// Modules are enabled as they are implemented (bottom-up build order).
pub mod config;
pub mod coordinator;
pub mod dsl;
pub mod geo;
pub mod sim;
pub mod governance;
pub mod lineage;
pub mod load;
pub mod materialize;
pub mod monitor;
pub mod serving;
pub mod metadata;
pub mod query;
pub mod scheduler;
pub mod offline_store;
pub mod online_store;
pub mod runtime;
pub mod source;
pub mod storage;
pub mod stream;

pub use types::{FsError, Result};
