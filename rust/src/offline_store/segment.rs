//! On-disk format for offline-store segments (`.gfseg`, version 3 —
//! version 2 stays readable).
//!
//! **v3** serializes the compressed in-memory [`Segment`] nearly
//! verbatim: the block directory (anchor keys + byte offsets), the
//! delta/dod/lag-coded key bytes, and the tagged value plane
//! (fixed-width / dictionary / ragged). Loading is therefore a handful
//! of bulk reads — no per-row parse and no re-encode; per-block bounds,
//! zone stats and the uniqueness-key bloom are rebuilt by the one
//! validation decode [`Segment::from_encoded`] performs anyway.
//!
//! v3 layout (all little-endian):
//! ```text
//! magic "GFSEG3\0\0"
//! u32 n_rows
//! u32 n_blocks
//! per block:            // the directory: decode seed + byte extent
//!   u64 anchor_entity, i64 anchor_event, i64 anchor_creation
//!   u32 bytes_end       // cumulative end into the key bytes
//! u32 key_bytes; u8 * key_bytes
//! u8  plane_tag         // 0 = ragged, 1 = fixed, 2 = dict
//!   ragged: u32 off * (n_rows+1), f32 * off[n]
//!   fixed:  u32 width, f32 * n_rows*width
//!   dict:   u32 width, u32 dict_rows, f32 * dict_rows*width, u32 code * n_rows
//! u64 checksum          // FNV-1a over everything after magic
//! ```
//!
//! **v2** (raw whole columns, the PR 2 format) is still read: its
//! columns are validated and re-encoded into the compressed form on
//! load, so stores persisted before the compression rebuild keep
//! working. [`persist_segment_v2`] is retained as the legacy writer so
//! the v2→v3 back-compat path stays testable.
//!
//! Writes go to a temp file then rename, so a crashed writer never
//! leaves a torn segment under the real name; the checksum catches
//! bit-level corruption, and the load-time validation decode rejects
//! shape and sort-order violations.

use std::io::Read;
use std::path::Path;

use super::bloom::BLOOM_BITS_PER_KEY;
use super::columnar::{Segment, ValuePlane};
use crate::types::{FeatureRecord, FsError, Result};

const MAGIC_V3: &[u8; 8] = b"GFSEG3\0\0";
const MAGIC_V2: &[u8; 8] = b"GFSEG2\0\0";

const TAG_RAGGED: u8 = 0;
const TAG_FIXED: u8 = 1;
const TAG_DICT: u8 = 2;

/// FNV-1a over the payload — cheap corruption detection.
fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn write_file_to(
    fs: &dyn crate::storage::Vfs,
    path: &Path,
    magic: &[u8; 8],
    payload: &[u8],
) -> Result<()> {
    let sum = checksum(payload);
    // Temp file + fsync + rename + parent-dir fsync via the shared
    // storage-layer helper: a crashed writer never leaves a torn
    // segment under the real name, and the rename itself is durable.
    crate::storage::vfs::atomic_write_parts(fs, path, &[magic, payload, &sum.to_le_bytes()])
}

fn write_file(path: &Path, magic: &[u8; 8], payload: &[u8]) -> Result<()> {
    write_file_to(&crate::storage::RealFs, path, magic, payload)
}

/// [`persist_segment`] through an explicit filesystem seam — the
/// durable checkpoint path writes segments here so fault injection
/// covers them like every other storage-layer write.
pub fn persist_segment_to(
    fs: &dyn crate::storage::Vfs,
    path: &Path,
    seg: &Segment,
) -> Result<()> {
    write_file_to(fs, path, MAGIC_V3, &encode_segment_v3(seg))
}

/// Persist one sorted columnar segment in the v3 compressed format.
pub fn persist_segment(path: &Path, seg: &Segment) -> Result<()> {
    write_file(path, MAGIC_V3, &encode_segment_v3(seg))
}

fn encode_segment_v3(seg: &Segment) -> Vec<u8> {
    let (blocks, keys, plane) = seg.encoded_parts();
    let mut payload = Vec::with_capacity(8 + blocks.len() * 28 + keys.len() + plane.size_bytes());
    payload.extend_from_slice(&(seg.len() as u32).to_le_bytes());
    payload.extend_from_slice(&(blocks.len() as u32).to_le_bytes());
    for m in blocks {
        payload.extend_from_slice(&m.first_entity.to_le_bytes());
        payload.extend_from_slice(&m.first_event.to_le_bytes());
        payload.extend_from_slice(&m.first_creation.to_le_bytes());
        payload.extend_from_slice(&m.bytes_end.to_le_bytes());
    }
    payload.extend_from_slice(&(keys.len() as u32).to_le_bytes());
    payload.extend_from_slice(keys);
    match plane {
        ValuePlane::Ragged { offsets, values } => {
            payload.push(TAG_RAGGED);
            for &o in offsets.iter() {
                payload.extend_from_slice(&o.to_le_bytes());
            }
            for v in values.iter() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        ValuePlane::Fixed { width, values } => {
            payload.push(TAG_FIXED);
            payload.extend_from_slice(&width.to_le_bytes());
            for v in values.iter() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        ValuePlane::Dict { width, dict, codes } => {
            payload.push(TAG_DICT);
            payload.extend_from_slice(&width.to_le_bytes());
            let dict_rows = if *width == 0 { 0 } else { dict.len() as u32 / width };
            payload.extend_from_slice(&dict_rows.to_le_bytes());
            for v in dict.iter() {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            for &c in codes.iter() {
                payload.extend_from_slice(&c.to_le_bytes());
            }
        }
    }
    payload
}

/// Legacy v2 writer (raw whole columns). Kept so the v2→v3 read
/// compatibility path stays exercised by tests and so downgrade
/// tooling has an escape hatch; new code persists v3.
pub fn persist_segment_v2(path: &Path, seg: &Segment) -> Result<()> {
    let n = seg.len();
    let mut entities = Vec::with_capacity(n);
    let mut event_ts = Vec::with_capacity(n);
    let mut creation_ts = Vec::with_capacity(n);
    let mut offsets: Vec<u32> = Vec::with_capacity(n + 1);
    offsets.push(0);
    let mut n_values = 0u32;
    for row in seg.iter() {
        entities.push(row.entity);
        event_ts.push(row.event_ts);
        creation_ts.push(row.creation_ts);
        n_values += row.values.len() as u32;
        offsets.push(n_values);
    }
    let mut payload = Vec::with_capacity(4 + n * (8 + 8 + 8 + 4) + 4);
    payload.extend_from_slice(&(n as u32).to_le_bytes());
    for &e in &entities {
        payload.extend_from_slice(&e.to_le_bytes());
    }
    for &t in &event_ts {
        payload.extend_from_slice(&t.to_le_bytes());
    }
    for &t in &creation_ts {
        payload.extend_from_slice(&t.to_le_bytes());
    }
    for &o in &offsets {
        payload.extend_from_slice(&o.to_le_bytes());
    }
    for i in 0..n {
        for v in seg.values_of(i) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    write_file(path, MAGIC_V2, &payload)
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(FsError::Other(format!("{:?}: truncated segment", self.path)));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn done(&self) -> bool {
        self.pos == self.bytes.len()
    }
}

/// Guard against absurd counts in a corrupt-but-checksum-valid header
/// (the checksum protects integrity, not semantics).
fn checked_vec_len(r: &Reader<'_>, count: usize, elem_bytes: usize, what: &str) -> Result<usize> {
    if count.saturating_mul(elem_bytes) > r.bytes.len() {
        return Err(FsError::Other(format!("{:?}: implausible {what} count {count}", r.path)));
    }
    Ok(count)
}

fn load_v3(path: &Path, payload: &[u8], bloom_bits: u32) -> Result<Segment> {
    let mut r = Reader { bytes: payload, pos: 0, path };
    let n = r.u32()? as usize;
    let raw_blocks = r.u32()? as usize;
    let n_blocks = checked_vec_len(&r, raw_blocks, 28, "block")?;
    let mut anchors = Vec::with_capacity(n_blocks);
    let mut bytes_ends = Vec::with_capacity(n_blocks);
    for _ in 0..n_blocks {
        let e = r.u64()?;
        let ev = r.i64()?;
        let cr = r.i64()?;
        anchors.push((e, ev, cr));
        bytes_ends.push(r.u32()?);
    }
    let key_bytes = r.u32()? as usize;
    let keys = r.take(key_bytes)?.to_vec();
    let plane = match r.u8()? {
        TAG_RAGGED => {
            let count = checked_vec_len(&r, n + 1, 4, "offset")?;
            let mut offsets = Vec::with_capacity(count);
            for _ in 0..count {
                offsets.push(r.u32()?);
            }
            let n_vals = checked_vec_len(&r, offsets.last().copied().unwrap_or(0) as usize, 4, "value")?;
            let mut values = Vec::with_capacity(n_vals);
            for _ in 0..n_vals {
                values.push(r.f32()?);
            }
            ValuePlane::Ragged { offsets: offsets.into_boxed_slice(), values: values.into_boxed_slice() }
        }
        TAG_FIXED => {
            let width = r.u32()?;
            let n_vals = checked_vec_len(&r, n.saturating_mul(width as usize), 4, "value")?;
            let mut values = Vec::with_capacity(n_vals);
            for _ in 0..n_vals {
                values.push(r.f32()?);
            }
            ValuePlane::Fixed { width, values: values.into_boxed_slice() }
        }
        TAG_DICT => {
            let width = r.u32()?;
            let dict_rows = r.u32()? as usize;
            let n_dict = checked_vec_len(&r, dict_rows.saturating_mul(width as usize), 4, "dict value")?;
            let mut dict = Vec::with_capacity(n_dict);
            for _ in 0..n_dict {
                dict.push(r.f32()?);
            }
            let n_codes = checked_vec_len(&r, n, 4, "code")?;
            let mut codes = Vec::with_capacity(n_codes);
            for _ in 0..n_codes {
                codes.push(r.u32()?);
            }
            ValuePlane::Dict { width, dict: dict.into_boxed_slice(), codes: codes.into_boxed_slice() }
        }
        tag => return Err(FsError::Other(format!("{path:?}: unknown value-plane tag {tag}"))),
    };
    if !r.done() {
        return Err(FsError::Other(format!("{path:?}: trailing bytes in segment")));
    }
    Segment::from_encoded(n, anchors, bytes_ends, keys, plane, bloom_bits)
        .map_err(|e| FsError::Other(format!("{path:?}: {e}")))
}

fn load_v2(path: &Path, payload: &[u8], bloom_bits: u32) -> Result<Segment> {
    let mut r = Reader { bytes: payload, pos: 0, path };
    let raw_rows = r.u32()? as usize;
    let n = checked_vec_len(&r, raw_rows, 28, "row")?;
    let mut entities = Vec::with_capacity(n);
    for _ in 0..n {
        entities.push(r.u64()?);
    }
    let mut event_ts = Vec::with_capacity(n);
    for _ in 0..n {
        event_ts.push(r.i64()?);
    }
    let mut creation_ts = Vec::with_capacity(n);
    for _ in 0..n {
        creation_ts.push(r.i64()?);
    }
    let mut value_offsets = Vec::with_capacity(n + 1);
    for _ in 0..n + 1 {
        value_offsets.push(r.u32()?);
    }
    let n_values = checked_vec_len(&r, *value_offsets.last().unwrap_or(&0) as usize, 4, "value")?;
    let mut values = Vec::with_capacity(n_values);
    for _ in 0..n_values {
        values.push(r.f32()?);
    }
    if !r.done() {
        return Err(FsError::Other(format!("{path:?}: trailing bytes in segment")));
    }
    Segment::from_columns_with(entities, event_ts, creation_ts, value_offsets, values, bloom_bits)
        .map_err(|e| FsError::Other(format!("{path:?}: {e}")))
}

/// Load one segment (v3 or legacy v2) at the default bloom density;
/// verifies checksum, shape and sort order.
pub fn load_segment(path: &Path) -> Result<Segment> {
    load_segment_with(path, BLOOM_BITS_PER_KEY)
}

/// [`load_segment`] with an explicit uniqueness-bloom density — the
/// density is a store tuning knob, not part of the file format, so a
/// store reloading its own segments passes its configured value here
/// (a restart must not silently reset an operator's memory bound back
/// to the default).
pub fn load_segment_with(path: &Path, bloom_bits: u32) -> Result<Segment> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < 8 + 4 + 8 {
        return Err(FsError::Other(format!("{path:?}: not a geofs segment")));
    }
    let magic: &[u8] = &bytes[..8];
    if magic != MAGIC_V3 && magic != MAGIC_V2 {
        return Err(FsError::Other(format!("{path:?}: not a geofs v2/v3 segment")));
    }
    let payload = &bytes[8..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(FsError::Other(format!("{path:?}: checksum mismatch (corrupt segment)")));
    }
    if magic == MAGIC_V3 {
        load_v3(path, payload, bloom_bits)
    } else {
        load_v2(path, payload, bloom_bits)
    }
}

/// Row-level convenience: persist records as one sorted segment.
/// Rows sharing a `(entity, event_ts, creation_ts)` uniqueness key are
/// collapsed to one (Alg 2 idempotence — they are the same logical
/// record), since the loader rejects duplicate keys.
pub fn persist_table(path: &Path, rows: &[&FeatureRecord]) -> Result<()> {
    let mut owned: Vec<FeatureRecord> = rows.iter().map(|r| (*r).clone()).collect();
    owned.sort_unstable_by_key(|r| (r.entity, r.event_ts, r.creation_ts));
    owned.dedup_by_key(|r| r.unique_key());
    let seg = Segment::from_unsorted(owned);
    persist_segment(path, &seg)
}

/// Row-level convenience: load a segment as owned records (in segment —
/// i.e. `(entity, event_ts, creation_ts)` — order).
pub fn load_table(path: &Path) -> Result<Vec<FeatureRecord>> {
    Ok(load_segment(path)?.iter().map(|r| r.to_record()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    #[test]
    fn roundtrip_preserves_sorted_rows() {
        let dir = TempDir::new("seg-rt");
        let path = dir.file("t.gfseg");
        let rows = vec![
            FeatureRecord::new(u64::MAX, -5, 0, vec![]),
            FeatureRecord::new(1, 100, 150, vec![1.0, 2.0, f32::INFINITY]),
        ];
        persist_table(&path, &rows.iter().collect::<Vec<_>>()).unwrap();
        let got = load_table(&path).unwrap();
        // Persist sorts by (entity, event_ts, creation_ts).
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], rows[1]);
        assert_eq!(got[1], rows[0]);
    }

    #[test]
    fn segment_roundtrip_is_columnar_identical() {
        let dir = TempDir::new("seg-col");
        let path = dir.file("t.gfseg");
        let seg = Segment::from_unsorted(vec![
            FeatureRecord::new(3, 30, 40, vec![0.25]),
            FeatureRecord::new(1, 10, 20, vec![1.0, -2.0]),
            FeatureRecord::new(1, 10, 99, vec![]),
        ]);
        persist_segment(&path, &seg).unwrap();
        let got = load_segment(&path).unwrap();
        assert_eq!(got.len(), seg.len());
        for (a, b) in got.iter().zip(seg.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(got.stats(), seg.stats());
    }

    #[test]
    fn v3_roundtrips_every_plane_encoding() {
        let dir = TempDir::new("seg-planes");
        // Dict (repetitive), Fixed (unique), Ragged (mixed widths),
        // multi-block (n > 256) — each must survive persist/load exactly.
        let cases: Vec<Vec<FeatureRecord>> = vec![
            (0..300).map(|i| FeatureRecord::new(i, i as i64, i as i64 + 1, vec![(i % 2) as f32])).collect(),
            (0..300).map(|i| FeatureRecord::new(i, i as i64, i as i64 + 1, vec![i as f32, 2.0])).collect(),
            vec![
                FeatureRecord::new(1, 1, 2, vec![1.0]),
                FeatureRecord::new(2, 1, 2, vec![1.0, 2.0]),
                FeatureRecord::new(3, 1, 2, vec![]),
            ],
            (0..700)
                .map(|i| FeatureRecord::new(i % 9, (i as i64) * 7, (i as i64) * 7 + 3, vec![1.0; 5]))
                .collect(),
        ];
        for (k, rows) in cases.into_iter().enumerate() {
            let path = dir.file(&format!("case{k}.gfseg"));
            let seg = Segment::from_unsorted(rows);
            persist_segment(&path, &seg).unwrap();
            let got = load_segment(&path).unwrap();
            assert_eq!(got.len(), seg.len(), "case {k}");
            for (a, b) in got.iter().zip(seg.iter()) {
                assert_eq!(a, b, "case {k}");
            }
            assert_eq!(got.stats(), seg.stats(), "case {k}");
        }
    }

    #[test]
    fn v2_files_load_into_compressed_segments() {
        // The back-compat contract: a store persisted by the PR 2 format
        // loads bit-identically through the new engine.
        let dir = TempDir::new("seg-v2compat");
        let path = dir.file("old.gfseg");
        let seg = Segment::from_unsorted(
            (0..500)
                .map(|i| FeatureRecord::new(i % 11, (i as i64) * 13, (i as i64) * 13 + 7, vec![i as f32, 0.5]))
                .collect(),
        );
        persist_segment_v2(&path, &seg).unwrap();
        // File on disk really is v2.
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"GFSEG2\0\0");
        let got = load_segment(&path).unwrap();
        assert_eq!(got.len(), seg.len());
        for (a, b) in got.iter().zip(seg.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(got.stats(), seg.stats());
        // And a v3 re-persist of the loaded segment reads back the same.
        let path3 = dir.file("new.gfseg");
        persist_segment(&path3, &got).unwrap();
        let got3 = load_segment(&path3).unwrap();
        for (a, b) in got3.iter().zip(seg.iter()) {
            assert_eq!(a, b);
        }
        // v3 is smaller than v2 for this (regular-cadence) table.
        let v2_len = std::fs::metadata(&path).unwrap().len();
        let v3_len = std::fs::metadata(&path3).unwrap().len();
        assert!(v3_len < v2_len, "v3 {v3_len} should undercut v2 {v2_len}");
    }

    #[test]
    fn detects_corruption() {
        let dir = TempDir::new("seg-corrupt");
        for (name, writer) in [
            ("t3.gfseg", persist_segment as fn(&Path, &Segment) -> Result<()>),
            ("t2.gfseg", persist_segment_v2 as fn(&Path, &Segment) -> Result<()>),
        ] {
            let path = dir.file(name);
            let seg = Segment::from_unsorted(vec![FeatureRecord::new(1, 2, 3, vec![4.0])]);
            writer(&path, &seg).unwrap();
            // Flip a payload byte.
            let mut bytes = std::fs::read(&path).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xff;
            std::fs::write(&path, &bytes).unwrap();
            assert!(load_table(&path).is_err(), "{name}");
        }
    }

    #[test]
    fn rejects_non_segment_and_old_format() {
        let dir = TempDir::new("seg-junk");
        let junk = dir.file("junk.gfseg");
        std::fs::write(&junk, b"hello world, definitely not a segment").unwrap();
        assert!(load_table(&junk).is_err());
        // A v1 magic is rejected cleanly, not misparsed.
        let old = dir.file("old.gfseg");
        std::fs::write(&old, b"GFSEG1\0\0rest-of-an-old-file").unwrap();
        assert!(load_table(&old).is_err());
    }

    #[test]
    fn persist_table_collapses_duplicate_keys() {
        let dir = TempDir::new("seg-dup");
        let path = dir.file("t.gfseg");
        let r = FeatureRecord::new(1, 2, 3, vec![4.0]);
        persist_table(&path, &[&r, &r, &r]).unwrap();
        let got = load_table(&path).unwrap();
        assert_eq!(got, vec![r]);
    }

    #[test]
    fn empty_table() {
        let dir = TempDir::new("seg-empty");
        let path = dir.file("t.gfseg");
        persist_table(&path, &[]).unwrap();
        assert_eq!(load_table(&path).unwrap(), vec![]);
        assert!(load_segment(&path).unwrap().is_empty());
    }
}
