//! On-disk format for offline-store segments (`.gfseg`, version 2).
//!
//! The file layout mirrors the in-memory [`Segment`]: whole columns are
//! written contiguously (not row-interleaved), so a load is four bulk
//! column decodes instead of a per-row parse, and the sorted order is
//! preserved — a loaded table needs no re-sort and no re-index.
//!
//! Layout (all little-endian):
//! ```text
//! magic "GFSEG2\0\0"
//! u32 n_rows
//! u64 entity      * n_rows
//! i64 event_ts    * n_rows
//! i64 creation_ts * n_rows
//! u32 value_off   * (n_rows + 1)   // off[0] = 0, off[n] = n_values
//! f32 value       * n_values
//! u64 checksum                      // FNV-1a over everything after magic
//! ```
//!
//! Writes go to a temp file then rename, so a crashed writer never
//! leaves a torn segment under the real name; the checksum catches
//! bit-level corruption, and [`Segment::from_columns`] validates shape
//! and sort order on load.

use std::io::{Read, Write};
use std::path::Path;

use super::columnar::Segment;
use crate::types::{FeatureRecord, FsError, Result};

const MAGIC: &[u8; 8] = b"GFSEG2\0\0";

/// FNV-1a over the payload — cheap corruption detection.
fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Persist one sorted columnar segment.
pub fn persist_segment(path: &Path, seg: &Segment) -> Result<()> {
    let n = seg.len();
    let mut payload = Vec::with_capacity(4 + n * (8 + 8 + 8 + 4) + 4);
    payload.extend_from_slice(&(n as u32).to_le_bytes());
    for &e in seg.entities() {
        payload.extend_from_slice(&e.to_le_bytes());
    }
    for &t in seg.event_ts() {
        payload.extend_from_slice(&t.to_le_bytes());
    }
    for &t in seg.creation_ts() {
        payload.extend_from_slice(&t.to_le_bytes());
    }
    let mut off: u32 = 0;
    payload.extend_from_slice(&off.to_le_bytes());
    for i in 0..n {
        off += seg.values_of(i).len() as u32;
        payload.extend_from_slice(&off.to_le_bytes());
    }
    for i in 0..n {
        for v in seg.values_of(i) {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = checksum(&payload);
    // Temp file + rename: a crashed writer never leaves a torn segment
    // under the real name.
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&sum.to_le_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load one segment; verifies checksum, shape and sort order.
pub fn load_segment(path: &Path) -> Result<Segment> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < MAGIC.len() + 4 + 8 || &bytes[..MAGIC.len()] != MAGIC {
        return Err(FsError::Other(format!("{path:?}: not a geofs v2 segment")));
    }
    let payload = &bytes[MAGIC.len()..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(FsError::Other(format!("{path:?}: checksum mismatch (corrupt segment)")));
    }

    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > payload.len() {
            return Err(FsError::Other(format!("{path:?}: truncated segment")));
        }
        let s = &payload[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut entities = Vec::with_capacity(n);
    for _ in 0..n {
        entities.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()));
    }
    let mut event_ts = Vec::with_capacity(n);
    for _ in 0..n {
        event_ts.push(i64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()));
    }
    let mut creation_ts = Vec::with_capacity(n);
    for _ in 0..n {
        creation_ts.push(i64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()));
    }
    let mut value_offsets = Vec::with_capacity(n + 1);
    for _ in 0..n + 1 {
        value_offsets.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()));
    }
    let n_values = *value_offsets.last().unwrap_or(&0) as usize;
    let mut values = Vec::with_capacity(n_values);
    for _ in 0..n_values {
        values.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()));
    }
    if pos != payload.len() {
        return Err(FsError::Other(format!("{path:?}: trailing bytes in segment")));
    }
    Segment::from_columns(entities, event_ts, creation_ts, value_offsets, values)
        .map_err(|e| FsError::Other(format!("{path:?}: {e}")))
}

/// Row-level convenience: persist records as one sorted segment.
/// Rows sharing a `(entity, event_ts, creation_ts)` uniqueness key are
/// collapsed to one (Alg 2 idempotence — they are the same logical
/// record), since the loader rejects duplicate keys.
pub fn persist_table(path: &Path, rows: &[&FeatureRecord]) -> Result<()> {
    let mut owned: Vec<FeatureRecord> = rows.iter().map(|r| (*r).clone()).collect();
    owned.sort_unstable_by_key(|r| (r.entity, r.event_ts, r.creation_ts));
    owned.dedup_by_key(|r| r.unique_key());
    let seg = Segment::from_unsorted(owned);
    persist_segment(path, &seg)
}

/// Row-level convenience: load a segment as owned records (in segment —
/// i.e. `(entity, event_ts, creation_ts)` — order).
pub fn load_table(path: &Path) -> Result<Vec<FeatureRecord>> {
    Ok(load_segment(path)?.iter().map(|r| r.to_record()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    #[test]
    fn roundtrip_preserves_sorted_rows() {
        let dir = TempDir::new("seg-rt");
        let path = dir.file("t.gfseg");
        let rows = vec![
            FeatureRecord::new(u64::MAX, -5, 0, vec![]),
            FeatureRecord::new(1, 100, 150, vec![1.0, 2.0, f32::INFINITY]),
        ];
        persist_table(&path, &rows.iter().collect::<Vec<_>>()).unwrap();
        let got = load_table(&path).unwrap();
        // Persist sorts by (entity, event_ts, creation_ts).
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], rows[1]);
        assert_eq!(got[1], rows[0]);
    }

    #[test]
    fn segment_roundtrip_is_columnar_identical() {
        let dir = TempDir::new("seg-col");
        let path = dir.file("t.gfseg");
        let seg = Segment::from_unsorted(vec![
            FeatureRecord::new(3, 30, 40, vec![0.25]),
            FeatureRecord::new(1, 10, 20, vec![1.0, -2.0]),
            FeatureRecord::new(1, 10, 99, vec![]),
        ]);
        persist_segment(&path, &seg).unwrap();
        let got = load_segment(&path).unwrap();
        assert_eq!(got.entities(), seg.entities());
        assert_eq!(got.event_ts(), seg.event_ts());
        assert_eq!(got.creation_ts(), seg.creation_ts());
        for i in 0..seg.len() {
            assert_eq!(got.values_of(i), seg.values_of(i));
        }
        assert_eq!(got.stats(), seg.stats());
    }

    #[test]
    fn detects_corruption() {
        let dir = TempDir::new("seg-corrupt");
        let path = dir.file("t.gfseg");
        let rows = vec![FeatureRecord::new(1, 2, 3, vec![4.0])];
        persist_table(&path, &rows.iter().collect::<Vec<_>>()).unwrap();
        // Flip a payload byte.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_table(&path).is_err());
    }

    #[test]
    fn rejects_non_segment_and_old_format() {
        let dir = TempDir::new("seg-junk");
        let junk = dir.file("junk.gfseg");
        std::fs::write(&junk, b"hello world, definitely not a segment").unwrap();
        assert!(load_table(&junk).is_err());
        // A v1 magic is rejected cleanly, not misparsed.
        let old = dir.file("old.gfseg");
        std::fs::write(&old, b"GFSEG1\0\0rest-of-an-old-file").unwrap();
        assert!(load_table(&old).is_err());
    }

    #[test]
    fn persist_table_collapses_duplicate_keys() {
        let dir = TempDir::new("seg-dup");
        let path = dir.file("t.gfseg");
        let r = FeatureRecord::new(1, 2, 3, vec![4.0]);
        persist_table(&path, &[&r, &r, &r]).unwrap();
        let got = load_table(&path).unwrap();
        assert_eq!(got, vec![r]);
    }

    #[test]
    fn empty_table() {
        let dir = TempDir::new("seg-empty");
        let path = dir.file("t.gfseg");
        persist_table(&path, &[]).unwrap();
        assert_eq!(load_table(&path).unwrap(), vec![]);
        assert!(load_segment(&path).unwrap().is_empty());
    }
}
