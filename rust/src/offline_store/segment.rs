//! On-disk segment format for offline-store tables.
//!
//! Simple length-prefixed binary layout with a CRC-style checksum —
//! enough to give the offline store real durability semantics (the geo
//! failover test kills a region and reloads from segments) without
//! pulling in parquet.
//!
//! Layout (all little-endian):
//! ```text
//! magic "GFSEG1\0\0" | u32 n_rows | rows... | u64 checksum
//! row := u64 entity | i64 event_ts | i64 creation_ts
//!        | u32 n_values | f32 * n_values
//! ```

use std::io::{Read, Write};
use std::path::Path;

use crate::types::{FeatureRecord, FsError, Result};

const MAGIC: &[u8; 8] = b"GFSEG1\0\0";

/// FNV-1a over the payload — cheap corruption detection.
fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn persist_table(path: &Path, rows: &[&FeatureRecord]) -> Result<()> {
    let mut payload = Vec::with_capacity(rows.len() * 32);
    payload.extend_from_slice(&(rows.len() as u32).to_le_bytes());
    for r in rows {
        payload.extend_from_slice(&r.entity.to_le_bytes());
        payload.extend_from_slice(&r.event_ts.to_le_bytes());
        payload.extend_from_slice(&r.creation_ts.to_le_bytes());
        payload.extend_from_slice(&(r.values.len() as u32).to_le_bytes());
        for v in r.values.iter() {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    }
    let sum = checksum(&payload);
    // Write to a temp file then rename: a crashed writer never leaves a
    // torn segment under the real name.
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&sum.to_le_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

pub fn load_table(path: &Path) -> Result<Vec<FeatureRecord>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    if bytes.len() < MAGIC.len() + 4 + 8 || &bytes[..MAGIC.len()] != MAGIC {
        return Err(FsError::Other(format!("{path:?}: not a geofs segment")));
    }
    let payload = &bytes[MAGIC.len()..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if checksum(payload) != stored_sum {
        return Err(FsError::Other(format!("{path:?}: checksum mismatch (corrupt segment)")));
    }

    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > payload.len() {
            return Err(FsError::Other(format!("{path:?}: truncated segment")));
        }
        let s = &payload[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let n_rows = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let entity = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let event_ts = i64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let creation_ts = i64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let n_vals = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut values = Vec::with_capacity(n_vals);
        for _ in 0..n_vals {
            values.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()));
        }
        rows.push(FeatureRecord::new(entity, event_ts, creation_ts, values));
    }
    if pos != payload.len() {
        return Err(FsError::Other(format!("{path:?}: trailing bytes in segment")));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("geofs-seg-{}-{tag}.gfseg", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let path = tmpfile("rt");
        let rows = vec![
            FeatureRecord::new(1, 100, 150, vec![1.0, 2.0, f32::INFINITY]),
            FeatureRecord::new(u64::MAX, -5, 0, vec![]),
        ];
        persist_table(&path, &rows.iter().collect::<Vec<_>>()).unwrap();
        let got = load_table(&path).unwrap();
        assert_eq!(got, rows);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn detects_corruption() {
        let path = tmpfile("corrupt");
        let rows = vec![FeatureRecord::new(1, 2, 3, vec![4.0])];
        persist_table(&path, &rows.iter().collect::<Vec<_>>()).unwrap();
        // Flip a payload byte.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_table(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_non_segment() {
        let path = tmpfile("junk");
        std::fs::write(&path, b"hello world, definitely not a segment").unwrap();
        assert!(load_table(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_table() {
        let path = tmpfile("empty");
        persist_table(&path, &[]).unwrap();
        assert_eq!(load_table(&path).unwrap(), vec![]);
        std::fs::remove_file(&path).unwrap();
    }
}
