//! Per-segment bloom filter over uniqueness keys (§4.5.1).
//!
//! The offline store's Alg-2 dedupe used to keep **every** row's
//! `(entity, event_ts, creation_ts)` key in one per-table `HashSet` —
//! ~48 bytes of heap per row, forever, the last per-row memory outside
//! the segments themselves. Sealed segments now answer "might this key
//! already exist?" with a bloom filter built at seal/load time
//! (~`BLOOM_BITS_PER_KEY` bits per row), and only the small unsealed
//! delta keeps an exact key set.
//!
//! Correctness does **not** rest on the filter: a bloom hit is always
//! confirmed by an exact binary-search probe of the segment's sorted
//! key columns ([`super::columnar::SegmentCursor::contains`]), so a
//! false positive costs one block decode, never a wrongly-skipped
//! insert, and a miss is definitive (no false negatives). The
//! idempotence-under-false-positives property is pinned by a dedicated
//! test in `tests/offline_stress.rs` with a deliberately degraded
//! 1-bit-per-key filter.

use crate::types::{EntityId, Timestamp};

/// Default sizing: ~10 bits/key with 7 probes ≈ 1% false positives.
pub const BLOOM_BITS_PER_KEY: u32 = 10;

type Key = (EntityId, Timestamp, Timestamp);

/// splitmix64 finalizer — the same avalanche the online store's shard
/// router and the stream log's key router use.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Two independent 64-bit hashes of a uniqueness key; probe `i` uses
/// `h1 + i·h2` (Kirsch–Mitzenmacher double hashing).
fn hash_pair(key: Key) -> (u64, u64) {
    let h1 = mix(key.0 ^ mix(key.1 as u64).wrapping_add(0x9e3779b97f4a7c15));
    let h2 = mix(h1 ^ mix(key.2 as u64)) | 1; // odd: never a zero stride
    (h1, h2)
}

/// Immutable bloom filter, built once per segment.
#[derive(Debug, Clone)]
pub struct Bloom {
    words: Box<[u64]>,
    probes: u32,
}

impl Bloom {
    /// Build over `keys` at `bits_per_key` density (probe count derived
    /// as `ln 2 · bits_per_key`, clamped to ≥ 1).
    pub fn build(keys: impl Iterator<Item = Key>, n: usize, bits_per_key: u32) -> Bloom {
        let bits = (n.max(1) as u64).saturating_mul(bits_per_key.max(1) as u64).max(64);
        let words = vec![0u64; bits.div_ceil(64) as usize];
        let probes = ((bits_per_key as f64 * 0.69) as u32).max(1);
        let mut b = Bloom { words: words.into_boxed_slice(), probes };
        for key in keys {
            b.insert(key);
        }
        b
    }

    /// Add one key (filters are built once per segment — at seal time
    /// or during the load-time validation decode — never mutated after).
    pub(crate) fn insert(&mut self, key: Key) {
        let nbits = self.words.len() as u64 * 64;
        let (h1, h2) = hash_pair(key);
        for i in 0..self.probes as u64 {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) % nbits;
            self.words[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
    }

    /// `false` means the key is definitely absent; `true` means the
    /// caller must confirm with an exact probe.
    pub fn might_contain(&self, key: Key) -> bool {
        let nbits = self.words.len() as u64 * 64;
        let (h1, h2) = hash_pair(key);
        (0..self.probes as u64).all(|i| {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) % nbits;
            self.words[(bit / 64) as usize] & (1u64 << (bit % 64)) != 0
        })
    }

    /// Filter heap footprint in bytes (tests assert the memory bound).
    pub fn size_bytes(&self) -> usize {
        self.words.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: u64) -> Vec<Key> {
        (0..n).map(|i| (i % 17, (i as i64) * 13, (i as i64) * 13 + 7)).collect()
    }

    #[test]
    fn no_false_negatives() {
        let ks = keys(5_000);
        let b = Bloom::build(ks.iter().copied(), ks.len(), BLOOM_BITS_PER_KEY);
        for &k in &ks {
            assert!(b.might_contain(k), "inserted key reported absent: {k:?}");
        }
    }

    #[test]
    fn false_positive_rate_is_low_at_default_density() {
        let ks = keys(5_000);
        let b = Bloom::build(ks.iter().copied(), ks.len(), BLOOM_BITS_PER_KEY);
        let fp = (0..10_000u64)
            .map(|i| (1_000_000 + i, -(i as i64), i as i64))
            .filter(|&k| b.might_contain(k))
            .count();
        assert!(fp < 400, "~1% expected at 10 bits/key, got {fp}/10000");
    }

    #[test]
    fn degraded_filter_still_has_no_false_negatives() {
        // 1 bit/key: lots of false positives, still zero false negatives
        // — the property the exact-probe fallback relies on.
        let ks = keys(2_000);
        let b = Bloom::build(ks.iter().copied(), ks.len(), 1);
        for &k in &ks {
            assert!(b.might_contain(k));
        }
        let fp = (0..2_000u64)
            .map(|i| (7_777_777 + i, i as i64, -(i as i64)))
            .filter(|&k| b.might_contain(k))
            .count();
        assert!(fp > 100, "a 1-bit filter must actually produce false positives, got {fp}");
    }

    #[test]
    fn empty_filter_answers_and_is_tiny() {
        let b = Bloom::build(std::iter::empty(), 0, BLOOM_BITS_PER_KEY);
        assert!(b.size_bytes() <= 16);
        // An empty filter may answer either way without UB; the all-zero
        // words make it a definite miss.
        assert!(!b.might_contain((1, 2, 3)));
    }
}
