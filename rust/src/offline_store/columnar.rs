//! Immutable columnar segments — the offline store's storage unit.
//!
//! A [`Segment`] holds one sorted run of records in column-major layout
//! (the Delta-table shape of §3.1.4, scaled down): one contiguous array
//! per key column (`entity`, `event_ts`, `creation_ts`) plus a flat
//! value plane addressed through per-row offsets. Rows are ordered by
//! `(entity, event_ts, creation_ts)` — exactly the order the PIT
//! merge-join consumes — so
//!
//! * all rows of one entity form one contiguous **run** found by binary
//!   search on the entity column,
//! * within a run, rows ascend by `(event_ts, creation_ts)`, which is
//!   the PIT lookup order, and
//! * the last row of a run is the entity's Eq. 2 max-version record,
//!   making `latest_per_entity` an O(#runs) walk instead of a per-row
//!   version tournament.
//!
//! Segments are immutable after construction and shared by `Arc`:
//! readers never copy row data, and compaction (k-way [`Segment::merge`]
//! of sorted runs) builds a new segment without disturbing concurrent
//! scans of the old ones. Per-segment zone stats (min/max of every key
//! column) let scans and joins prune whole segments without touching a
//! row.

use crate::types::{EntityId, FeatureRecord, FeatureWindow, Timestamp};

/// Borrowed view of one row — the zero-clone scan currency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowView<'a> {
    pub entity: EntityId,
    pub event_ts: Timestamp,
    pub creation_ts: Timestamp,
    pub values: &'a [f32],
}

impl RowView<'_> {
    /// Materialize an owned record (only for callers that must own).
    pub fn to_record(&self) -> FeatureRecord {
        FeatureRecord::new(self.entity, self.event_ts, self.creation_ts, self.values.to_vec())
    }
}

/// Buckets in the per-segment creation-time histogram.
pub const CREATION_BUCKETS: usize = 16;

/// Min/max of each key column — segment pruning for scans and joins —
/// plus a small equi-width histogram over `creation_ts`, so `as_of`
/// readers can classify a segment as all-visible (skip the per-row
/// creation check entirely), none-visible (skip the segment), or
/// partially visible (with row-count bounds for planning) without
/// touching a row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZoneStats {
    pub min_entity: EntityId,
    pub max_entity: EntityId,
    pub min_event: Timestamp,
    pub max_event: Timestamp,
    pub min_creation: Timestamp,
    pub max_creation: Timestamp,
    /// Row counts per equi-width `creation_ts` bucket over
    /// `[min_creation, max_creation]`.
    pub creation_hist: [u32; CREATION_BUCKETS],
}

impl Default for ZoneStats {
    fn default() -> Self {
        ZoneStats {
            min_entity: 0,
            max_entity: 0,
            min_event: 0,
            max_event: 0,
            min_creation: 0,
            max_creation: 0,
            creation_hist: [0; CREATION_BUCKETS],
        }
    }
}

impl ZoneStats {
    fn creation_bucket(&self, ts: Timestamp) -> usize {
        // Width covers the inclusive span; i128 avoids overflow on wide
        // timestamp ranges.
        let span = self.max_creation as i128 - self.min_creation as i128 + 1;
        let w = (span + CREATION_BUCKETS as i128 - 1) / CREATION_BUCKETS as i128;
        (((ts as i128 - self.min_creation as i128) / w) as usize).min(CREATION_BUCKETS - 1)
    }

    /// `(lower, upper)` bounds on the number of rows with
    /// `creation_ts <= as_of`, answered from the histogram alone.
    pub fn visible_bounds(&self, as_of: Timestamp) -> (u64, u64) {
        let total: u64 = self.creation_hist.iter().map(|&c| c as u64).sum();
        if total == 0 || as_of < self.min_creation {
            return (0, 0);
        }
        if as_of >= self.max_creation {
            return (total, total);
        }
        let k = self.creation_bucket(as_of);
        let lower: u64 = self.creation_hist[..k].iter().map(|&c| c as u64).sum();
        (lower, lower + self.creation_hist[k] as u64)
    }
}

/// An immutable columnar run sorted by `(entity, event_ts, creation_ts)`.
#[derive(Debug)]
pub struct Segment {
    entities: Box<[EntityId]>,
    event_ts: Box<[Timestamp]>,
    creation_ts: Box<[Timestamp]>,
    /// Row `i`'s values live at `values[offsets[i]..offsets[i+1]]`.
    value_offsets: Box<[u32]>,
    values: Box<[f32]>,
    stats: ZoneStats,
}

impl Segment {
    /// Build from arbitrary-order rows (sorts once, at write time — the
    /// cost queries used to pay per `PitIndex::build`).
    pub fn from_unsorted(mut rows: Vec<FeatureRecord>) -> Segment {
        rows.sort_unstable_by_key(|r| (r.entity, r.event_ts, r.creation_ts));
        let total_vals = rows.iter().map(|r| r.values.len()).sum();
        let mut b = SegmentBuilder::with_capacity(rows.len(), total_vals);
        for r in &rows {
            b.push(r.entity, r.event_ts, r.creation_ts, &r.values);
        }
        b.finish()
    }

    /// K-way merge of sorted segments into one sorted segment — the
    /// compaction kernel. No re-sort: inputs are already runs.
    pub fn merge(segs: &[&Segment]) -> Segment {
        let total_rows = segs.iter().map(|s| s.len()).sum();
        let total_vals = segs.iter().map(|s| s.values.len()).sum();
        let mut b = SegmentBuilder::with_capacity(total_rows, total_vals);
        let mut cur = vec![0usize; segs.len()];
        loop {
            let mut best: Option<(usize, (EntityId, Timestamp, Timestamp))> = None;
            for (si, s) in segs.iter().enumerate() {
                let i = cur[si];
                if i < s.len() {
                    let key = (s.entities[i], s.event_ts[i], s.creation_ts[i]);
                    match best {
                        Some((_, bk)) if bk <= key => {}
                        _ => best = Some((si, key)),
                    }
                }
            }
            let Some((si, _)) = best else { break };
            let i = cur[si];
            b.push(segs[si].entities[i], segs[si].event_ts[i], segs[si].creation_ts[i], segs[si].values_of(i));
            cur[si] += 1;
        }
        b.finish()
    }

    /// Reassemble from decoded columns (the `.gfseg` load path),
    /// validating shape and sort order.
    pub(crate) fn from_columns(
        entities: Vec<EntityId>,
        event_ts: Vec<Timestamp>,
        creation_ts: Vec<Timestamp>,
        value_offsets: Vec<u32>,
        values: Vec<f32>,
    ) -> std::result::Result<Segment, String> {
        let n = entities.len();
        if event_ts.len() != n || creation_ts.len() != n {
            return Err("key columns disagree on row count".into());
        }
        if value_offsets.len() != n + 1 || value_offsets[0] != 0 {
            return Err("bad value offsets".into());
        }
        if value_offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("value offsets not monotone".into());
        }
        if *value_offsets.last().unwrap() as usize != values.len() {
            return Err("value plane length mismatch".into());
        }
        for i in 1..n {
            let prev = (entities[i - 1], event_ts[i - 1], creation_ts[i - 1]);
            let this = (entities[i], event_ts[i], creation_ts[i]);
            // Strictly increasing: equal adjacent keys would break the
            // store's uniqueness invariant (the key set dedupes, so a
            // duplicate row would be served but uncounted).
            if prev >= this {
                return Err(format!("rows out of order or duplicate at {i}"));
            }
        }
        let stats = compute_stats(&entities, &event_ts, &creation_ts);
        Ok(Segment {
            entities: entities.into_boxed_slice(),
            event_ts: event_ts.into_boxed_slice(),
            creation_ts: creation_ts.into_boxed_slice(),
            value_offsets: value_offsets.into_boxed_slice(),
            values: values.into_boxed_slice(),
            stats,
        })
    }

    pub fn len(&self) -> usize {
        self.entities.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entities.is_empty()
    }

    pub fn stats(&self) -> ZoneStats {
        self.stats
    }

    /// Column accessors (borrowed — the join reads these in place).
    pub fn entities(&self) -> &[EntityId] {
        &self.entities
    }

    pub fn event_ts(&self) -> &[Timestamp] {
        &self.event_ts
    }

    pub fn creation_ts(&self) -> &[Timestamp] {
        &self.creation_ts
    }

    /// Row `i`'s value plane slice.
    pub fn values_of(&self, i: usize) -> &[f32] {
        &self.values[self.value_offsets[i] as usize..self.value_offsets[i + 1] as usize]
    }

    pub fn row(&self, i: usize) -> RowView<'_> {
        RowView {
            entity: self.entities[i],
            event_ts: self.event_ts[i],
            creation_ts: self.creation_ts[i],
            values: self.values_of(i),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = RowView<'_>> {
        (0..self.len()).map(move |i| self.row(i))
    }

    /// Zone check: could any row's `event_ts` fall inside `window`?
    pub fn overlaps_event_window(&self, window: FeatureWindow) -> bool {
        !self.is_empty() && self.stats.min_event < window.end && self.stats.max_event >= window.start
    }

    /// Zone check: does any row version exist at `as_of`
    /// (`creation_ts <= as_of`)?
    pub fn any_visible_at(&self, as_of: Timestamp) -> bool {
        !self.is_empty() && self.stats.min_creation <= as_of
    }

    /// Zone check: is *every* row visible at `as_of`? When true, an
    /// `as_of` scan can skip the per-row creation filter for this whole
    /// segment.
    pub fn all_visible_at(&self, as_of: Timestamp) -> bool {
        !self.is_empty() && self.stats.max_creation <= as_of
    }

    /// Histogram-backed `(lower, upper)` bounds on rows visible at
    /// `as_of` — the planning statistic behind creation-time pruning.
    pub fn visible_bounds(&self, as_of: Timestamp) -> (u64, u64) {
        self.stats.visible_bounds(as_of)
    }

    /// Zone check: could `entity` be present at all?
    pub fn may_contain_entity(&self, entity: EntityId) -> bool {
        !self.is_empty() && self.stats.min_entity <= entity && entity <= self.stats.max_entity
    }

    /// The contiguous run of rows for `entity`, searched from `from`
    /// (pass a cursor when probing entities in ascending order —
    /// the merge-join's access pattern). Returns `(lo, hi)`, possibly
    /// empty.
    pub fn entity_run(&self, entity: EntityId, from: usize) -> (usize, usize) {
        let tail = &self.entities[from..];
        let lo = from + tail.partition_point(|&e| e < entity);
        let hi = from + tail.partition_point(|&e| e <= entity);
        (lo, hi)
    }

    /// Restrict a run to rows whose `event_ts` lies in `window`
    /// (within a run the event column ascends, so this is two binary
    /// searches).
    pub fn run_event_window(&self, lo: usize, hi: usize, window: FeatureWindow) -> (usize, usize) {
        let evs = &self.event_ts[lo..hi];
        (
            lo + evs.partition_point(|&t| t < window.start),
            lo + evs.partition_point(|&t| t < window.end),
        )
    }
}

fn compute_stats(entities: &[EntityId], event_ts: &[Timestamp], creation_ts: &[Timestamp]) -> ZoneStats {
    if entities.is_empty() {
        return ZoneStats::default();
    }
    let mut stats = ZoneStats {
        // Sorted by entity first, so the entity bounds are the ends.
        min_entity: entities[0],
        max_entity: entities[entities.len() - 1],
        min_event: Timestamp::MAX,
        max_event: Timestamp::MIN,
        min_creation: Timestamp::MAX,
        max_creation: Timestamp::MIN,
        creation_hist: [0; CREATION_BUCKETS],
    };
    for (&ev, &cr) in event_ts.iter().zip(creation_ts.iter()) {
        stats.min_event = stats.min_event.min(ev);
        stats.max_event = stats.max_event.max(ev);
        stats.min_creation = stats.min_creation.min(cr);
        stats.max_creation = stats.max_creation.max(cr);
    }
    // Second pass now that the creation span is known.
    for &cr in creation_ts {
        stats.creation_hist[stats.creation_bucket(cr)] += 1;
    }
    stats
}

/// Append-only builder; rows must arrive in sorted order.
pub(crate) struct SegmentBuilder {
    entities: Vec<EntityId>,
    event_ts: Vec<Timestamp>,
    creation_ts: Vec<Timestamp>,
    value_offsets: Vec<u32>,
    values: Vec<f32>,
}

impl SegmentBuilder {
    pub(crate) fn with_capacity(rows: usize, vals: usize) -> Self {
        let mut value_offsets = Vec::with_capacity(rows + 1);
        value_offsets.push(0);
        SegmentBuilder {
            entities: Vec::with_capacity(rows),
            event_ts: Vec::with_capacity(rows),
            creation_ts: Vec::with_capacity(rows),
            value_offsets,
            values: Vec::with_capacity(vals),
        }
    }

    pub(crate) fn push(&mut self, entity: EntityId, event: Timestamp, creation: Timestamp, values: &[f32]) {
        debug_assert!(
            self.entities.is_empty()
                || (*self.entities.last().unwrap(), *self.event_ts.last().unwrap(), *self.creation_ts.last().unwrap())
                    <= (entity, event, creation),
            "builder fed out of order"
        );
        self.entities.push(entity);
        self.event_ts.push(event);
        self.creation_ts.push(creation);
        self.values.extend_from_slice(values);
        assert!(self.values.len() <= u32::MAX as usize, "value plane exceeds u32 offsets");
        self.value_offsets.push(self.values.len() as u32);
    }

    pub(crate) fn finish(self) -> Segment {
        let stats = compute_stats(&self.entities, &self.event_ts, &self.creation_ts);
        Segment {
            entities: self.entities.into_boxed_slice(),
            event_ts: self.event_ts.into_boxed_slice(),
            creation_ts: self.creation_ts.into_boxed_slice(),
            value_offsets: self.value_offsets.into_boxed_slice(),
            values: self.values.into_boxed_slice(),
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, vals: &[f32]) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vals.to_vec())
    }

    #[test]
    fn from_unsorted_sorts_and_rounds_trip() {
        let rows = vec![
            rec(2, 50, 60, &[2.0]),
            rec(1, 100, 150, &[1.0, 1.5]),
            rec(1, 100, 120, &[]),
            rec(1, 30, 40, &[0.5]),
        ];
        let seg = Segment::from_unsorted(rows);
        assert_eq!(seg.len(), 4);
        let keys: Vec<_> = seg.iter().map(|r| (r.entity, r.event_ts, r.creation_ts)).collect();
        assert_eq!(keys, vec![(1, 30, 40), (1, 100, 120), (1, 100, 150), (2, 50, 60)]);
        assert_eq!(seg.values_of(2), &[1.0, 1.5]);
        assert_eq!(seg.values_of(1), &[] as &[f32]);
        assert_eq!(seg.row(3).values, &[2.0]);
    }

    #[test]
    fn zone_stats() {
        let seg = Segment::from_unsorted(vec![rec(3, -5, 10, &[0.0]), rec(7, 99, 2, &[0.0])]);
        let z = seg.stats();
        assert_eq!((z.min_entity, z.max_entity), (3, 7));
        assert_eq!((z.min_event, z.max_event), (-5, 99));
        assert_eq!((z.min_creation, z.max_creation), (2, 10));
        assert!(seg.overlaps_event_window(FeatureWindow::new(-10, 0)));
        assert!(!seg.overlaps_event_window(FeatureWindow::new(100, 200)));
        assert!(seg.overlaps_event_window(FeatureWindow::new(99, 100)));
        assert!(seg.any_visible_at(2) && !seg.any_visible_at(1));
        assert!(seg.may_contain_entity(5) && !seg.may_contain_entity(8));
    }

    #[test]
    fn empty_segment_prunes_everything() {
        let seg = Segment::from_unsorted(vec![]);
        assert!(seg.is_empty());
        assert!(!seg.overlaps_event_window(FeatureWindow::new(i64::MIN / 2, i64::MAX / 2)));
        assert!(!seg.any_visible_at(i64::MAX));
        assert!(!seg.may_contain_entity(0));
    }

    #[test]
    fn entity_runs_and_event_windows() {
        let seg = Segment::from_unsorted(vec![
            rec(1, 10, 11, &[0.0]),
            rec(1, 20, 21, &[1.0]),
            rec(1, 20, 30, &[2.0]),
            rec(5, 7, 8, &[3.0]),
        ]);
        assert_eq!(seg.entity_run(1, 0), (0, 3));
        assert_eq!(seg.entity_run(5, 3), (3, 4));
        assert_eq!(seg.entity_run(4, 0), (3, 3)); // absent: empty run
        assert_eq!(seg.entity_run(9, 0), (4, 4));
        // Window restriction inside entity 1's run.
        assert_eq!(seg.run_event_window(0, 3, FeatureWindow::new(15, 21)), (1, 3));
        assert_eq!(seg.run_event_window(0, 3, FeatureWindow::new(0, 10)), (0, 0));
    }

    #[test]
    fn kway_merge_interleaves_sorted() {
        let a = Segment::from_unsorted(vec![rec(1, 10, 11, &[1.0]), rec(3, 5, 6, &[3.0])]);
        let b = Segment::from_unsorted(vec![rec(1, 10, 9, &[0.9]), rec(2, 1, 2, &[2.0])]);
        let c = Segment::from_unsorted(vec![]);
        let m = Segment::merge(&[&a, &b, &c]);
        let keys: Vec<_> = m.iter().map(|r| (r.entity, r.event_ts, r.creation_ts)).collect();
        assert_eq!(keys, vec![(1, 10, 9), (1, 10, 11), (2, 1, 2), (3, 5, 6)]);
        assert_eq!(m.values_of(0), &[0.9]);
        assert_eq!(m.values_of(1), &[1.0]);
        assert_eq!(m.stats().max_entity, 3);
    }

    #[test]
    fn from_columns_validates() {
        assert!(Segment::from_columns(vec![1, 2], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_ok());
        // out of order
        assert!(Segment::from_columns(vec![2, 1], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_err());
        // duplicate uniqueness key
        assert!(Segment::from_columns(vec![1, 1], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_err());
        // ragged columns
        assert!(Segment::from_columns(vec![1], vec![0, 0], vec![0], vec![0, 0], vec![]).is_err());
        // offsets vs value plane
        assert!(Segment::from_columns(vec![1], vec![0], vec![0], vec![0, 2], vec![1.0]).is_err());
        assert!(Segment::from_columns(vec![1], vec![0], vec![0], vec![0, 1], vec![1.0]).is_ok());
    }

    #[test]
    fn to_record_roundtrip() {
        let r = rec(9, 1, 2, &[4.0, 5.0]);
        let seg = Segment::from_unsorted(vec![r.clone()]);
        assert_eq!(seg.row(0).to_record(), r);
    }

    #[test]
    fn creation_histogram_bounds_are_sound_and_tight_at_edges() {
        // 100 rows with creation_ts 0..100.
        let rows: Vec<FeatureRecord> =
            (0..100).map(|i| rec(i as u64, 0, i as Timestamp, &[0.0])).collect();
        let seg = Segment::from_unsorted(rows);
        assert_eq!(seg.stats().creation_hist.iter().sum::<u32>(), 100);
        // Exact at the extremes.
        assert_eq!(seg.visible_bounds(-1), (0, 0));
        assert_eq!(seg.visible_bounds(99), (100, 100));
        assert!(seg.all_visible_at(99) && !seg.all_visible_at(98));
        // Sound everywhere: lower ≤ truth ≤ upper, and the bucketed
        // uncertainty is at most one bucket's width of rows.
        for as_of in -5..110 {
            let truth = seg.iter().filter(|r| r.creation_ts <= as_of).count() as u64;
            let (lo, hi) = seg.visible_bounds(as_of);
            assert!(lo <= truth && truth <= hi, "as_of {as_of}: {lo} ≤ {truth} ≤ {hi}");
            assert!(hi - lo <= 100_u64.div_ceil(CREATION_BUCKETS as u64) + 1);
        }
    }

    #[test]
    fn creation_histogram_handles_degenerate_spans() {
        // All rows share one creation_ts (single bucket).
        let seg = Segment::from_unsorted(vec![rec(1, 0, 500, &[0.0]), rec(2, 0, 500, &[0.0])]);
        assert_eq!(seg.visible_bounds(499), (0, 0));
        assert_eq!(seg.visible_bounds(500), (2, 2));
        assert!(seg.all_visible_at(500));
        // Empty segment.
        let empty = Segment::from_unsorted(vec![]);
        assert_eq!(empty.visible_bounds(i64::MAX), (0, 0));
        assert!(!empty.all_visible_at(i64::MAX));
        // Extreme span (negative to large positive) must not overflow.
        let wide = Segment::from_unsorted(vec![
            rec(1, 0, -4_000_000_000, &[0.0]),
            rec(2, 0, 4_000_000_000, &[0.0]),
        ]);
        assert_eq!(wide.visible_bounds(0).0, 1);
        assert_eq!(wide.visible_bounds(4_000_000_000), (2, 2));
    }
}
