//! Immutable **compressed** columnar segments — the offline store's
//! storage unit.
//!
//! A [`Segment`] holds one sorted run of records in column-major layout
//! (the Delta-table shape of §3.1.4, scaled down). Rows are ordered by
//! `(entity, event_ts, creation_ts)` — exactly the order the PIT
//! merge-join consumes — so
//!
//! * all rows of one entity form one contiguous **run** found by binary
//!   search on the entity column,
//! * within a run, rows ascend by `(event_ts, creation_ts)`, which is
//!   the PIT lookup order, and
//! * the last row of a run is the entity's Eq. 2 max-version record.
//!
//! # Compression (the PR 4 rebuild)
//!
//! Training-frame scans are bandwidth-bound, so the key columns are no
//! longer raw `u64`/`i64` planes. Rows are grouped into blocks of
//! [`BLOCK_ROWS`]; each block's first key is stored verbatim in a small
//! **block directory** ([`BlockMeta`], with per-block event/creation
//! min-max for pruning) and the remaining rows are byte-coded
//! ([`super::codec`]):
//!
//! * `entity` — plain deltas (varint; non-negative under the sort),
//! * `event_ts` — **delta-of-delta** (zigzag varint; regular cadences —
//!   daily bins, hourly bins — encode as zeros),
//! * `creation_ts` — delta against the *same row's* `event_ts` (zigzag
//!   varint; creation trails event by a near-constant materialization
//!   lag, so this is the tightest correlation to exploit).
//!
//! Value planes pick the cheapest of three encodings at seal time
//! ([`ValuePlane`]): **fixed-width** (every row matches the feature-set
//! schema width — per-row offsets dropped, values addressed by
//! arithmetic), **dictionary** (low-cardinality planes store unique rows
//! once plus per-row codes), or **ragged** (raw offsets + values, the
//! v2 shape) as the fallback. All three serve `values_of` as a borrowed
//! slice — value reads stay zero-copy.
//!
//! # Lazy decode
//!
//! Readers never materialize full planes. A [`SegmentCursor`] owns a
//! one-block scratch and decodes on demand: `entity_run` binary-searches
//! the block directory first and touches exactly one block, and the
//! merge-join's ascending probes stream block to block. Each segment
//! also carries a uniqueness-key [`Bloom`] filter (built at seal/load),
//! so `merge`-side dedupe probes skip segments without decoding a row —
//! see [`super::bloom`].
//!
//! Segments are immutable after construction and shared by `Arc`:
//! compaction (k-way [`Segment::merge`] of sorted runs) builds a new
//! segment without disturbing concurrent scans of the old ones.

use crate::types::{EntityId, FeatureRecord, FeatureWindow, Timestamp};

use super::bloom::{Bloom, BLOOM_BITS_PER_KEY};
use super::codec::{get_ivarint, get_uvarint, put_ivarint, put_uvarint};

/// Borrowed view of one row — the zero-clone scan currency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowView<'a> {
    pub entity: EntityId,
    pub event_ts: Timestamp,
    pub creation_ts: Timestamp,
    pub values: &'a [f32],
}

impl RowView<'_> {
    /// Materialize an owned record (only for callers that must own).
    pub fn to_record(&self) -> FeatureRecord {
        FeatureRecord::new(self.entity, self.event_ts, self.creation_ts, self.values.to_vec())
    }
}

/// Rows per compressed key block — the decode unit. Small enough that a
/// point probe decodes microseconds of work, large enough that varint
/// runs amortize the block-directory entry.
pub const BLOCK_ROWS: usize = 256;

/// Buckets in the per-segment creation-time histogram.
pub const CREATION_BUCKETS: usize = 16;

/// Min/max of each key column — segment pruning for scans and joins —
/// plus a small equi-width histogram over `creation_ts`, so `as_of`
/// readers can classify a segment as all-visible (skip the per-row
/// creation check entirely), none-visible (skip the segment), or
/// partially visible (with row-count bounds for planning) without
/// touching a row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZoneStats {
    pub min_entity: EntityId,
    pub max_entity: EntityId,
    pub min_event: Timestamp,
    pub max_event: Timestamp,
    pub min_creation: Timestamp,
    pub max_creation: Timestamp,
    /// Row counts per equi-width `creation_ts` bucket over
    /// `[min_creation, max_creation]`.
    pub creation_hist: [u32; CREATION_BUCKETS],
}

impl Default for ZoneStats {
    fn default() -> Self {
        ZoneStats {
            min_entity: 0,
            max_entity: 0,
            min_event: 0,
            max_event: 0,
            min_creation: 0,
            max_creation: 0,
            creation_hist: [0; CREATION_BUCKETS],
        }
    }
}

impl ZoneStats {
    fn creation_bucket(&self, ts: Timestamp) -> usize {
        // Width covers the inclusive span; i128 avoids overflow on wide
        // timestamp ranges.
        let span = self.max_creation as i128 - self.min_creation as i128 + 1;
        let w = (span + CREATION_BUCKETS as i128 - 1) / CREATION_BUCKETS as i128;
        (((ts as i128 - self.min_creation as i128) / w) as usize).min(CREATION_BUCKETS - 1)
    }

    /// `(lower, upper)` bounds on the number of rows with
    /// `creation_ts <= as_of`, answered from the histogram alone.
    pub fn visible_bounds(&self, as_of: Timestamp) -> (u64, u64) {
        let total: u64 = self.creation_hist.iter().map(|&c| c as u64).sum();
        if total == 0 || as_of < self.min_creation {
            return (0, 0);
        }
        if as_of >= self.max_creation {
            return (total, total);
        }
        let k = self.creation_bucket(as_of);
        let lower: u64 = self.creation_hist[..k].iter().map(|&c| c as u64).sum();
        (lower, lower + self.creation_hist[k] as u64)
    }
}

/// Block-directory entry: the block's first key (decode seed + search
/// anchor) plus event/creation bounds for block-level pruning, and the
/// exclusive end of the block's bytes in the segment's key buffer.
#[derive(Debug, Clone, Copy)]
pub(crate) struct BlockMeta {
    pub(crate) first_entity: EntityId,
    pub(crate) first_event: Timestamp,
    pub(crate) first_creation: Timestamp,
    pub(crate) min_event: Timestamp,
    pub(crate) max_event: Timestamp,
    pub(crate) min_creation: Timestamp,
    pub(crate) max_creation: Timestamp,
    pub(crate) bytes_end: u32,
}

/// Value-plane encoding, chosen per segment at seal time. All variants
/// answer `values_of` as a borrowed slice — value reads never decode.
#[derive(Debug, Clone)]
pub(crate) enum ValuePlane {
    /// Raw per-row offsets + flat values (rows of differing widths).
    Ragged { offsets: Box<[u32]>, values: Box<[f32]> },
    /// Every row has exactly `width` values; offsets are arithmetic.
    Fixed { width: u32, values: Box<[f32]> },
    /// Low-cardinality planes: unique rows stored once, per-row codes.
    Dict { width: u32, dict: Box<[f32]>, codes: Box<[u32]> },
}

impl ValuePlane {
    pub(crate) fn of(&self, i: usize) -> &[f32] {
        match self {
            ValuePlane::Ragged { offsets, values } => {
                &values[offsets[i] as usize..offsets[i + 1] as usize]
            }
            ValuePlane::Fixed { width, values } => {
                let w = *width as usize;
                &values[i * w..(i + 1) * w]
            }
            ValuePlane::Dict { width, dict, codes } => {
                let w = *width as usize;
                let c = codes[i] as usize;
                &dict[c * w..(c + 1) * w]
            }
        }
    }

    /// Total logical values across rows (capacity hint for merges).
    pub(crate) fn logical_len(&self) -> usize {
        match self {
            ValuePlane::Ragged { values, .. } => values.len(),
            ValuePlane::Fixed { values, .. } => values.len(),
            ValuePlane::Dict { width, codes, .. } => *width as usize * codes.len(),
        }
    }

    /// Physical heap bytes of the encoding.
    pub(crate) fn size_bytes(&self) -> usize {
        match self {
            ValuePlane::Ragged { offsets, values } => offsets.len() * 4 + values.len() * 4,
            ValuePlane::Fixed { values, .. } => 8 + values.len() * 4,
            ValuePlane::Dict { dict, codes, .. } => 8 + dict.len() * 4 + codes.len() * 4,
        }
    }
}

/// Minimum rows before a dictionary encoding is even attempted.
const DICT_MIN_ROWS: usize = 16;

/// Pick the cheapest value-plane encoding for `n` rows described by raw
/// `offsets` + `values` (the v2 shape).
fn build_plane(n: usize, offsets: Vec<u32>, values: Vec<f32>) -> ValuePlane {
    if n == 0 {
        return ValuePlane::Fixed { width: 0, values: Box::new([]) };
    }
    let fixed_width = {
        let w0 = offsets[1] - offsets[0];
        offsets.windows(2).all(|p| p[1] - p[0] == w0).then_some(w0)
    };
    let Some(width) = fixed_width else {
        return ValuePlane::Ragged { offsets: offsets.into_boxed_slice(), values: values.into_boxed_slice() };
    };
    if width == 0 {
        return ValuePlane::Fixed { width: 0, values: Box::new([]) };
    }
    let w = width as usize;
    // A u32 code costs one f32 slot, so the dictionary only wins when
    // `dict_rows * w + n < n * w` — impossible at w == 1, and not worth
    // trialing below a handful of rows.
    if n >= DICT_MIN_ROWS && w >= 2 {
        // Cheap cardinality sample first: if even a small prefix is
        // mostly unique, skip the full O(n·w) dedupe trial (compaction
        // merges of high-cardinality planes would otherwise pay it on
        // every fold just to throw the dictionary away).
        let sample = n.min(256);
        let mut scratch: Vec<u32> = Vec::with_capacity(w);
        {
            let mut probe: std::collections::HashSet<Vec<u32>> =
                std::collections::HashSet::with_capacity(sample);
            for i in 0..sample {
                scratch.clear();
                scratch.extend(values[i * w..(i + 1) * w].iter().map(|v| v.to_bits()));
                if !probe.contains(&scratch) {
                    probe.insert(scratch.clone());
                }
            }
            if probe.len() * 2 > sample {
                return ValuePlane::Fixed { width, values: values.into_boxed_slice() };
            }
        }
        // Full dedupe by exact bit pattern (NaN-safe, bit-exact), with
        // an early abort the moment the dictionary can no longer win
        // even if every remaining row were a repeat.
        let mut codes: Vec<u32> = Vec::with_capacity(n);
        let mut dict: Vec<f32> = Vec::new();
        let mut seen: std::collections::HashMap<Box<[u32]>, u32> =
            std::collections::HashMap::new();
        let mut aborted = false;
        for i in 0..n {
            if seen.len() * w + n >= n * w {
                aborted = true;
                break;
            }
            let row = &values[i * w..(i + 1) * w];
            scratch.clear();
            scratch.extend(row.iter().map(|v| v.to_bits()));
            match seen.get(&scratch[..]) {
                Some(&code) => codes.push(code),
                None => {
                    let code = seen.len() as u32;
                    seen.insert(scratch.clone().into_boxed_slice(), code);
                    dict.extend_from_slice(row);
                    codes.push(code);
                }
            }
        }
        if !aborted && seen.len() * w + n < n * w {
            return ValuePlane::Dict {
                width,
                dict: dict.into_boxed_slice(),
                codes: codes.into_boxed_slice(),
            };
        }
    }
    ValuePlane::Fixed { width, values: values.into_boxed_slice() }
}

/// Encode sorted key columns into a block directory + byte buffer.
fn encode_keys(
    entities: &[EntityId],
    event_ts: &[Timestamp],
    creation_ts: &[Timestamp],
) -> (Vec<BlockMeta>, Vec<u8>) {
    let n = entities.len();
    let n_blocks = n.div_ceil(BLOCK_ROWS);
    let mut metas = Vec::with_capacity(n_blocks);
    let mut bytes = Vec::new();
    for b in 0..n_blocks {
        let start = b * BLOCK_ROWS;
        let end = ((b + 1) * BLOCK_ROWS).min(n);
        let (mut min_event, mut max_event) = (event_ts[start], event_ts[start]);
        let (mut min_creation, mut max_creation) = (creation_ts[start], creation_ts[start]);
        let mut prev_e = entities[start];
        let mut prev_ev = event_ts[start];
        let mut prev_dev: i64 = 0;
        for i in start + 1..end {
            put_uvarint(&mut bytes, entities[i].wrapping_sub(prev_e));
            let dev = event_ts[i].wrapping_sub(prev_ev);
            put_ivarint(&mut bytes, dev.wrapping_sub(prev_dev));
            put_ivarint(&mut bytes, creation_ts[i].wrapping_sub(event_ts[i]));
            prev_e = entities[i];
            prev_ev = event_ts[i];
            prev_dev = dev;
            min_event = min_event.min(event_ts[i]);
            max_event = max_event.max(event_ts[i]);
            min_creation = min_creation.min(creation_ts[i]);
            max_creation = max_creation.max(creation_ts[i]);
        }
        assert!(bytes.len() <= u32::MAX as usize, "key plane exceeds u32 offsets");
        metas.push(BlockMeta {
            first_entity: entities[start],
            first_event: event_ts[start],
            first_creation: creation_ts[start],
            min_event,
            max_event,
            min_creation,
            max_creation,
            bytes_end: bytes.len() as u32,
        });
    }
    (metas, bytes)
}

/// An immutable compressed columnar run sorted by
/// `(entity, event_ts, creation_ts)`.
#[derive(Debug)]
pub struct Segment {
    n: usize,
    blocks: Box<[BlockMeta]>,
    /// Delta/dod/lag-coded key triples, block-restarted.
    keys: Box<[u8]>,
    values: ValuePlane,
    stats: ZoneStats,
    /// Uniqueness-key filter for `merge`-side dedupe probes.
    bloom: Bloom,
}

impl Segment {
    /// Build from arbitrary-order rows (sorts once, at write time — the
    /// cost queries used to pay per `PitIndex::build`).
    pub fn from_unsorted(rows: Vec<FeatureRecord>) -> Segment {
        Self::from_unsorted_with(rows, BLOOM_BITS_PER_KEY)
    }

    /// [`Segment::from_unsorted`] with an explicit bloom density (the
    /// store's config knob; degraded densities are also how the
    /// false-positive property test forces the exact-probe path).
    pub fn from_unsorted_with(mut rows: Vec<FeatureRecord>, bloom_bits: u32) -> Segment {
        rows.sort_unstable_by_key(|r| (r.entity, r.event_ts, r.creation_ts));
        let total_vals = rows.iter().map(|r| r.values.len()).sum();
        let mut b = SegmentBuilder::with_capacity(rows.len(), total_vals);
        for r in &rows {
            b.push(r.entity, r.event_ts, r.creation_ts, &r.values);
        }
        b.finish_with(bloom_bits)
    }

    /// K-way merge of sorted segments into one sorted segment — the
    /// compaction kernel. No re-sort: inputs are already runs, streamed
    /// through per-input cursors (one decoded block per input at a time).
    pub fn merge(segs: &[&Segment]) -> Segment {
        Self::merge_with(segs, BLOOM_BITS_PER_KEY)
    }

    /// [`Segment::merge`] with an explicit bloom density.
    pub fn merge_with(segs: &[&Segment], bloom_bits: u32) -> Segment {
        let total_rows = segs.iter().map(|s| s.len()).sum();
        let total_vals = segs.iter().map(|s| s.values.logical_len()).sum();
        let mut b = SegmentBuilder::with_capacity(total_rows, total_vals);
        let mut curs: Vec<SegmentCursor<'_>> = segs.iter().map(|s| s.cursor()).collect();
        let mut pos = vec![0usize; segs.len()];
        loop {
            let mut best: Option<(usize, (EntityId, Timestamp, Timestamp))> = None;
            for (si, s) in segs.iter().enumerate() {
                let i = pos[si];
                if i < s.len() {
                    let key = curs[si].key(i);
                    match best {
                        Some((_, bk)) if bk <= key => {}
                        _ => best = Some((si, key)),
                    }
                }
            }
            let Some((si, key)) = best else { break };
            let i = pos[si];
            b.push(key.0, key.1, key.2, segs[si].values_of(i));
            pos[si] += 1;
        }
        b.finish_with(bloom_bits)
    }

    /// Reassemble from raw decoded columns (the `.gfseg` **v2** load
    /// path), validating shape and sort order, then re-encoding into the
    /// compressed in-memory form. Default bloom density; loaders that
    /// carry a store's configured density use
    /// [`Segment::from_columns_with`].
    pub(crate) fn from_columns(
        entities: Vec<EntityId>,
        event_ts: Vec<Timestamp>,
        creation_ts: Vec<Timestamp>,
        value_offsets: Vec<u32>,
        values: Vec<f32>,
    ) -> std::result::Result<Segment, String> {
        Self::from_columns_with(entities, event_ts, creation_ts, value_offsets, values, BLOOM_BITS_PER_KEY)
    }

    pub(crate) fn from_columns_with(
        entities: Vec<EntityId>,
        event_ts: Vec<Timestamp>,
        creation_ts: Vec<Timestamp>,
        value_offsets: Vec<u32>,
        values: Vec<f32>,
        bloom_bits: u32,
    ) -> std::result::Result<Segment, String> {
        let n = entities.len();
        if event_ts.len() != n || creation_ts.len() != n {
            return Err("key columns disagree on row count".into());
        }
        if value_offsets.len() != n + 1 || value_offsets[0] != 0 {
            return Err("bad value offsets".into());
        }
        if value_offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err("value offsets not monotone".into());
        }
        if *value_offsets.last().unwrap() as usize != values.len() {
            return Err("value plane length mismatch".into());
        }
        for i in 1..n {
            let prev = (entities[i - 1], event_ts[i - 1], creation_ts[i - 1]);
            let this = (entities[i], event_ts[i], creation_ts[i]);
            // Strictly increasing: equal adjacent keys would break the
            // store's uniqueness invariant (the key set dedupes, so a
            // duplicate row would be served but uncounted).
            if prev >= this {
                return Err(format!("rows out of order or duplicate at {i}"));
            }
        }
        let mut b = SegmentBuilder::with_capacity(n, values.len());
        let rows = entities.iter().zip(&event_ts).zip(&creation_ts).zip(value_offsets.windows(2));
        for (((&e, &ev), &cr), w) in rows {
            b.push(e, ev, cr, &values[w[0] as usize..w[1] as usize]);
        }
        Ok(b.finish_with(bloom_bits))
    }

    /// Reassemble from already-encoded parts (the `.gfseg` **v3** load
    /// path). Streams every block through a one-block scratch (twice:
    /// once for validation/bounds/min-max/bloom, once for the creation
    /// histogram, which needs the span first) — full key columns are
    /// never materialized, so load-time peak memory stays at
    /// encoded-size + one block, not the raw planes the format exists
    /// to avoid. Nothing in the directory is trusted beyond the anchors
    /// the decode itself is seeded from. `bloom_bits` carries the
    /// owning store's configured density through the reload.
    pub(crate) fn from_encoded(
        n: usize,
        anchors: Vec<(EntityId, Timestamp, Timestamp)>,
        bytes_ends: Vec<u32>,
        keys: Vec<u8>,
        values: ValuePlane,
        bloom_bits: u32,
    ) -> std::result::Result<Segment, String> {
        let n_blocks = n.div_ceil(BLOCK_ROWS);
        if anchors.len() != n_blocks || bytes_ends.len() != n_blocks {
            return Err("block directory disagrees with row count".into());
        }
        if bytes_ends.windows(2).any(|w| w[0] > w[1]) {
            return Err("block byte offsets not monotone".into());
        }
        if bytes_ends.last().copied().unwrap_or(0) as usize != keys.len() {
            return Err("key plane length mismatch".into());
        }
        match &values {
            ValuePlane::Ragged { offsets, values: v } => {
                if offsets.len() != n + 1
                    || offsets.first().copied().unwrap_or(1) != 0
                    || offsets.windows(2).any(|w| w[0] > w[1])
                    || *offsets.last().unwrap() as usize != v.len()
                {
                    return Err("bad ragged value plane".into());
                }
            }
            ValuePlane::Fixed { width, values: v } => {
                if v.len() != n * *width as usize {
                    return Err("bad fixed value plane".into());
                }
            }
            ValuePlane::Dict { width, dict, codes } => {
                let w = *width as usize;
                if w == 0 || codes.len() != n || dict.len() % w != 0 {
                    return Err("bad dict value plane".into());
                }
                let dict_rows = (dict.len() / w) as u32;
                if codes.iter().any(|&c| c >= dict_rows) {
                    return Err("dict code out of range".into());
                }
            }
        }
        // Provisional segment so decode_block_into can run; bounds,
        // stats and bloom are rebuilt from the validation decode below.
        let blocks: Vec<BlockMeta> = anchors
            .iter()
            .zip(&bytes_ends)
            .map(|(&(e, ev, cr), &end)| BlockMeta {
                first_entity: e,
                first_event: ev,
                first_creation: cr,
                min_event: ev,
                max_event: ev,
                min_creation: cr,
                max_creation: cr,
                bytes_end: end,
            })
            .collect();
        let mut seg = Segment {
            n,
            blocks: blocks.into_boxed_slice(),
            keys: keys.into_boxed_slice(),
            values,
            stats: ZoneStats::default(),
            // Placeholder; the sized filter is built by the pass below.
            bloom: Bloom::build(std::iter::empty(), 0, bloom_bits),
        };
        let (mut e, mut ev, mut cr) = (Vec::new(), Vec::new(), Vec::new());
        let mut metas = seg.blocks.to_vec();
        let mut prev: Option<(EntityId, Timestamp, Timestamp)> = None;
        let mut stats = ZoneStats::default();
        let mut bloom = Bloom::build(std::iter::empty(), n, bloom_bits);
        // Pass 1: validate bytes + strict order, rebuild per-block
        // bounds, fold segment min/max, and populate the bloom — one
        // block of scratch at a time.
        for (b, meta) in metas.iter_mut().enumerate() {
            seg.decode_block_into(b, &mut e, &mut ev, &mut cr)?;
            meta.min_event = *ev.iter().min().unwrap();
            meta.max_event = *ev.iter().max().unwrap();
            meta.min_creation = *cr.iter().min().unwrap();
            meta.max_creation = *cr.iter().max().unwrap();
            for ((&ke, &kev), &kcr) in e.iter().zip(ev.iter()).zip(cr.iter()) {
                let key = (ke, kev, kcr);
                if prev.is_some_and(|p| p >= key) {
                    return Err(format!("rows out of order or duplicate in block {b}"));
                }
                prev = Some(key);
                bloom.insert(key);
            }
            if b == 0 {
                stats.min_entity = e[0];
                stats.min_event = meta.min_event;
                stats.max_event = meta.max_event;
                stats.min_creation = meta.min_creation;
                stats.max_creation = meta.max_creation;
            } else {
                stats.min_event = stats.min_event.min(meta.min_event);
                stats.max_event = stats.max_event.max(meta.max_event);
                stats.min_creation = stats.min_creation.min(meta.min_creation);
                stats.max_creation = stats.max_creation.max(meta.max_creation);
            }
            // Entity-sorted: the running max is the last row seen.
            stats.max_entity = *e.last().unwrap();
        }
        seg.blocks = metas.into_boxed_slice();
        // Pass 2: creation histogram (needs the creation span from
        // pass 1) — re-decode rather than retain columns.
        if n > 0 {
            for b in 0..seg.blocks.len() {
                seg.decode_block_into(b, &mut e, &mut ev, &mut cr)?;
                for &kcr in &cr {
                    stats.creation_hist[stats.creation_bucket(kcr)] += 1;
                }
            }
        }
        seg.stats = stats;
        seg.bloom = bloom;
        Ok(seg)
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn stats(&self) -> ZoneStats {
        self.stats
    }

    /// Physical heap footprint of the encoding (key bytes + directory +
    /// value plane + bloom) — what the compression bench reports against
    /// the raw-plane equivalent.
    pub fn encoded_size_bytes(&self) -> usize {
        self.keys.len()
            + self.blocks.len() * std::mem::size_of::<BlockMeta>()
            + self.values.size_bytes()
            + self.bloom.size_bytes()
    }

    /// Bytes the v2 raw-plane layout would spend on the same rows.
    pub fn raw_size_bytes(&self) -> usize {
        self.n * (8 + 8 + 8 + 4) + 4 + self.values.logical_len() * 4
    }

    pub(crate) fn encoded_parts(&self) -> (&[BlockMeta], &[u8], &ValuePlane) {
        (&self.blocks, &self.keys, &self.values)
    }

    fn block_rows(&self, b: usize) -> (usize, usize) {
        (b * BLOCK_ROWS, ((b + 1) * BLOCK_ROWS).min(self.n))
    }

    /// Decode block `b`'s key columns into the caller's scratch.
    fn decode_block_into(
        &self,
        b: usize,
        e: &mut Vec<EntityId>,
        ev: &mut Vec<Timestamp>,
        cr: &mut Vec<Timestamp>,
    ) -> std::result::Result<(), String> {
        let meta = &self.blocks[b];
        let (start, end) = self.block_rows(b);
        let lo = if b == 0 { 0 } else { self.blocks[b - 1].bytes_end as usize };
        let bytes = &self.keys[lo..meta.bytes_end as usize];
        e.clear();
        ev.clear();
        cr.clear();
        let mut ce = meta.first_entity;
        let mut cev = meta.first_event;
        let mut ccr = meta.first_creation;
        e.push(ce);
        ev.push(cev);
        cr.push(ccr);
        let mut pos = 0usize;
        let mut prev_dev: i64 = 0;
        for _ in start + 1..end {
            let de = get_uvarint(bytes, &mut pos).ok_or_else(|| "truncated key block".to_string())?;
            let dod = get_ivarint(bytes, &mut pos).ok_or_else(|| "truncated key block".to_string())?;
            let lag = get_ivarint(bytes, &mut pos).ok_or_else(|| "truncated key block".to_string())?;
            ce = ce.wrapping_add(de);
            let dev = prev_dev.wrapping_add(dod);
            cev = cev.wrapping_add(dev);
            prev_dev = dev;
            ccr = cev.wrapping_add(lag);
            e.push(ce);
            ev.push(cev);
            cr.push(ccr);
        }
        if pos != bytes.len() {
            return Err("trailing bytes in key block".into());
        }
        Ok(())
    }

    /// A lazy key-column reader over this segment. Creation allocates
    /// nothing — the one-block scratch grows on the first real decode —
    /// so hot paths can hold a cursor per segment "just in case" (the
    /// merge loop's bloom-gated probes) without paying for segments they
    /// never touch. Ascending access patterns (entity runs, merge heads)
    /// decode each block once.
    pub fn cursor(&self) -> SegmentCursor<'_> {
        SegmentCursor {
            seg: self,
            block: usize::MAX,
            e: Vec::new(),
            ev: Vec::new(),
            cr: Vec::new(),
        }
    }

    /// Row `i`'s value plane slice (zero-copy on every encoding).
    pub fn values_of(&self, i: usize) -> &[f32] {
        self.values.of(i)
    }

    /// One decoded row. Allocates a throwaway cursor — convenience for
    /// tests and cold paths; hot paths hold a [`SegmentCursor`].
    pub fn row(&self, i: usize) -> RowView<'_> {
        let mut cur = self.cursor();
        let (entity, event_ts, creation_ts) = cur.key(i);
        RowView { entity, event_ts, creation_ts, values: self.values_of(i) }
    }

    /// Streaming row iteration (block-at-a-time decode, never a full
    /// materialized plane).
    pub fn iter(&self) -> SegmentIter<'_> {
        SegmentIter { cur: self.cursor(), i: 0 }
    }

    /// Visit rows with `event_ts` in `window` (and, when `as_of` is set,
    /// `creation_ts <= as_of`), pruning whole blocks via the block
    /// directory: blocks outside the event window or created entirely
    /// after `as_of` are skipped without decoding a byte, and blocks
    /// whose every row was already visible skip the per-row creation
    /// check.
    pub fn for_each_in<F: FnMut(RowView<'_>)>(
        &self,
        window: FeatureWindow,
        as_of: Option<Timestamp>,
        f: &mut F,
    ) {
        let mut cur = self.cursor();
        for b in 0..self.blocks.len() {
            let m = &self.blocks[b];
            if m.max_event < window.start || m.min_event >= window.end {
                continue;
            }
            let check_creation = match as_of {
                None => None,
                Some(t0) => {
                    if m.min_creation > t0 {
                        continue; // whole block created after as_of
                    }
                    (m.max_creation > t0).then_some(t0)
                }
            };
            let (start, _) = self.block_rows(b);
            cur.load(b);
            for (j, &event_ts) in cur.ev.iter().enumerate() {
                if !window.contains(event_ts) {
                    continue;
                }
                let creation_ts = cur.cr[j];
                if check_creation.is_some_and(|t0| creation_ts > t0) {
                    continue;
                }
                f(RowView {
                    entity: cur.e[j],
                    event_ts,
                    creation_ts,
                    values: self.values_of(start + j),
                });
            }
        }
    }

    /// Zone check: could any row's `event_ts` fall inside `window`?
    pub fn overlaps_event_window(&self, window: FeatureWindow) -> bool {
        !self.is_empty() && self.stats.min_event < window.end && self.stats.max_event >= window.start
    }

    /// Zone check: does any row version exist at `as_of`
    /// (`creation_ts <= as_of`)?
    pub fn any_visible_at(&self, as_of: Timestamp) -> bool {
        !self.is_empty() && self.stats.min_creation <= as_of
    }

    /// Zone check: is *every* row visible at `as_of`? When true, an
    /// `as_of` scan can skip the per-row creation filter for this whole
    /// segment.
    pub fn all_visible_at(&self, as_of: Timestamp) -> bool {
        !self.is_empty() && self.stats.max_creation <= as_of
    }

    /// Histogram-backed `(lower, upper)` bounds on rows visible at
    /// `as_of` — the planning statistic behind creation-time pruning.
    pub fn visible_bounds(&self, as_of: Timestamp) -> (u64, u64) {
        self.stats.visible_bounds(as_of)
    }

    /// Zone check: could `entity` be present at all?
    pub fn may_contain_entity(&self, entity: EntityId) -> bool {
        !self.is_empty() && self.stats.min_entity <= entity && entity <= self.stats.max_entity
    }

    /// Zone + bloom check: could this uniqueness key be present? `false`
    /// is definitive; `true` must be confirmed by
    /// [`SegmentCursor::contains`] (bloom false positives).
    pub fn may_contain_key(&self, key: (EntityId, Timestamp, Timestamp)) -> bool {
        self.may_contain_entity(key.0) && self.bloom.might_contain(key)
    }

    /// Exact membership of a uniqueness key: zone + bloom prefilter, then
    /// a binary-search probe that decodes at most one block. Cold-path
    /// convenience (allocates a cursor); the store's merge loop holds
    /// reusable probe cursors instead.
    pub fn contains_key(&self, key: (EntityId, Timestamp, Timestamp)) -> bool {
        self.may_contain_key(key) && self.cursor().contains(key)
    }
}

/// Streaming iterator over a segment's rows.
pub struct SegmentIter<'a> {
    cur: SegmentCursor<'a>,
    i: usize,
}

impl<'a> Iterator for SegmentIter<'a> {
    type Item = RowView<'a>;

    fn next(&mut self) -> Option<RowView<'a>> {
        let seg: &'a Segment = self.cur.seg;
        if self.i >= seg.len() {
            return None;
        }
        let (entity, event_ts, creation_ts) = self.cur.key(self.i);
        let values = seg.values_of(self.i);
        self.i += 1;
        Some(RowView { entity, event_ts, creation_ts, values })
    }
}

/// Lazy key-column reader: decodes one block at a time into an owned
/// scratch, so each reader thread pays for exactly the blocks it
/// touches and concurrent readers share nothing but the immutable
/// segment.
pub struct SegmentCursor<'a> {
    seg: &'a Segment,
    /// Index of the decoded block (`usize::MAX` = none yet).
    block: usize,
    e: Vec<EntityId>,
    ev: Vec<Timestamp>,
    cr: Vec<Timestamp>,
}

impl SegmentCursor<'_> {
    fn load(&mut self, b: usize) {
        if self.block != b {
            self.seg
                .decode_block_into(b, &mut self.e, &mut self.ev, &mut self.cr)
                .expect("segment validated at construction");
            self.block = b;
        }
    }

    /// Key of row `i` (decodes the containing block on first touch).
    pub fn key(&mut self, i: usize) -> (EntityId, Timestamp, Timestamp) {
        debug_assert!(i < self.seg.len(), "row {i} out of bounds ({})", self.seg.len());
        let b = i / BLOCK_ROWS;
        self.load(b);
        let j = i - b * BLOCK_ROWS;
        (self.e[j], self.ev[j], self.cr[j])
    }

    pub fn entity(&mut self, i: usize) -> EntityId {
        self.key(i).0
    }

    pub fn event(&mut self, i: usize) -> Timestamp {
        self.key(i).1
    }

    pub fn creation(&mut self, i: usize) -> Timestamp {
        self.key(i).2
    }

    /// First row index in `[from, to)` where `less(key)` turns false
    /// (`less` must be monotone over the sorted rows: true for a prefix).
    /// Two-level search: the block directory narrows to one block via
    /// its anchors, then that single block is decoded and searched — the
    /// whole probe touches O(log blocks) directory entries and one
    /// block's bytes.
    fn partition(
        &mut self,
        from: usize,
        to: usize,
        less: impl Fn(EntityId, Timestamp, Timestamp) -> bool,
    ) -> usize {
        if from >= to {
            return from;
        }
        let b_from = from / BLOCK_ROWS;
        let b_last = (to - 1) / BLOCK_ROWS;
        // Last block in (b_from, b_last] whose first row still satisfies
        // `less` — the boundary row lives there (or in b_from if none).
        let tail = &self.seg.blocks[b_from + 1..b_last + 1];
        let k = tail.partition_point(|m| less(m.first_entity, m.first_event, m.first_creation));
        let target = b_from + k;
        let (c_start, c_end) = self.seg.block_rows(target);
        self.load(target);
        let mut lo = from.max(c_start);
        let mut hi = to.min(c_end);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let j = mid - c_start;
            if less(self.e[j], self.ev[j], self.cr[j]) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// The contiguous run of rows for `entity`, searched from `from`
    /// (pass the previous run's end when probing entities in ascending
    /// order — the merge-join's access pattern). Returns `(lo, hi)`,
    /// possibly empty.
    pub fn entity_run(&mut self, entity: EntityId, from: usize) -> (usize, usize) {
        let n = self.seg.len();
        let lo = self.partition(from, n, |e, _, _| e < entity);
        let hi = self.partition(lo, n, |e, _, _| e <= entity);
        (lo, hi)
    }

    /// Restrict a run to rows whose `event_ts` lies in `window` (within
    /// a run the event column ascends, so this is two block-directory
    /// binary searches).
    pub fn run_event_window(&mut self, lo: usize, hi: usize, window: FeatureWindow) -> (usize, usize) {
        let wlo = self.partition(lo, hi, |_, ev, _| ev < window.start);
        let whi = self.partition(wlo, hi, |_, ev, _| ev < window.end);
        (wlo, whi)
    }

    /// Exact uniqueness-key membership (binary search on the full key).
    pub fn contains(&mut self, key: (EntityId, Timestamp, Timestamp)) -> bool {
        let n = self.seg.len();
        if n == 0 {
            return false;
        }
        let i = self.partition(0, n, |e, ev, cr| (e, ev, cr) < key);
        i < n && self.key(i) == key
    }
}

fn compute_stats(entities: &[EntityId], event_ts: &[Timestamp], creation_ts: &[Timestamp]) -> ZoneStats {
    if entities.is_empty() {
        return ZoneStats::default();
    }
    let mut stats = ZoneStats {
        // Sorted by entity first, so the entity bounds are the ends.
        min_entity: entities[0],
        max_entity: entities[entities.len() - 1],
        min_event: Timestamp::MAX,
        max_event: Timestamp::MIN,
        min_creation: Timestamp::MAX,
        max_creation: Timestamp::MIN,
        creation_hist: [0; CREATION_BUCKETS],
    };
    for (&ev, &cr) in event_ts.iter().zip(creation_ts.iter()) {
        stats.min_event = stats.min_event.min(ev);
        stats.max_event = stats.max_event.max(ev);
        stats.min_creation = stats.min_creation.min(cr);
        stats.max_creation = stats.max_creation.max(cr);
    }
    // Second pass now that the creation span is known.
    for &cr in creation_ts {
        stats.creation_hist[stats.creation_bucket(cr)] += 1;
    }
    stats
}

/// Append-only builder; rows must arrive in sorted order. Accumulates
/// raw columns and encodes once in `finish` (encoding needs the whole
/// segment to pick the value-plane representation).
pub(crate) struct SegmentBuilder {
    entities: Vec<EntityId>,
    event_ts: Vec<Timestamp>,
    creation_ts: Vec<Timestamp>,
    value_offsets: Vec<u32>,
    values: Vec<f32>,
}

impl SegmentBuilder {
    pub(crate) fn with_capacity(rows: usize, vals: usize) -> Self {
        let mut value_offsets = Vec::with_capacity(rows + 1);
        value_offsets.push(0);
        SegmentBuilder {
            entities: Vec::with_capacity(rows),
            event_ts: Vec::with_capacity(rows),
            creation_ts: Vec::with_capacity(rows),
            value_offsets,
            values: Vec::with_capacity(vals),
        }
    }

    pub(crate) fn push(&mut self, entity: EntityId, event: Timestamp, creation: Timestamp, values: &[f32]) {
        debug_assert!(
            self.entities.is_empty()
                || (*self.entities.last().unwrap(), *self.event_ts.last().unwrap(), *self.creation_ts.last().unwrap())
                    <= (entity, event, creation),
            "builder fed out of order"
        );
        self.entities.push(entity);
        self.event_ts.push(event);
        self.creation_ts.push(creation);
        self.values.extend_from_slice(values);
        assert!(self.values.len() <= u32::MAX as usize, "value plane exceeds u32 offsets");
        self.value_offsets.push(self.values.len() as u32);
    }

    pub(crate) fn finish(self) -> Segment {
        self.finish_with(BLOOM_BITS_PER_KEY)
    }

    pub(crate) fn finish_with(self, bloom_bits: u32) -> Segment {
        let SegmentBuilder { entities, event_ts, creation_ts, value_offsets, values } = self;
        let n = entities.len();
        let stats = compute_stats(&entities, &event_ts, &creation_ts);
        let (blocks, keys) = encode_keys(&entities, &event_ts, &creation_ts);
        let bloom = Bloom::build(
            (0..n).map(|i| (entities[i], event_ts[i], creation_ts[i])),
            n,
            bloom_bits,
        );
        let values = build_plane(n, value_offsets, values);
        Segment {
            n,
            blocks: blocks.into_boxed_slice(),
            keys: keys.into_boxed_slice(),
            values,
            stats,
            bloom,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, vals: &[f32]) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vals.to_vec())
    }

    #[test]
    fn from_unsorted_sorts_and_rounds_trip() {
        let rows = vec![
            rec(2, 50, 60, &[2.0]),
            rec(1, 100, 150, &[1.0, 1.5]),
            rec(1, 100, 120, &[]),
            rec(1, 30, 40, &[0.5]),
        ];
        let seg = Segment::from_unsorted(rows);
        assert_eq!(seg.len(), 4);
        let keys: Vec<_> = seg.iter().map(|r| (r.entity, r.event_ts, r.creation_ts)).collect();
        assert_eq!(keys, vec![(1, 30, 40), (1, 100, 120), (1, 100, 150), (2, 50, 60)]);
        assert_eq!(seg.values_of(2), &[1.0, 1.5]);
        assert_eq!(seg.values_of(1), &[] as &[f32]);
        assert_eq!(seg.row(3).values, &[2.0]);
    }

    #[test]
    fn zone_stats() {
        let seg = Segment::from_unsorted(vec![rec(3, -5, 10, &[0.0]), rec(7, 99, 2, &[0.0])]);
        let z = seg.stats();
        assert_eq!((z.min_entity, z.max_entity), (3, 7));
        assert_eq!((z.min_event, z.max_event), (-5, 99));
        assert_eq!((z.min_creation, z.max_creation), (2, 10));
        assert!(seg.overlaps_event_window(FeatureWindow::new(-10, 0)));
        assert!(!seg.overlaps_event_window(FeatureWindow::new(100, 200)));
        assert!(seg.overlaps_event_window(FeatureWindow::new(99, 100)));
        assert!(seg.any_visible_at(2) && !seg.any_visible_at(1));
        assert!(seg.may_contain_entity(5) && !seg.may_contain_entity(8));
    }

    #[test]
    fn empty_segment_prunes_everything() {
        let seg = Segment::from_unsorted(vec![]);
        assert!(seg.is_empty());
        assert!(!seg.overlaps_event_window(FeatureWindow::new(i64::MIN / 2, i64::MAX / 2)));
        assert!(!seg.any_visible_at(i64::MAX));
        assert!(!seg.may_contain_entity(0));
        assert!(!seg.contains_key((0, 0, 0)));
        assert_eq!(seg.iter().count(), 0);
    }

    #[test]
    fn entity_runs_and_event_windows() {
        let seg = Segment::from_unsorted(vec![
            rec(1, 10, 11, &[0.0]),
            rec(1, 20, 21, &[1.0]),
            rec(1, 20, 30, &[2.0]),
            rec(5, 7, 8, &[3.0]),
        ]);
        let mut cur = seg.cursor();
        assert_eq!(cur.entity_run(1, 0), (0, 3));
        assert_eq!(cur.entity_run(5, 3), (3, 4));
        assert_eq!(cur.entity_run(4, 0), (3, 3)); // absent: empty run
        assert_eq!(cur.entity_run(9, 0), (4, 4));
        // Window restriction inside entity 1's run.
        assert_eq!(cur.run_event_window(0, 3, FeatureWindow::new(15, 21)), (1, 3));
        assert_eq!(cur.run_event_window(0, 3, FeatureWindow::new(0, 10)), (0, 0));
    }

    #[test]
    fn kway_merge_interleaves_sorted() {
        let a = Segment::from_unsorted(vec![rec(1, 10, 11, &[1.0]), rec(3, 5, 6, &[3.0])]);
        let b = Segment::from_unsorted(vec![rec(1, 10, 9, &[0.9]), rec(2, 1, 2, &[2.0])]);
        let c = Segment::from_unsorted(vec![]);
        let m = Segment::merge(&[&a, &b, &c]);
        let keys: Vec<_> = m.iter().map(|r| (r.entity, r.event_ts, r.creation_ts)).collect();
        assert_eq!(keys, vec![(1, 10, 9), (1, 10, 11), (2, 1, 2), (3, 5, 6)]);
        assert_eq!(m.values_of(0), &[0.9]);
        assert_eq!(m.values_of(1), &[1.0]);
        assert_eq!(m.stats().max_entity, 3);
    }

    #[test]
    fn from_columns_validates() {
        assert!(Segment::from_columns(vec![1, 2], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_ok());
        // out of order
        assert!(Segment::from_columns(vec![2, 1], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_err());
        // duplicate uniqueness key
        assert!(Segment::from_columns(vec![1, 1], vec![0, 0], vec![0, 0], vec![0, 0, 0], vec![]).is_err());
        // ragged columns
        assert!(Segment::from_columns(vec![1], vec![0, 0], vec![0], vec![0, 0], vec![]).is_err());
        // offsets vs value plane
        assert!(Segment::from_columns(vec![1], vec![0], vec![0], vec![0, 2], vec![1.0]).is_err());
        assert!(Segment::from_columns(vec![1], vec![0], vec![0], vec![0, 1], vec![1.0]).is_ok());
    }

    #[test]
    fn to_record_roundtrip() {
        let r = rec(9, 1, 2, &[4.0, 5.0]);
        let seg = Segment::from_unsorted(vec![r.clone()]);
        assert_eq!(seg.row(0).to_record(), r);
    }

    #[test]
    fn creation_histogram_bounds_are_sound_and_tight_at_edges() {
        // 100 rows with creation_ts 0..100.
        let rows: Vec<FeatureRecord> =
            (0..100).map(|i| rec(i as u64, 0, i as Timestamp, &[0.0])).collect();
        let seg = Segment::from_unsorted(rows);
        assert_eq!(seg.stats().creation_hist.iter().sum::<u32>(), 100);
        // Exact at the extremes.
        assert_eq!(seg.visible_bounds(-1), (0, 0));
        assert_eq!(seg.visible_bounds(99), (100, 100));
        assert!(seg.all_visible_at(99) && !seg.all_visible_at(98));
        // Sound everywhere: lower ≤ truth ≤ upper, and the bucketed
        // uncertainty is at most one bucket's width of rows.
        for as_of in -5..110 {
            let truth = seg.iter().filter(|r| r.creation_ts <= as_of).count() as u64;
            let (lo, hi) = seg.visible_bounds(as_of);
            assert!(lo <= truth && truth <= hi, "as_of {as_of}: {lo} ≤ {truth} ≤ {hi}");
            assert!(hi - lo <= 100_u64.div_ceil(CREATION_BUCKETS as u64) + 1);
        }
    }

    #[test]
    fn creation_histogram_handles_degenerate_spans() {
        // All rows share one creation_ts (single bucket).
        let seg = Segment::from_unsorted(vec![rec(1, 0, 500, &[0.0]), rec(2, 0, 500, &[0.0])]);
        assert_eq!(seg.visible_bounds(499), (0, 0));
        assert_eq!(seg.visible_bounds(500), (2, 2));
        assert!(seg.all_visible_at(500));
        // Empty segment.
        let empty = Segment::from_unsorted(vec![]);
        assert_eq!(empty.visible_bounds(i64::MAX), (0, 0));
        assert!(!empty.all_visible_at(i64::MAX));
        // Extreme span (negative to large positive) must not overflow.
        let wide = Segment::from_unsorted(vec![
            rec(1, 0, -4_000_000_000, &[0.0]),
            rec(2, 0, 4_000_000_000, &[0.0]),
        ]);
        assert_eq!(wide.visible_bounds(0).0, 1);
        assert_eq!(wide.visible_bounds(4_000_000_000), (2, 2));
    }

    // ---- compression-specific coverage ----------------------------------

    /// Random rows spanning several blocks, with pathological extremes.
    fn random_rows(rng: &mut Rng, n: usize) -> Vec<FeatureRecord> {
        let mut out = Vec::with_capacity(n);
        let mut seen = std::collections::HashSet::new();
        while out.len() < n {
            let e = rng.below(40);
            let ev = rng.range(-5_000, 5_000);
            let cr = ev + rng.range(0, 3_000);
            if !seen.insert((e, ev, cr)) {
                continue;
            }
            let w = rng.below(4) as usize;
            let vals: Vec<f32> = (0..w).map(|_| rng.f32()).collect();
            out.push(FeatureRecord::new(e, ev, cr, vals));
        }
        out
    }

    #[test]
    fn multi_block_roundtrip_matches_source_rows() {
        let mut rng = Rng::new(42);
        for &n in &[1usize, 255, 256, 257, 1_000] {
            let mut rows = random_rows(&mut rng, n);
            let seg = Segment::from_unsorted(rows.clone());
            rows.sort_unstable_by_key(|r| r.unique_key());
            let got: Vec<FeatureRecord> = seg.iter().map(|r| r.to_record()).collect();
            assert_eq!(got, rows, "n={n}");
        }
    }

    #[test]
    fn cursor_matches_linear_oracle_across_blocks() {
        let mut rng = Rng::new(7);
        let rows = {
            let mut r = random_rows(&mut rng, 900);
            r.sort_unstable_by_key(|x| x.unique_key());
            r
        };
        let seg = Segment::from_unsorted(rows.clone());
        let mut cur = seg.cursor();
        // entity_run ≡ linear scan for every entity (present and absent).
        for e in 0..45u64 {
            let lo = rows.iter().position(|r| r.entity == e).unwrap_or_else(|| {
                rows.iter().take_while(|r| r.entity < e).count()
            });
            let hi = lo + rows[lo..].iter().take_while(|r| r.entity == e).count();
            assert_eq!(cur.entity_run(e, 0), (lo, hi), "entity {e}");
            // Window restriction inside the run, against the oracle.
            let w = FeatureWindow::new(-1_000, 1_000);
            let (wlo, whi) = cur.run_event_window(lo, hi, w);
            let olo = lo + rows[lo..hi].iter().take_while(|r| r.event_ts < w.start).count();
            let ohi = lo + rows[lo..hi].iter().take_while(|r| r.event_ts < w.end).count();
            assert_eq!((wlo, whi), (olo, ohi), "entity {e} window");
        }
        // Random point keys: contains ≡ set membership.
        let present: std::collections::HashSet<_> = rows.iter().map(|r| r.unique_key()).collect();
        for _ in 0..500 {
            let k = (rng.below(45), rng.range(-5_100, 5_100), rng.range(-5_100, 8_100));
            assert_eq!(cur.contains(k), present.contains(&k), "key {k:?}");
            assert_eq!(seg.contains_key(k), present.contains(&k), "key {k:?} via bloom path");
        }
    }

    #[test]
    fn fixed_width_and_dict_planes_are_chosen_and_exact() {
        // Repetitive fixed-width rows → dictionary plane.
        let rows: Vec<FeatureRecord> = (0..400)
            .map(|i| rec(i, 10 * i as i64, 10 * i as i64 + 5, &[(i % 3) as f32, 1.0]))
            .collect();
        let seg = Segment::from_unsorted(rows.clone());
        assert!(
            matches!(seg.encoded_parts().2, ValuePlane::Dict { .. }),
            "3 distinct planes over 400 rows must dictionary-encode"
        );
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(seg.values_of(i), &r.values[..]);
        }
        // High-cardinality fixed-width rows → fixed plane.
        let rows: Vec<FeatureRecord> =
            (0..400).map(|i| rec(i, i as i64, i as i64 + 1, &[i as f32, -(i as f32)])).collect();
        let seg = Segment::from_unsorted(rows.clone());
        assert!(matches!(seg.encoded_parts().2, ValuePlane::Fixed { .. }));
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(seg.values_of(i), &r.values[..]);
        }
        // Mixed widths → ragged.
        let seg = Segment::from_unsorted(vec![rec(1, 1, 2, &[1.0]), rec(2, 1, 2, &[1.0, 2.0])]);
        assert!(matches!(seg.encoded_parts().2, ValuePlane::Ragged { .. }));
    }

    #[test]
    fn regular_cadence_compresses_hard() {
        // Daily bins with constant materialization lag — the shape the
        // paper's tables actually have. Delta-of-delta + creation-lag
        // coding should crush the 28 raw bytes/row of key columns.
        let rows: Vec<FeatureRecord> = (0..2_000u64)
            .map(|i| {
                let e = i / 50; // 50 rows per entity
                let d = (i % 50) as i64;
                rec(e, d * 86_400, d * 86_400 + 600, &[1.0, 2.0, 3.0, 4.0, 5.0])
            })
            .collect();
        let seg = Segment::from_unsorted(rows);
        let encoded = seg.encoded_size_bytes();
        let raw = seg.raw_size_bytes();
        assert!(
            encoded * 2 < raw,
            "expected ≥2x compression on regular cadence: {encoded} vs {raw} bytes"
        );
    }

    #[test]
    fn block_pruned_scan_matches_filtered_iter() {
        let mut rng = Rng::new(11);
        let rows = random_rows(&mut rng, 700);
        let seg = Segment::from_unsorted(rows);
        for (w, as_of) in [
            (FeatureWindow::new(-1_000, 1_000), None),
            (FeatureWindow::new(0, 1), None),
            (FeatureWindow::new(-6_000, 6_000), Some(0)),
            (FeatureWindow::new(-6_000, 6_000), Some(-10_000)),
            (FeatureWindow::new(-6_000, 6_000), Some(10_000)),
            (FeatureWindow::new(200, 2_000), Some(500)),
        ] {
            let mut got = Vec::new();
            seg.for_each_in(w, as_of, &mut |r| got.push(r.to_record()));
            let want: Vec<FeatureRecord> = seg
                .iter()
                .filter(|r| w.contains(r.event_ts) && as_of.is_none_or(|t0| r.creation_ts <= t0))
                .map(|r| r.to_record())
                .collect();
            assert_eq!(got, want, "window {w:?} as_of {as_of:?}");
        }
    }

    #[test]
    fn extreme_timestamps_roundtrip_via_wrapping_codec() {
        let rows = vec![
            rec(0, i64::MIN / 2, i64::MIN / 2 + 1, &[0.0]),
            rec(u64::MAX, i64::MAX / 2, i64::MAX / 2 + 7, &[1.0]),
        ];
        let seg = Segment::from_unsorted(rows.clone());
        let got: Vec<FeatureRecord> = seg.iter().map(|r| r.to_record()).collect();
        assert_eq!(got, rows);
        assert!(seg.contains_key((u64::MAX, i64::MAX / 2, i64::MAX / 2 + 7)));
    }
}
