//! Byte-level integer codecs for the compressed segment format.
//!
//! Two primitives, shared by the key-column encoder in
//! [`super::columnar`] and the `.gfseg` v3 reader/writer in
//! [`super::segment`]:
//!
//! * **LEB128 varints** (`put_uvarint`/`get_uvarint`): 7 value bits per
//!   byte, continuation in the high bit — small magnitudes cost one
//!   byte, and the sorted key columns are all small magnitudes once
//!   delta-encoded.
//! * **ZigZag** (`zigzag`/`unzigzag`): folds signed deltas into small
//!   unsigned ints (`0, -1, 1, -2, …` → `0, 1, 2, 3, …`) so negative
//!   deltas (event-time resets at entity-run boundaries, late-arriving
//!   creation stamps) stay short instead of exploding to ten bytes.
//!
//! All arithmetic around these codecs is **wrapping**: an encoder that
//! wraps on a pathological delta (`i64::MIN`-ish spans) still round-trips
//! exactly, because encode and decode are inverse maps modulo 2⁶⁴ — the
//! codec never has to reject an input.

/// Append `v` as a LEB128 varint (1–10 bytes).
pub(crate) fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Decode one varint from `bytes[*pos..]`, advancing `pos`.
/// Returns `None` on truncation or a >10-byte (malformed) varint.
pub(crate) fn get_uvarint(bytes: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *bytes.get(*pos)?;
        *pos += 1;
        if shift >= 64 {
            return None; // malformed: more than 10 continuation bytes
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

/// Map a signed value to an unsigned one with small magnitudes first.
pub(crate) fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub(crate) fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append a signed value as a zigzag varint.
pub(crate) fn put_ivarint(out: &mut Vec<u8>, v: i64) {
    put_uvarint(out, zigzag(v));
}

/// Decode one zigzag varint.
pub(crate) fn get_ivarint(bytes: &[u8], pos: &mut usize) -> Option<i64> {
    get_uvarint(bytes, pos).map(unzigzag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip_and_lengths() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            buf.clear();
            put_uvarint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_uvarint(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len());
        }
        // Small values are one byte; the worst case is ten.
        buf.clear();
        put_uvarint(&mut buf, 127);
        assert_eq!(buf.len(), 1);
        buf.clear();
        put_uvarint(&mut buf, u64::MAX);
        assert_eq!(buf.len(), 10);
    }

    #[test]
    fn uvarint_rejects_truncation_and_overlong() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 1_000_000);
        let mut pos = 0;
        assert!(get_uvarint(&buf[..buf.len() - 1], &mut pos).is_none());
        // Eleven continuation bytes is malformed, not a wrap.
        let bad = vec![0x80u8; 11];
        let mut pos = 0;
        assert!(get_uvarint(&bad, &mut pos).is_none());
    }

    #[test]
    fn zigzag_orders_small_magnitudes_first() {
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
        for v in [0i64, 1, -1, 42, -42, i64::MAX, i64::MIN, i64::MIN + 1] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn ivarint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0i64, -1, 1, -300, 300, i64::MIN, i64::MAX];
        for &v in &vals {
            put_ivarint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_ivarint(&buf, &mut pos), Some(v));
        }
        assert_eq!(pos, buf.len());
    }
}
