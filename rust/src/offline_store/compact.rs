//! Background size-tiered compaction — tier merges **off** the writer's
//! merge path.
//!
//! The PR 2 store compacted inline: when a spill pushed a table past a
//! segment-count threshold, the *writer* folded every segment into one
//! under the table's write lock — the fold-everything pattern whose
//! latency grows with table history. This module replaces it:
//!
//! * [`pick_tier`] is the size-tiered picker (the STCS shape Cassandra
//!   and Chroma's compacted-block segments use, scaled down): segments
//!   are bucketed into tiers by row count (tier `t` holds segments up to
//!   `base · fanin^t` rows), and the lowest tier with ≥ `fanin` members
//!   yields its `fanin` oldest-creation members as one merge task.
//!   Merging `fanin` tier-`t` segments produces one tier-`t+1` segment,
//!   so write amplification is logarithmic in table size instead of
//!   linear.
//! * [`CompactionDriver`] is the background thread (the PR 3
//!   `FlushDriver` shape): parked on a wake channel the store pings on
//!   every delta spill, ticking at least every `period`. Each tick
//!   drains [`super::OfflineStore::compact_tick`] until no table has an
//!   eligible tier.
//!
//! **Creation-sorted tiering:** each table's segment list is kept
//! ordered by `min_creation`, and the picker only ever merges
//! creation-*adjacent* members of a tier, so compacted outputs keep
//! compact creation ranges. Time-travel readers exploit the order: a
//! `scan_as_of` binary-searches the creation-sorted segment list to cut
//! off every segment created after `as_of` wholesale, and inside a
//! partially-visible segment the block directory's creation bounds
//! classify each block as skip / all-visible / row-filter (see
//! [`super::columnar::Segment::for_each_in`]).
//!
//! Concurrency contract: the merge itself runs with **no lock held** —
//! inputs are immutable `Arc<Segment>`s cloned under a read lock; the
//! swap takes the table's write lock only to splice the output in, and
//! aborts (discarding the merged output) if any input vanished in the
//! meantime (a racing explicit `compact()` or second driver). Readers
//! never block: snapshots hold their own `Arc`s.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::columnar::Segment;
use super::OfflineStore;
use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::monitor::trace::Tracer;
use crate::util::wake::Wake;

/// Size tier of a segment: the smallest `t` with
/// `rows ≤ base · fanin^t` (saturating — gigantic segments share the
/// top tier instead of overflowing).
pub(crate) fn tier_of(rows: usize, base: usize, fanin: usize) -> u32 {
    let mut cap = base.max(1) as u64;
    let fanin = fanin.max(2) as u64;
    let rows = rows as u64;
    let mut t = 0u32;
    while rows > cap {
        cap = cap.saturating_mul(fanin);
        t += 1;
        if cap == u64::MAX {
            break;
        }
    }
    t
}

/// Pick one tier merge over per-segment **row counts**: the `fanin`
/// creation-adjacent member indices of the lowest over-full tier.
/// `None` when no tier is over-full. This arithmetic core is shared by
/// the real picker below and the backlog estimator
/// (`OfflineStore::compaction_backlog`), which simulates folds on the
/// count list without touching any segment.
pub(crate) fn pick_tier_rows(
    rows: &[usize],
    base: usize,
    fanin: usize,
) -> Option<(u32, Vec<usize>)> {
    let fanin = fanin.max(2);
    if rows.len() < fanin {
        return None;
    }
    // tier → creation-ordered member indices.
    let mut tiers: std::collections::BTreeMap<u32, Vec<usize>> = std::collections::BTreeMap::new();
    for (i, &r) in rows.iter().enumerate() {
        tiers.entry(tier_of(r, base, fanin)).or_default().push(i);
    }
    for (&tier, members) in tiers.iter() {
        if members.len() >= fanin {
            return Some((tier, members[..fanin].to_vec()));
        }
    }
    None
}

/// Pick one tier merge: the `fanin` creation-adjacent members of the
/// lowest over-full tier (the segment list is creation-sorted, so tier
/// members are visited — and therefore merged — in creation order).
/// Returns the tier merged from, for the per-tier merge counters.
/// `None` when no tier is over-full.
pub(crate) fn pick_tier(
    segments: &[Arc<Segment>],
    base: usize,
    fanin: usize,
) -> Option<(u32, Vec<Arc<Segment>>)> {
    let rows: Vec<usize> = segments.iter().map(|s| s.len()).collect();
    let (tier, idxs) = pick_tier_rows(&rows, base, fanin)?;
    Some((tier, idxs.into_iter().map(|i| segments[i].clone()).collect()))
}

/// Background compaction thread bound to one store. Dropping the driver
/// stops the thread (after its current merge, if any).
pub struct CompactionDriver {
    stop: Arc<AtomicBool>,
    wake: Arc<Wake>,
    merges: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CompactionDriver {
    /// Spawn the driver: woken by every delta spill, ticking at least
    /// every `period`, each tick running tier merges until no table has
    /// an over-full tier.
    pub fn spawn(store: Arc<OfflineStore>, period: Duration) -> CompactionDriver {
        Self::spawn_with(store, period, None)
    }

    /// [`CompactionDriver::spawn`] with observability: each merge bumps
    /// `compaction_merges_total` and a `compaction_merges_tier{t}`
    /// counter for the tier it folded, and every tick refreshes the
    /// `compaction_backlog` gauge (tier merges currently pending across
    /// all tables — 0 once the driver has drained the store's shape).
    pub fn spawn_with(
        store: Arc<OfflineStore>,
        period: Duration,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> CompactionDriver {
        Self::spawn_observed(store, period, metrics, None)
    }

    /// [`CompactionDriver::spawn_with`] plus request tracing: each wake
    /// round that merged anything publishes a sampled trace of the tiers
    /// folded and the backlog left behind.
    pub fn spawn_observed(
        store: Arc<OfflineStore>,
        period: Duration,
        metrics: Option<Arc<MetricsRegistry>>,
        tracer: Option<Arc<Tracer>>,
    ) -> CompactionDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let merges = Arc::new(AtomicU64::new(0));
        let wake = store.compaction_wake();
        let stop2 = stop.clone();
        let merges2 = merges.clone();
        let wake2 = wake.clone();
        let handle = std::thread::Builder::new()
            .name("geofs-compactor".into())
            .spawn(move || {
                let mut seen = 0u64;
                loop {
                    if stop2.load(Ordering::Acquire) {
                        return;
                    }
                    seen = wake2.wait(seen, period);
                    let trace = tracer.as_ref().and_then(|t| t.maybe_trace("compaction_tick"));
                    let mut round_merges = 0u64;
                    loop {
                        let tiers = store.compact_tick_tiers();
                        merges2.fetch_add(tiers.len() as u64, Ordering::Relaxed);
                        round_merges += tiers.len() as u64;
                        if let Some(m) = &metrics {
                            if !tiers.is_empty() {
                                m.inc(
                                    MetricKind::System,
                                    names::COMPACTION_MERGES_TOTAL,
                                    tiers.len() as u64,
                                );
                                for t in &tiers {
                                    m.inc(
                                        MetricKind::System,
                                        &names::compaction_merges_tier(*t as usize),
                                        1,
                                    );
                                }
                            }
                        }
                        if let Some(t) = &trace {
                            if !tiers.is_empty() {
                                t.event("merge", format!("tiers={tiers:?}"));
                            }
                        }
                        if tiers.is_empty() || stop2.load(Ordering::Acquire) {
                            break;
                        }
                    }
                    let backlog = store.compaction_backlog();
                    if let Some(m) = &metrics {
                        m.set_gauge(
                            MetricKind::System,
                            names::COMPACTION_BACKLOG,
                            backlog as f64,
                        );
                    }
                    if let Some(t) = &trace {
                        t.event("drained", format!("merges={round_merges} backlog={backlog}"));
                        t.finish();
                    }
                }
            })
            .expect("spawn compaction driver");
        CompactionDriver { stop, wake, merges, handle: Some(handle) }
    }

    /// Tier merges performed since spawn (test/metrics hook).
    pub fn merges(&self) -> u64 {
        self.merges.load(Ordering::Relaxed)
    }
}

impl Drop for CompactionDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.wake.ping();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::FeatureRecord;

    fn seg_at(rows: usize, cr0: i64) -> Arc<Segment> {
        Arc::new(Segment::from_unsorted(
            (0..rows).map(|i| FeatureRecord::new(i as u64, 0, cr0 + i as i64, vec![0.0])).collect(),
        ))
    }

    fn seg(rows: usize) -> Arc<Segment> {
        seg_at(rows, 0)
    }

    #[test]
    fn tiers_grow_geometrically() {
        assert_eq!(tier_of(1, 100, 4), 0);
        assert_eq!(tier_of(100, 100, 4), 0);
        assert_eq!(tier_of(101, 100, 4), 1);
        assert_eq!(tier_of(400, 100, 4), 1);
        assert_eq!(tier_of(401, 100, 4), 2);
        let _ = tier_of(usize::MAX, 100, 4); // saturates, no panic
        assert_eq!(tier_of(7, 0, 0), tier_of(7, 1, 2)); // degenerate knobs clamp
    }

    #[test]
    fn picks_lowest_overfull_tier_in_creation_order() {
        // Three tier-0 segments (≤4 rows) + one big one; fanin 3.
        let segs = vec![seg(2), seg(3), seg(4), seg(400)];
        let (tier, picked) = pick_tier(&segs, 4, 3).expect("tier 0 over-full");
        assert_eq!(tier, 0);
        assert_eq!(picked.len(), 3);
        for (p, s) in picked.iter().zip(&segs[..3]) {
            assert!(Arc::ptr_eq(p, s), "must take the first (creation-adjacent) members");
        }
        // Under-full: nothing to do.
        assert!(pick_tier(&segs[..2], 4, 3).is_none());
        assert!(pick_tier(&[seg(400), seg(2)], 4, 3).is_none());
    }

    #[test]
    fn merged_output_climbs_a_tier() {
        // fanin tier-0 segments merge into one tier-1 segment, so the
        // picker cannot loop on its own output.
        let base = 4;
        let fanin = 4;
        let segs: Vec<Arc<Segment>> = (0..4).map(|k| seg_at(4, k * 100)).collect();
        let (_, picked) = pick_tier(&segs, base, fanin).unwrap();
        let refs: Vec<&Segment> = picked.iter().map(|s| s.as_ref()).collect();
        let merged = Segment::merge(&refs);
        assert!(tier_of(merged.len(), base, fanin) >= 1);
    }

    #[test]
    fn pick_tier_rows_simulates_backlog_to_exhaustion() {
        // Six tier-0 counts, fanin 4: one pickable merge now; folding it
        // leaves 2 + 1 merged — under-full, so the simulated backlog is
        // exactly 1 (what the backlog gauge reports).
        let mut rows = vec![4usize, 4, 4, 4, 4, 4];
        let mut pending = 0;
        while let Some((_, idxs)) = pick_tier_rows(&rows, 4, 4) {
            let merged: usize = idxs.iter().map(|&i| rows[i]).sum();
            for &i in idxs.iter().rev() {
                rows.remove(i);
            }
            rows.push(merged);
            pending += 1;
        }
        assert_eq!(pending, 1);
        assert!(pick_tier_rows(&[4, 4], 4, 4).is_none());
    }
}
