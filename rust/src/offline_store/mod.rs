//! Offline store (§3.1.4): big-data sink with high-throughput retrieval.
//!
//! The paper materializes feature-set tables into ADLS gen2 as Delta
//! tables; here the equivalent substrate is an append-only, day-
//! partitioned segment store with the same contract:
//!
//! * Alg 2 (offline branch): insert iff the `(IDs, event_ts, creation_ts)`
//!   uniqueness key is absent, else no-op — merges are idempotent.
//! * Keeps **every** record version over time (Eq. 1), enabling
//!   point-in-time reads and time travel on `creation_ts`.
//! * Partition pruning on the event-time day for range scans.
//! * Durable persistence with checksums (`persist`/`load`).

pub mod segment;

use std::collections::{BTreeMap, HashMap};
use std::sync::RwLock;

use crate::types::time::DAY;
use crate::types::{EntityId, FeatureRecord, FeatureWindow, FsError, Result, Timestamp};

pub use segment::{load_table, persist_table};

/// Merge accounting (fed into monitoring).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MergeStats {
    pub inserted: u64,
    pub skipped: u64,
}

impl MergeStats {
    pub fn add(&mut self, other: MergeStats) {
        self.inserted += other.inserted;
        self.skipped += other.skipped;
    }
}

/// One feature-set table: day partitions + uniqueness index.
#[derive(Debug, Default)]
pub(crate) struct Table {
    /// day index (event_ts div DAY) → records in that partition.
    pub(crate) partitions: BTreeMap<i64, Vec<FeatureRecord>>,
    /// Uniqueness keys (§4.5.1).
    keys: std::collections::HashSet<(EntityId, Timestamp, Timestamp)>,
    pub(crate) rows: u64,
}

impl Table {
    fn merge(&mut self, records: &[FeatureRecord]) -> MergeStats {
        let mut stats = MergeStats::default();
        for r in records {
            if self.keys.insert(r.unique_key()) {
                self.partitions.entry(r.event_ts.div_euclid(DAY)).or_default().push(r.clone());
                self.rows += 1;
                stats.inserted += 1;
            } else {
                stats.skipped += 1;
            }
        }
        stats
    }

    fn scan(&self, window: FeatureWindow, as_of: Option<Timestamp>) -> Vec<FeatureRecord> {
        let day_lo = window.start.div_euclid(DAY);
        let day_hi = window.end.div_euclid(DAY); // inclusive: end may sit inside this day
        let mut out = Vec::new();
        for (_, part) in self.partitions.range(day_lo..=day_hi) {
            for r in part {
                if window.contains(r.event_ts) && as_of.map_or(true, |t| r.creation_ts <= t) {
                    out.push(r.clone());
                }
            }
        }
        out
    }
}

/// The offline store: many feature-set tables.
#[derive(Debug, Default)]
pub struct OfflineStore {
    tables: RwLock<HashMap<String, Table>>,
}

impl OfflineStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Alg 2 offline merge: idempotent insert of new record versions.
    pub fn merge(&self, table: &str, records: &[FeatureRecord]) -> MergeStats {
        let mut g = self.tables.write().unwrap();
        g.entry(table.to_string()).or_default().merge(records)
    }

    /// All records with `event_ts` in `window` (every version — Eq. 1).
    pub fn scan(&self, table: &str, window: FeatureWindow) -> Vec<FeatureRecord> {
        self.tables
            .read()
            .unwrap()
            .get(table)
            .map(|t| t.scan(window, None))
            .unwrap_or_default()
    }

    /// Time travel: only record versions that existed at `as_of`
    /// (creation_ts ≤ as_of). This is what the PIT training query uses so
    /// training reproduces what inference would have seen.
    pub fn scan_as_of(&self, table: &str, window: FeatureWindow, as_of: Timestamp) -> Vec<FeatureRecord> {
        self.tables
            .read()
            .unwrap()
            .get(table)
            .map(|t| t.scan(window, Some(as_of)))
            .unwrap_or_default()
    }

    /// Latest record per entity by `(event_ts, creation_ts)` — the
    /// offline→online bootstrap read (§4.5.5).
    pub fn latest_per_entity(&self, table: &str) -> Vec<FeatureRecord> {
        let g = self.tables.read().unwrap();
        let Some(t) = g.get(table) else { return Vec::new() };
        let mut best: HashMap<EntityId, FeatureRecord> = HashMap::new();
        for part in t.partitions.values() {
            for r in part {
                match best.get(&r.entity) {
                    Some(b) if b.version() >= r.version() => {}
                    _ => {
                        best.insert(r.entity, r.clone());
                    }
                }
            }
        }
        let mut out: Vec<_> = best.into_values().collect();
        out.sort_by_key(|r| r.entity);
        out
    }

    pub fn row_count(&self, table: &str) -> u64 {
        self.tables.read().unwrap().get(table).map(|t| t.rows).unwrap_or(0)
    }

    pub fn tables(&self) -> Vec<String> {
        self.tables.read().unwrap().keys().cloned().collect()
    }

    /// Event-time coverage `[min, max_event_ts]` of a table, if nonempty.
    pub fn event_range(&self, table: &str) -> Option<(Timestamp, Timestamp)> {
        let g = self.tables.read().unwrap();
        let t = g.get(table)?;
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for part in t.partitions.values() {
            for r in part {
                lo = lo.min(r.event_ts);
                hi = hi.max(r.event_ts);
            }
        }
        (lo <= hi).then_some((lo, hi))
    }

    /// Persist all tables under `dir` (one file per table).
    pub fn persist(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let g = self.tables.read().unwrap();
        for (name, table) in g.iter() {
            let rows: Vec<&FeatureRecord> = table.partitions.values().flatten().collect();
            segment::persist_table(&dir.join(format!("{name}.gfseg")), &rows)?;
        }
        Ok(())
    }

    /// Load tables persisted by [`OfflineStore::persist`].
    pub fn load(dir: &std::path::Path) -> Result<OfflineStore> {
        let store = OfflineStore::new();
        if !dir.exists() {
            return Ok(store);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("gfseg") {
                continue;
            }
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| FsError::Other(format!("bad segment file {path:?}")))?
                .to_string();
            let rows = segment::load_table(&path)?;
            store.merge(&name, &rows);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: EntityId, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn merge_is_idempotent() {
        let s = OfflineStore::new();
        let rows = vec![rec(1, 100, 200, 1.0), rec(2, 100, 200, 2.0)];
        let m1 = s.merge("t", &rows);
        assert_eq!(m1, MergeStats { inserted: 2, skipped: 0 });
        let m2 = s.merge("t", &rows);
        assert_eq!(m2, MergeStats { inserted: 0, skipped: 2 });
        assert_eq!(s.row_count("t"), 2);
    }

    #[test]
    fn keeps_every_version_eq1() {
        let s = OfflineStore::new();
        // Same entity+event_ts, three creation timestamps (job retries /
        // late recomputes) — all kept (Eq. 1).
        s.merge("t", &[rec(1, 100, 200, 1.0), rec(1, 100, 300, 1.1), rec(1, 100, 400, 1.2)]);
        assert_eq!(s.row_count("t"), 3);
        assert_eq!(s.scan("t", FeatureWindow::new(0, 1_000)).len(), 3);
    }

    #[test]
    fn scan_respects_window_half_open() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 100, 200, 0.0), rec(1, 200, 300, 0.0), rec(1, 300, 400, 0.0)]);
        let got = s.scan("t", FeatureWindow::new(100, 300));
        let evs: Vec<_> = got.iter().map(|r| r.event_ts).collect();
        assert_eq!(evs.len(), 2);
        assert!(evs.contains(&100) && evs.contains(&200));
    }

    #[test]
    fn scan_prunes_partitions_across_days() {
        let s = OfflineStore::new();
        for d in 0..30 {
            s.merge("t", &[rec(1, d * DAY + 10, d * DAY + 20, d as f32)]);
        }
        let got = s.scan("t", FeatureWindow::new(10 * DAY, 12 * DAY));
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn time_travel_as_of() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 100, 150, 1.0), rec(1, 100, 500, 2.0)]);
        let w = FeatureWindow::new(0, 1_000);
        assert_eq!(s.scan_as_of("t", w, 200).len(), 1);
        assert_eq!(s.scan_as_of("t", w, 100).len(), 0);
        assert_eq!(s.scan_as_of("t", w, 500).len(), 2);
    }

    #[test]
    fn latest_per_entity_matches_eq2() {
        let s = OfflineStore::new();
        // Fig 5's records: R1={t1,t1'}, R3={t1,t3'} late-arriving;
        // R2={t2,t2'} has the max event_ts → R2 is the latest.
        s.merge(
            "t",
            &[rec(1, 10, 11, 0.0), rec(1, 20, 21, 1.0), rec(1, 30, 31, 2.0), rec(1, 20, 99, 3.0)],
        );
        let latest = s.latest_per_entity("t");
        assert_eq!(latest.len(), 1);
        assert_eq!(latest[0].event_ts, 30);
        assert_eq!(latest[0].creation_ts, 31);
    }

    #[test]
    fn latest_per_entity_tie_breaks_on_creation() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 10, 11, 0.0), rec(1, 10, 50, 1.0)]);
        let latest = s.latest_per_entity("t");
        assert_eq!(latest[0].creation_ts, 50);
    }

    #[test]
    fn event_range() {
        let s = OfflineStore::new();
        assert_eq!(s.event_range("t"), None);
        s.merge("t", &[rec(1, 100, 150, 0.0), rec(2, 900, 950, 0.0)]);
        assert_eq!(s.event_range("t"), Some((100, 900)));
    }

    #[test]
    fn negative_event_ts_partitions() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, -100, 0, 0.0)]);
        assert_eq!(s.scan("t", FeatureWindow::new(-DAY, 0)).len(), 1);
    }

    #[test]
    fn persist_and_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("geofs-off-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = OfflineStore::new();
        s.merge("alpha", &[rec(1, 100, 150, 1.5), rec(2, 200, 250, -2.5)]);
        s.merge("beta", &[rec(3, 300, 350, 0.25)]);
        s.persist(&dir).unwrap();

        let loaded = OfflineStore::load(&dir).unwrap();
        assert_eq!(loaded.row_count("alpha"), 2);
        assert_eq!(loaded.row_count("beta"), 1);
        let got = loaded.scan("alpha", FeatureWindow::new(0, 1_000));
        assert!(got.iter().any(|r| r.values[0] == 1.5));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
