//! Offline store (§3.1.4): big-data sink with high-throughput retrieval.
//!
//! The paper materializes feature-set tables into ADLS gen2 as Delta
//! tables; here the equivalent substrate is a compressed columnar
//! segment store with the same contract:
//!
//! * Alg 2 (offline branch): insert iff the `(IDs, event_ts, creation_ts)`
//!   uniqueness key is absent, else no-op — merges are idempotent.
//! * Keeps **every** record version over time (Eq. 1), enabling
//!   point-in-time reads and time travel on `creation_ts`.
//! * Zone-stat pruning (per-segment min/max of each key column, plus
//!   per-block bounds inside each segment) for range scans — the
//!   columnar analogue of day-partition pruning.
//! * Durable persistence with checksums (`persist`/`load`).
//!
//! # Storage layout (the PR 4 rebuild)
//!
//! Each table is a set of immutable, `(entity, event_ts, creation_ts)`-
//! sorted **compressed** [`columnar::Segment`]s plus a small
//! row-oriented **delta buffer** of recent merges:
//!
//! * **Writes** append accepted records to the delta; when it reaches
//!   the spill threshold it is sorted once and sealed into a new
//!   segment (delta/dod varint key columns, dictionary/fixed value
//!   planes, a uniqueness-key bloom — see [`columnar`]). The writer
//!   **never compacts inline**: segment folding is the
//!   [`compact::CompactionDriver`]'s job (size-tiered, off the merge
//!   path), so `merge` latency is independent of segment count.
//! * **Dedupe memory is bounded** (the old per-table all-keys `HashSet`
//!   is gone): only the unsealed delta keeps exact keys; sealed
//!   segments answer membership via their bloom filter with an exact
//!   binary-search probe on bloom hits — false positives cost one block
//!   decode, never a lost insert (property-tested with degraded
//!   filters in `tests/offline_stress.rs`).
//! * **Reads** either visit rows in place ([`OfflineStore::for_each_in_window`],
//!   zero clones, block-pruned) or take a [`OfflineStore::snapshot`] —
//!   `Arc`-shared segments plus the delta sealed into a mini-segment —
//!   which the PIT merge-join consumes through lazy
//!   [`columnar::SegmentCursor`]s without materializing a plane.
//! * **Creation-sorted tiering:** the segment list is ordered by
//!   `min_creation` and compaction merges creation-adjacent tier
//!   members, so a time-travel scan binary-searches the list to drop
//!   every segment created after `as_of`, and partially-visible
//!   segments classify whole blocks (skip / all-visible / row-filter)
//!   from the block directory instead of row-filtering the segment.
//! * **Locking** is per table: a `RwLock` map resolves the table name to
//!   an `Arc<Table>` (held only for the lookup), and each table has its
//!   own `RwLock`. Compaction merges run with no lock held (immutable
//!   `Arc` inputs) and splice results in under a brief write lock.
//! * [`OfflineStore::latest_per_entity`] (§4.5.5 bootstrap) exploits the
//!   sort order: the last row of each entity run is that segment's
//!   Eq. 2 max, so the scan is a cursor run-walk plus a cross-segment
//!   max — no per-row version tournament and no full-table clone.

pub mod bloom;
pub(crate) mod codec;
pub mod columnar;
pub mod compact;
pub mod segment;

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, RwLock};

use crate::types::{EntityId, FeatureRecord, FeatureWindow, FsError, Result, Timestamp};
use crate::util::wake::Wake;

pub use bloom::{Bloom, BLOOM_BITS_PER_KEY};
pub use columnar::{RowView, Segment, SegmentCursor, ZoneStats, BLOCK_ROWS, CREATION_BUCKETS};
pub use compact::CompactionDriver;
pub use segment::{
    load_segment, load_segment_with, load_table, persist_segment, persist_segment_to,
    persist_segment_v2, persist_table,
};

/// Delta rows that trigger a spill into a sorted segment.
const DEFAULT_SPILL_ROWS: usize = 1024;

/// Store tuning knobs (all have production defaults; tests shrink them
/// to force constant spill/compaction/bloom-probe churn).
#[derive(Debug, Clone, Copy)]
pub struct StoreConfig {
    /// Delta rows that trigger a spill into a sealed segment.
    pub spill_rows: usize,
    /// Segments per size tier that make the tier eligible for a
    /// background merge (also the tier growth ratio).
    pub tier_fanin: usize,
    /// Bloom density for sealed-segment uniqueness filters. Lower values
    /// trade false-positive probes for memory; correctness is unaffected
    /// (hits are always confirmed exactly).
    pub bloom_bits_per_key: u32,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            spill_rows: DEFAULT_SPILL_ROWS,
            tier_fanin: 4,
            bloom_bits_per_key: BLOOM_BITS_PER_KEY,
        }
    }
}

/// Merge accounting (fed into monitoring).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MergeStats {
    pub inserted: u64,
    pub skipped: u64,
}

impl MergeStats {
    pub fn add(&mut self, other: MergeStats) {
        self.inserted += other.inserted;
        self.skipped += other.skipped;
    }
}

/// Batch size from which the sorted dedupe probe pays for its sort:
/// below this, per-record probes win.
const SORTED_PROBE_MIN: usize = 8;

/// One feature-set table: sealed segments + delta + bounded dedupe
/// state.
#[derive(Debug, Default)]
struct TableInner {
    /// Immutable sorted runs, shared with in-flight snapshots, ordered
    /// by `min_creation` (creation-sorted tiering).
    segments: Vec<Arc<Segment>>,
    /// Recent merges, not yet sealed (bounded by the spill threshold).
    delta: Vec<FeatureRecord>,
    /// Exact uniqueness keys of the **delta only** (§4.5.1). Sealed
    /// segments answer membership via bloom + exact probe, so dedupe
    /// memory is bounded by the spill threshold, not table history.
    delta_keys: HashSet<(EntityId, Timestamp, Timestamp)>,
    rows: u64,
}

impl TableInner {
    /// Returns the merge stats and whether a spill happened (the store
    /// pings the compaction driver on spills).
    fn merge(&mut self, records: &[FeatureRecord], cfg: &StoreConfig) -> (MergeStats, bool) {
        let stats = if records.len() >= SORTED_PROBE_MIN && !self.segments.is_empty() {
            self.merge_sorted(records)
        } else {
            self.merge_pointwise(records)
        };
        let mut spilled = false;
        if self.delta.len() >= cfg.spill_rows {
            self.spill_delta(cfg);
            spilled = true;
        }
        (stats, spilled)
    }

    /// Per-record dedupe probe — small batches, where sorting overhead
    /// would dominate the saved block decodes.
    fn merge_pointwise(&mut self, records: &[FeatureRecord]) -> MergeStats {
        let mut stats = MergeStats::default();
        // One reusable probe cursor per sealed segment: consecutive
        // records often hash into the same blocks, and the cursors'
        // scratch is allocated once per merge call, not per probe.
        let mut probes: Vec<SegmentCursor<'_>> =
            self.segments.iter().map(|s| s.cursor()).collect();
        for r in records {
            let key = r.unique_key();
            let dup = self.delta_keys.contains(&key)
                || self
                    .segments
                    .iter()
                    .zip(probes.iter_mut())
                    .any(|(s, c)| s.may_contain_key(key) && c.contains(key));
            if dup {
                stats.skipped += 1;
            } else {
                self.delta_keys.insert(key);
                self.delta.push(r.clone());
                self.rows += 1;
                stats.inserted += 1;
            }
        }
        stats
    }

    /// Sorted-batch dedupe probe: sort the batch's keys once, then walk
    /// each sealed segment in ascending key order — one `entity_run`
    /// binary search per entity (with a monotone `from` hint, so the
    /// directory walk never restarts) and a two-pointer scan inside the
    /// run, instead of an independent `contains` probe per record. Each
    /// segment block is decoded at most once per merge call however
    /// many records land in it, which is what amortizes bulk re-merge
    /// (backfill replay, failover log replay) over big batches.
    /// Classification is identical to [`Self::merge_pointwise`]: among
    /// in-batch duplicates of one key the **first arrival** wins, and
    /// inserts land in arrival order.
    fn merge_sorted(&mut self, records: &[FeatureRecord]) -> MergeStats {
        let mut order: Vec<usize> = (0..records.len()).collect();
        // Sort by (key, arrival index): duplicate keys may carry
        // different values, and pointwise application keeps the first.
        order.sort_unstable_by_key(|&i| (records[i].unique_key(), i));
        let mut dup = vec![false; records.len()];
        for w in order.windows(2) {
            if records[w[0]].unique_key() == records[w[1]].unique_key() {
                dup[w[1]] = true;
            }
        }
        for &i in &order {
            if !dup[i] && self.delta_keys.contains(&records[i].unique_key()) {
                dup[i] = true;
            }
        }
        for seg in &self.segments {
            let mut cur = seg.cursor();
            let mut pos = 0usize; // keys ascend over `order` → monotone hint
            let mut k = 0usize;
            while k < order.len() {
                let entity = records[order[k]].entity;
                let mut k_end = k + 1;
                while k_end < order.len() && records[order[k_end]].entity == entity {
                    k_end += 1;
                }
                let group = &order[k..k_end];
                k = k_end;
                if !seg.may_contain_entity(entity)
                    || !group
                        .iter()
                        .any(|&i| !dup[i] && seg.may_contain_key(records[i].unique_key()))
                {
                    continue;
                }
                let (lo, hi) = cur.entity_run(entity, pos);
                pos = hi;
                let mut row = lo;
                for &i in group {
                    if dup[i] || !seg.may_contain_key(records[i].unique_key()) {
                        continue;
                    }
                    let key = records[i].unique_key();
                    while row < hi && cur.key(row) < key {
                        row += 1;
                    }
                    if row < hi && cur.key(row) == key {
                        dup[i] = true;
                    }
                }
            }
        }
        let mut stats = MergeStats::default();
        for (i, r) in records.iter().enumerate() {
            if dup[i] {
                stats.skipped += 1;
            } else {
                self.delta_keys.insert(r.unique_key());
                self.delta.push(r.clone());
                self.rows += 1;
                stats.inserted += 1;
            }
        }
        stats
    }

    /// Seal the delta into a sorted segment (one sort, at write time).
    /// No inline compaction — constant work regardless of segment count.
    fn spill_delta(&mut self, cfg: &StoreConfig) {
        if self.delta.is_empty() {
            return;
        }
        let rows = std::mem::take(&mut self.delta);
        self.delta_keys.clear();
        self.segments
            .push(Arc::new(Segment::from_unsorted_with(rows, cfg.bloom_bits_per_key)));
        self.segments.sort_by_key(|s| s.stats().min_creation);
    }

    /// Fold all segments into one via k-way merge of sorted runs (the
    /// explicit `compact()` / persist path; background tiering uses
    /// [`compact::pick_tier`] instead).
    fn compact_all(&mut self, cfg: &StoreConfig) {
        if self.segments.len() <= 1 {
            return;
        }
        let refs: Vec<&Segment> = self.segments.iter().map(|s| s.as_ref()).collect();
        self.segments = vec![Arc::new(Segment::merge_with(&refs, cfg.bloom_bits_per_key))];
    }

    /// `Arc`-shared view of every row: sealed segments plus the current
    /// delta sealed into a mini-segment (bounded by the spill threshold,
    /// so this copy is small and constant-bounded — never a full-table
    /// clone).
    fn snapshot(&self) -> Vec<Arc<Segment>> {
        let mut out = self.segments.clone();
        if !self.delta.is_empty() {
            out.push(Arc::new(Segment::from_unsorted(self.delta.clone())));
        }
        out
    }
}

#[derive(Debug, Default)]
struct Table {
    inner: RwLock<TableInner>,
}

/// The offline store: many feature-set tables, independently locked.
#[derive(Debug)]
pub struct OfflineStore {
    /// Name → table. The map lock is held only for the name lookup;
    /// all data operations take the table's own lock.
    tables: RwLock<HashMap<String, Arc<Table>>>,
    cfg: StoreConfig,
    /// Pinged on every delta spill; the compaction driver parks here.
    wake: Arc<Wake>,
}

impl Default for OfflineStore {
    fn default() -> Self {
        Self::new()
    }
}

impl OfflineStore {
    pub fn new() -> Self {
        Self::with_config(StoreConfig::default())
    }

    /// A store with a custom delta-spill threshold (tests use tiny
    /// thresholds to force constant spill/compaction churn).
    pub fn with_spill_threshold(spill_rows: usize) -> Self {
        Self::with_config(StoreConfig { spill_rows, ..Default::default() })
    }

    /// A store with explicit tuning knobs.
    pub fn with_config(cfg: StoreConfig) -> Self {
        assert!(cfg.spill_rows > 0);
        OfflineStore {
            tables: RwLock::new(HashMap::new()),
            cfg,
            wake: Arc::new(Wake::default()),
        }
    }

    pub(crate) fn compaction_wake(&self) -> Arc<Wake> {
        self.wake.clone()
    }

    fn table(&self, name: &str) -> Option<Arc<Table>> {
        self.tables.read().unwrap().get(name).cloned()
    }

    fn table_or_create(&self, name: &str) -> Arc<Table> {
        if let Some(t) = self.table(name) {
            return t;
        }
        self.tables.write().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Alg 2 offline merge: idempotent insert of new record versions.
    /// Constant-bounded writer work: delta append + dedupe probes + an
    /// occasional spill sort; tier folding happens on the background
    /// driver, never here.
    pub fn merge(&self, table: &str, records: &[FeatureRecord]) -> MergeStats {
        let t = self.table_or_create(table);
        let (stats, spilled) = {
            let mut g = t.inner.write().unwrap();
            g.merge(records, &self.cfg)
        };
        if spilled {
            self.wake.ping();
        }
        stats
    }

    /// One background-compaction round: for every table, merge the
    /// lowest over-full size tier until no tier is eligible. The k-way
    /// merges run **without holding any table lock** (inputs are
    /// immutable `Arc` segments); only the final splice takes the write
    /// lock, and it aborts harmlessly if a racing explicit `compact()`
    /// already removed an input. Returns tier merges performed.
    pub fn compact_tick(&self) -> usize {
        self.compact_tick_tiers().len()
    }

    /// [`OfflineStore::compact_tick`], reporting the **tier** of every
    /// merge performed (the driver's per-tier merge counters).
    pub fn compact_tick_tiers(&self) -> Vec<u32> {
        let mut merges = Vec::new();
        for name in self.tables() {
            let Some(t) = self.table(&name) else { continue };
            loop {
                let picked = {
                    let g = t.inner.read().unwrap();
                    compact::pick_tier(&g.segments, self.cfg.spill_rows, self.cfg.tier_fanin)
                };
                let Some((tier, picked)) = picked else { break };
                let refs: Vec<&Segment> = picked.iter().map(|s| s.as_ref()).collect();
                let merged = Arc::new(Segment::merge_with(&refs, self.cfg.bloom_bits_per_key));
                let mut g = t.inner.write().unwrap();
                let all_present =
                    picked.iter().all(|p| g.segments.iter().any(|s| Arc::ptr_eq(s, p)));
                if !all_present {
                    break; // lost the race to an explicit compact; retry next tick
                }
                g.segments.retain(|s| !picked.iter().any(|p| Arc::ptr_eq(s, p)));
                g.segments.push(merged);
                g.segments.sort_by_key(|s| s.stats().min_creation);
                merges.push(tier);
            }
        }
        merges
    }

    /// Tier merges currently pending across all tables, estimated by
    /// simulating the size-tiered picker on per-segment row counts until
    /// no tier is over-full — pure arithmetic, no segment touched, no
    /// lock held during the simulation. This is the
    /// `compaction_backlog` gauge the [`CompactionDriver`] exports: 0
    /// means every table's shape is settled.
    pub fn compaction_backlog(&self) -> u64 {
        let mut pending = 0u64;
        for name in self.tables() {
            let Some(t) = self.table(&name) else { continue };
            let mut rows: Vec<usize> =
                t.inner.read().unwrap().segments.iter().map(|s| s.len()).collect();
            while let Some((_, idxs)) =
                compact::pick_tier_rows(&rows, self.cfg.spill_rows, self.cfg.tier_fanin)
            {
                let merged: usize = idxs.iter().map(|&i| rows[i]).sum();
                for &i in idxs.iter().rev() {
                    rows.remove(i);
                }
                rows.push(merged);
                pending += 1;
            }
        }
        pending
    }

    /// Visit every record with `event_ts` in `window` (and, when `as_of`
    /// is set, `creation_ts <= as_of`) **in place** — no record clones.
    /// Pruning is three-level: the creation-sorted segment list is
    /// binary-searched to drop every segment created after `as_of`
    /// wholesale; segment zone stats drop segments outside the event
    /// window; and inside a segment the block directory skips blocks
    /// outside the window or the visibility horizon, with the per-row
    /// creation check paid only by blocks that genuinely straddle
    /// `as_of`. Visit order is unspecified.
    pub fn for_each_in_window<F: FnMut(RowView<'_>)>(
        &self,
        table: &str,
        window: FeatureWindow,
        as_of: Option<Timestamp>,
        mut f: F,
    ) {
        let Some(t) = self.table(table) else { return };
        let g = t.inner.read().unwrap();
        let visible = match as_of {
            Some(t0) => g.segments.partition_point(|s| s.stats().min_creation <= t0),
            None => g.segments.len(),
        };
        for seg in &g.segments[..visible] {
            if seg.overlaps_event_window(window) {
                seg.for_each_in(window, as_of, &mut f);
            }
        }
        for r in &g.delta {
            if window.contains(r.event_ts) && as_of.map_or(true, |t0| r.creation_ts <= t0) {
                f(RowView {
                    entity: r.entity,
                    event_ts: r.event_ts,
                    creation_ts: r.creation_ts,
                    values: &r.values,
                });
            }
        }
    }

    /// All records with `event_ts` in `window` (every version — Eq. 1),
    /// as owned rows. Compatibility/oracle path: the query engine streams
    /// via [`OfflineStore::snapshot`] instead.
    pub fn scan(&self, table: &str, window: FeatureWindow) -> Vec<FeatureRecord> {
        let mut out = Vec::new();
        self.for_each_in_window(table, window, None, |r| out.push(r.to_record()));
        out
    }

    /// Time travel: only record versions that existed at `as_of`
    /// (creation_ts ≤ as_of). This is what the PIT training query uses so
    /// training reproduces what inference would have seen.
    pub fn scan_as_of(&self, table: &str, window: FeatureWindow, as_of: Timestamp) -> Vec<FeatureRecord> {
        let mut out = Vec::new();
        self.for_each_in_window(table, window, Some(as_of), |r| out.push(r.to_record()));
        out
    }

    /// `Arc`-shared sorted segments covering every row of the table
    /// (delta included as a sealed mini-segment). This is the PIT
    /// merge-join's input: callers stream entity runs straight out of
    /// the shared compressed columns — no full-table
    /// `Vec<FeatureRecord>` is ever materialized.
    pub fn snapshot(&self, table: &str) -> Vec<Arc<Segment>> {
        match self.table(table) {
            Some(t) => t.inner.read().unwrap().snapshot(),
            None => Vec::new(),
        }
    }

    /// Force-seal the delta and fold all segments into one. Returns the
    /// resulting segment count (0 for an empty table). This is the
    /// explicit maintenance/persist path — the writer never does this
    /// inline, and steady-state folding belongs to the background
    /// [`CompactionDriver`].
    pub fn compact(&self, table: &str) -> usize {
        let Some(t) = self.table(table) else { return 0 };
        let mut g = t.inner.write().unwrap();
        g.spill_delta(&self.cfg);
        g.compact_all(&self.cfg);
        g.segments.len()
    }

    /// `(lower, upper)` bounds on rows visible at `as_of`
    /// (`creation_ts <= as_of`), answered from the per-segment
    /// creation-time histograms plus an exact pass over the small delta
    /// — no sealed row is touched. The planning statistic behind
    /// time-travel scans: `upper == 0` proves a table has nothing to
    /// say at `as_of`, `lower == row_count` proves the creation filter
    /// is a no-op.
    pub fn visible_row_bounds(&self, table: &str, as_of: Timestamp) -> (u64, u64) {
        let Some(t) = self.table(table) else { return (0, 0) };
        let g = t.inner.read().unwrap();
        let (mut lo, mut hi) = (0u64, 0u64);
        for seg in &g.segments {
            let (l, h) = seg.visible_bounds(as_of);
            lo += l;
            hi += h;
        }
        let delta_visible = g.delta.iter().filter(|r| r.creation_ts <= as_of).count() as u64;
        (lo + delta_visible, hi + delta_visible)
    }

    /// Physical shape for introspection/tests: `(sealed segments, delta rows)`.
    pub fn storage_shape(&self, table: &str) -> (usize, usize) {
        match self.table(table) {
            Some(t) => {
                let g = t.inner.read().unwrap();
                (g.segments.len(), g.delta.len())
            }
            None => (0, 0),
        }
    }

    /// Encoded heap bytes of a table's sealed segments and the raw bytes
    /// the uncompressed layout would need — the compression ratio the
    /// `segment_scan` bench reports.
    pub fn encoded_bytes(&self, table: &str) -> (usize, usize) {
        match self.table(table) {
            Some(t) => {
                let g = t.inner.read().unwrap();
                let enc = g.segments.iter().map(|s| s.encoded_size_bytes()).sum();
                let raw = g.segments.iter().map(|s| s.raw_size_bytes()).sum();
                (enc, raw)
            }
            None => (0, 0),
        }
    }

    /// Latest record per entity by `(event_ts, creation_ts)` — the
    /// offline→online bootstrap read (§4.5.5). Exploits the segment sort
    /// order: within a segment the last row of an entity run is that
    /// segment's Eq. 2 max, so this walks entity runs with a cursor and
    /// keeps a cross-segment max instead of comparing versions row by
    /// row.
    pub fn latest_per_entity(&self, table: &str) -> Vec<FeatureRecord> {
        let segs = self.snapshot(table);
        // One reusable cursor per segment: the run walk streams blocks
        // in order, and the final gather below revisits mostly-cached
        // blocks instead of paying a throwaway cursor per entity.
        let mut curs: Vec<SegmentCursor<'_>> = segs.iter().map(|s| s.cursor()).collect();
        // entity → (event_ts, creation_ts, segment, row); BTreeMap keeps
        // the output entity-sorted.
        let mut best: BTreeMap<EntityId, (Timestamp, Timestamp, usize, usize)> = BTreeMap::new();
        for (si, seg) in segs.iter().enumerate() {
            let cur = &mut curs[si];
            let mut i = 0;
            while i < seg.len() {
                let e = cur.entity(i);
                let (_, hi) = cur.entity_run(e, i);
                let last = hi - 1;
                let (_, lev, lcr) = cur.key(last);
                match best.get(&e) {
                    Some(&(bev, bcr, _, _)) if (bev, bcr) >= (lev, lcr) => {}
                    _ => {
                        best.insert(e, (lev, lcr, si, last));
                    }
                }
                i = hi;
            }
        }
        let mut out = Vec::with_capacity(best.len());
        for (_, _, si, ri) in best.into_values() {
            let (entity, event_ts, creation_ts) = curs[si].key(ri);
            out.push(FeatureRecord::new(entity, event_ts, creation_ts, segs[si].values_of(ri).to_vec()));
        }
        out
    }

    pub fn row_count(&self, table: &str) -> u64 {
        match self.table(table) {
            Some(t) => t.inner.read().unwrap().rows,
            None => 0,
        }
    }

    pub fn tables(&self) -> Vec<String> {
        self.tables.read().unwrap().keys().cloned().collect()
    }

    /// Event-time coverage `[min, max_event_ts]` of a table, if nonempty.
    /// Answered from segment zone stats plus a linear pass over the small
    /// delta — no row materialization.
    pub fn event_range(&self, table: &str) -> Option<(Timestamp, Timestamp)> {
        let t = self.table(table)?;
        let g = t.inner.read().unwrap();
        let mut lo = Timestamp::MAX;
        let mut hi = Timestamp::MIN;
        for seg in &g.segments {
            if seg.is_empty() {
                continue;
            }
            lo = lo.min(seg.stats().min_event);
            hi = hi.max(seg.stats().max_event);
        }
        for r in &g.delta {
            lo = lo.min(r.event_ts);
            hi = hi.max(r.event_ts);
        }
        (lo <= hi).then_some((lo, hi))
    }

    /// Persist all tables under `dir` (one compacted `.gfseg` v3 per
    /// table).
    pub fn persist(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let names = self.tables();
        for name in names {
            let segs = self.snapshot(&name);
            let path = dir.join(format!("{name}.gfseg"));
            match segs.len() {
                0 => segment::persist_segment(&path, &Segment::from_unsorted(Vec::new()))?,
                1 => segment::persist_segment(&path, &segs[0])?,
                _ => {
                    let refs: Vec<&Segment> = segs.iter().map(|s| s.as_ref()).collect();
                    segment::persist_segment(&path, &Segment::merge(&refs))?;
                }
            }
        }
        Ok(())
    }

    /// Load tables persisted by [`OfflineStore::persist`] (v3 or legacy
    /// v2 files), with default tuning knobs. Segments load directly into
    /// compressed columnar form — already sorted, no re-index: the
    /// uniqueness bloom is rebuilt by the load-time validation decode,
    /// and no per-row key set exists to rebuild.
    pub fn load(dir: &std::path::Path) -> Result<OfflineStore> {
        Self::load_with(dir, StoreConfig::default())
    }

    /// [`OfflineStore::load`] with explicit tuning knobs — segments are
    /// loaded at `cfg.bloom_bits_per_key`, so an operator's configured
    /// dedupe-memory bound survives a restart instead of silently
    /// resetting to the default density.
    pub fn load_with(dir: &std::path::Path, cfg: StoreConfig) -> Result<OfflineStore> {
        let store = OfflineStore::with_config(cfg);
        if !dir.exists() {
            return Ok(store);
        }
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("gfseg") {
                continue;
            }
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| FsError::Other(format!("bad segment file {path:?}")))?
                .to_string();
            let seg = segment::load_segment_with(&path, cfg.bloom_bits_per_key)?;
            let rows = seg.len() as u64;
            let inner = TableInner {
                segments: if seg.is_empty() { Vec::new() } else { vec![Arc::new(seg)] },
                delta: Vec::new(),
                delta_keys: HashSet::new(),
                rows,
            };
            store
                .tables
                .write()
                .unwrap()
                .insert(name, Arc::new(Table { inner: RwLock::new(inner) }));
        }
        Ok(store)
    }

    /// Load an explicit `(table, segment-file)` set — the durable-store
    /// recovery path, where the *manifest* (not a directory scan) names
    /// which `.gfseg` files are live. A directory may legitimately hold
    /// unreferenced segments awaiting GC; scanning it would resurrect
    /// them.
    pub fn load_files(files: &[(String, std::path::PathBuf)], cfg: StoreConfig) -> Result<OfflineStore> {
        let store = OfflineStore::with_config(cfg);
        for (name, path) in files {
            let seg = segment::load_segment_with(path, store.cfg.bloom_bits_per_key)?;
            let rows = seg.len() as u64;
            let inner = TableInner {
                segments: if seg.is_empty() { Vec::new() } else { vec![Arc::new(seg)] },
                delta: Vec::new(),
                delta_keys: HashSet::new(),
                rows,
            };
            store
                .tables
                .write()
                .unwrap()
                .insert(name.clone(), Arc::new(Table { inner: RwLock::new(inner) }));
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;
    use crate::types::time::DAY;

    fn rec(entity: EntityId, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn merge_is_idempotent() {
        let s = OfflineStore::new();
        let rows = vec![rec(1, 100, 200, 1.0), rec(2, 100, 200, 2.0)];
        let m1 = s.merge("t", &rows);
        assert_eq!(m1, MergeStats { inserted: 2, skipped: 0 });
        let m2 = s.merge("t", &rows);
        assert_eq!(m2, MergeStats { inserted: 0, skipped: 2 });
        assert_eq!(s.row_count("t"), 2);
    }

    #[test]
    fn sorted_batch_dedupe_matches_pointwise() {
        // Differential: bulk merges (sorted-probe path) against the same
        // records applied one by one (pointwise path) — identical stats,
        // identical surviving rows, under heavy key collisions: re-draws
        // of already-sealed keys, in-batch duplicates carrying different
        // values (first arrival must win), and fresh keys.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let bulk = OfflineStore::with_spill_threshold(16);
        let pointwise = OfflineStore::with_spill_threshold(16);
        let history: Vec<FeatureRecord> = (0..64)
            .map(|i| rec(rng.below(20), rng.range(0, 50), rng.range(0, 50), i as f32))
            .collect();
        bulk.merge("t", &history);
        for r in &history {
            pointwise.merge("t", std::slice::from_ref(r));
        }
        for round in 0..10 {
            let batch: Vec<FeatureRecord> = (0..40)
                .map(|j| {
                    rec(rng.below(20), rng.range(0, 60), rng.range(0, 60), (round * 100 + j) as f32)
                })
                .collect();
            let mb = bulk.merge("t", &batch);
            let mut mp = MergeStats::default();
            for r in &batch {
                mp.add(pointwise.merge("t", std::slice::from_ref(r)));
            }
            assert_eq!(mb, mp, "round {round}");
        }
        assert_eq!(bulk.row_count("t"), pointwise.row_count("t"));
        let w = FeatureWindow::new(0, 1_000);
        let key = |r: &FeatureRecord| (r.entity, r.event_ts, r.creation_ts);
        let (mut a, mut b) = (bulk.scan("t", w), pointwise.scan("t", w));
        a.sort_by_key(key);
        b.sort_by_key(key);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(key(x), key(y));
            assert_eq!(x.values, y.values, "first in-batch duplicate must win in both paths");
        }
    }

    #[test]
    fn keeps_every_version_eq1() {
        let s = OfflineStore::new();
        // Same entity+event_ts, three creation timestamps (job retries /
        // late recomputes) — all kept (Eq. 1).
        s.merge("t", &[rec(1, 100, 200, 1.0), rec(1, 100, 300, 1.1), rec(1, 100, 400, 1.2)]);
        assert_eq!(s.row_count("t"), 3);
        assert_eq!(s.scan("t", FeatureWindow::new(0, 1_000)).len(), 3);
    }

    #[test]
    fn scan_respects_window_half_open() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 100, 200, 0.0), rec(1, 200, 300, 0.0), rec(1, 300, 400, 0.0)]);
        let got = s.scan("t", FeatureWindow::new(100, 300));
        let evs: Vec<_> = got.iter().map(|r| r.event_ts).collect();
        assert_eq!(evs.len(), 2);
        assert!(evs.contains(&100) && evs.contains(&200));
    }

    #[test]
    fn scan_prunes_segments_across_days() {
        // Spill every 5 rows so the 30 days land in several segments with
        // disjoint event ranges; the windowed scan must still see exactly
        // the two in-window rows.
        let s = OfflineStore::with_spill_threshold(5);
        for d in 0..30 {
            s.merge("t", &[rec(1, d * DAY + 10, d * DAY + 20, d as f32)]);
        }
        let (segs, delta) = s.storage_shape("t");
        assert!(segs >= 2, "expected several sealed segments, got {segs} (+{delta} delta)");
        let got = s.scan("t", FeatureWindow::new(10 * DAY, 12 * DAY));
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn time_travel_as_of() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 100, 150, 1.0), rec(1, 100, 500, 2.0)]);
        let w = FeatureWindow::new(0, 1_000);
        assert_eq!(s.scan_as_of("t", w, 200).len(), 1);
        assert_eq!(s.scan_as_of("t", w, 100).len(), 0);
        assert_eq!(s.scan_as_of("t", w, 500).len(), 2);
    }

    #[test]
    fn time_travel_prunes_creation_sorted_segments() {
        // Segments sealed at distinct creation epochs: an as_of in the
        // middle must cut the later segments off wholesale (correctness
        // is asserted here; the wholesale cut is the partition_point on
        // the creation-sorted list).
        let s = OfflineStore::with_spill_threshold(2);
        for k in 0..6i64 {
            s.merge(
                "t",
                &[
                    rec(1, 10 + k, 1_000 * k + 1, k as f32),
                    rec(2, 20 + k, 1_000 * k + 2, k as f32),
                ],
            );
        }
        let (segs, _) = s.storage_shape("t");
        assert!(segs >= 3);
        let w = FeatureWindow::new(0, 1_000);
        for as_of in [0, 1, 1_500, 3_002, 5_002, 99_999] {
            let got = s.scan_as_of("t", w, as_of);
            let want = s
                .scan("t", w)
                .into_iter()
                .filter(|r| r.creation_ts <= as_of)
                .count();
            assert_eq!(got.len(), want, "as_of {as_of}");
        }
    }

    #[test]
    fn latest_per_entity_matches_eq2() {
        let s = OfflineStore::new();
        // Fig 5's records: R1={t1,t1'}, R3={t1,t3'} late-arriving;
        // R2={t2,t2'} has the max event_ts → R2 is the latest.
        s.merge(
            "t",
            &[rec(1, 10, 11, 0.0), rec(1, 20, 21, 1.0), rec(1, 30, 31, 2.0), rec(1, 20, 99, 3.0)],
        );
        let latest = s.latest_per_entity("t");
        assert_eq!(latest.len(), 1);
        assert_eq!(latest[0].event_ts, 30);
        assert_eq!(latest[0].creation_ts, 31);
    }

    #[test]
    fn latest_per_entity_tie_breaks_on_creation() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, 10, 11, 0.0), rec(1, 10, 50, 1.0)]);
        let latest = s.latest_per_entity("t");
        assert_eq!(latest[0].creation_ts, 50);
    }

    #[test]
    fn latest_per_entity_across_segments_and_delta() {
        // Max version lives in a different segment per entity; output is
        // entity-sorted.
        let s = OfflineStore::with_spill_threshold(2);
        s.merge("t", &[rec(2, 10, 11, 0.2), rec(1, 50, 51, 1.5)]); // sealed
        s.merge("t", &[rec(1, 40, 41, 1.4), rec(2, 60, 61, 2.6)]); // sealed
        s.merge("t", &[rec(3, 5, 6, 3.0)]); // stays in delta
        let latest = s.latest_per_entity("t");
        let got: Vec<_> = latest.iter().map(|r| (r.entity, r.version())).collect();
        assert_eq!(got, vec![(1, (50, 51)), (2, (60, 61)), (3, (5, 6))]);
    }

    #[test]
    fn event_range() {
        let s = OfflineStore::new();
        assert_eq!(s.event_range("t"), None);
        s.merge("t", &[rec(1, 100, 150, 0.0), rec(2, 900, 950, 0.0)]);
        assert_eq!(s.event_range("t"), Some((100, 900)));
        // Survives sealing + compaction.
        s.compact("t");
        assert_eq!(s.event_range("t"), Some((100, 900)));
    }

    #[test]
    fn negative_event_ts() {
        let s = OfflineStore::new();
        s.merge("t", &[rec(1, -100, 0, 0.0)]);
        assert_eq!(s.scan("t", FeatureWindow::new(-DAY, 0)).len(), 1);
    }

    #[test]
    fn spill_and_compaction_preserve_contents_and_idempotence() {
        let s = OfflineStore::with_spill_threshold(4);
        let rows: Vec<FeatureRecord> =
            (0..30).map(|i| rec(i % 5, 100 + i as i64, 200 + i as i64, i as f32)).collect();
        for chunk in rows.chunks(3) {
            s.merge("t", chunk);
        }
        assert_eq!(s.row_count("t"), 30);
        let mut got = s.scan("t", FeatureWindow::new(0, 10_000));
        got.sort_by_key(|r| r.unique_key());
        let mut want = rows.clone();
        want.sort_by_key(|r| r.unique_key());
        assert_eq!(got, want);

        // Replaying the whole batch is a pure no-op, whatever the shape —
        // this now exercises the bloom + exact-probe path for every
        // sealed row (the exact delta-key set was cleared by spills).
        let m = s.merge("t", &rows);
        assert_eq!(m, MergeStats { inserted: 0, skipped: 30 });

        // Explicit compaction folds to one segment, contents unchanged.
        assert_eq!(s.compact("t"), 1);
        assert_eq!(s.storage_shape("t"), (1, 0));
        let mut after = s.scan("t", FeatureWindow::new(0, 10_000));
        after.sort_by_key(|r| r.unique_key());
        assert_eq!(after, want);
        assert_eq!(s.row_count("t"), 30);
        // And the probe path still dedupes against the folded segment.
        let m = s.merge("t", &rows);
        assert_eq!(m, MergeStats { inserted: 0, skipped: 30 });
    }

    #[test]
    fn writer_never_compacts_inline_background_tick_does() {
        let cfg = StoreConfig { spill_rows: 8, tier_fanin: 4, ..Default::default() };
        let s = OfflineStore::with_config(cfg);
        for i in 0..400i64 {
            s.merge("t", &[rec((i % 13) as u64, i * 10, i * 10 + 5, i as f32)]);
        }
        let (before, delta) = s.storage_shape("t");
        // 400 rows / spill 8 = 50 spills; the writer must have left all
        // of them sealed (no inline folding).
        assert_eq!((before, delta), (50, 0));

        // Draining the tiers folds 50 → a handful, geometrically.
        let merges = {
            let mut n = 0;
            loop {
                let m = s.compact_tick();
                if m == 0 {
                    break n;
                }
                n += m;
            }
        };
        assert!(merges > 0);
        let (after, _) = s.storage_shape("t");
        assert!(after <= 8, "tiering should bound segments, got {after}");
        // Physical churn only: contents, count and idempotence intact.
        assert_eq!(s.row_count("t"), 400);
        assert_eq!(s.scan("t", FeatureWindow::new(0, 100_000)).len(), 400);
        let m = s.merge("t", &[rec(3, 30, 35, 3.0)]);
        assert_eq!(m, MergeStats { inserted: 0, skipped: 1 });
    }

    #[test]
    fn compaction_driver_folds_in_background() {
        let cfg = StoreConfig { spill_rows: 8, tier_fanin: 4, ..Default::default() };
        let s = Arc::new(OfflineStore::with_config(cfg));
        let driver = CompactionDriver::spawn(s.clone(), std::time::Duration::from_millis(1));
        for i in 0..400i64 {
            s.merge("t", &[rec((i % 7) as u64, i * 10, i * 10 + 5, i as f32)]);
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while s.storage_shape("t").0 > 8 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let (segs, _) = s.storage_shape("t");
        assert!(segs <= 8, "driver must fold tiers in the background, got {segs}");
        assert!(driver.merges() > 0);
        assert_eq!(s.row_count("t"), 400);
        assert_eq!(s.scan("t", FeatureWindow::new(0, 100_000)).len(), 400);
        drop(driver);
    }

    #[test]
    fn visitor_matches_scan_zero_clone() {
        let s = OfflineStore::with_spill_threshold(3);
        for i in 0..10 {
            s.merge("t", &[rec(i % 3, i as i64 * 10, i as i64 * 10 + 5, i as f32)]);
        }
        let w = FeatureWindow::new(15, 75);
        let mut visited = Vec::new();
        s.for_each_in_window("t", w, None, |r| visited.push(r.to_record()));
        let mut scanned = s.scan("t", w);
        visited.sort_by_key(|r| r.unique_key());
        scanned.sort_by_key(|r| r.unique_key());
        assert_eq!(visited, scanned);
        // as_of variant too.
        let mut visited_asof = Vec::new();
        s.for_each_in_window("t", w, Some(40), |r| visited_asof.push(r.to_record()));
        let mut scanned_asof = s.scan_as_of("t", w, 40);
        visited_asof.sort_by_key(|r| r.unique_key());
        scanned_asof.sort_by_key(|r| r.unique_key());
        assert_eq!(visited_asof, scanned_asof);
        assert!(visited_asof.len() < visited.len());
    }

    #[test]
    fn visible_row_bounds_bracket_scan_as_of() {
        let s = OfflineStore::with_spill_threshold(8);
        for i in 0..50i64 {
            s.merge("t", &[rec((i % 7) as EntityId, i * 10, 1_000 + i * 5, i as f32)]);
        }
        let w = FeatureWindow::new(i64::MIN / 2, i64::MAX / 2);
        for as_of in [0, 1_000, 1_040, 1_120, 1_245, 9_999] {
            let truth = s.scan_as_of("t", w, as_of).len() as u64;
            let (lo, hi) = s.visible_row_bounds("t", as_of);
            assert!(lo <= truth && truth <= hi, "as_of {as_of}: {lo} ≤ {truth} ≤ {hi}");
        }
        // Edges are exact, whatever the segment/delta split.
        assert_eq!(s.visible_row_bounds("t", 999), (0, 0));
        assert_eq!(s.visible_row_bounds("t", 9_999), (50, 50));
        assert_eq!(s.visible_row_bounds("ghost", 0), (0, 0));
        // The all-visible fast path (no per-row creation check) must be
        // indistinguishable from the filtering path.
        let all = s.scan("t", w);
        let fast = s.scan_as_of("t", w, 9_999);
        assert_eq!(all.len(), fast.len());
    }

    #[test]
    fn snapshot_covers_delta_and_segments() {
        let s = OfflineStore::with_spill_threshold(3);
        s.merge("t", &[rec(1, 10, 20, 1.0), rec(2, 30, 40, 2.0), rec(3, 50, 60, 3.0)]); // seals
        s.merge("t", &[rec(4, 70, 80, 4.0)]); // delta
        let segs = s.snapshot("t");
        assert_eq!(segs.len(), 2);
        let total: usize = segs.iter().map(|g| g.len()).sum();
        assert_eq!(total, 4);
        // Each snapshot segment is sorted (from_columns-style invariant).
        for seg in &segs {
            for i in 1..seg.len() {
                assert!(seg.row(i - 1).entity <= seg.row(i).entity);
            }
        }
        // Unknown table: empty snapshot, not a panic.
        assert!(s.snapshot("ghost").is_empty());
    }

    #[test]
    fn persist_and_load_roundtrip() {
        let dir = TempDir::new("off-roundtrip");
        let s = OfflineStore::with_spill_threshold(2);
        s.merge("alpha", &[rec(1, 100, 150, 1.5), rec(2, 200, 250, -2.5)]);
        s.merge("alpha", &[rec(3, 300, 350, 7.0)]);
        s.merge("beta", &[rec(3, 300, 350, 0.25)]);
        s.persist(dir.path()).unwrap();

        let loaded = OfflineStore::load(dir.path()).unwrap();
        assert_eq!(loaded.row_count("alpha"), 3);
        assert_eq!(loaded.row_count("beta"), 1);
        // A persisted table loads as one compacted segment.
        assert_eq!(loaded.storage_shape("alpha"), (1, 0));
        let got = loaded.scan("alpha", FeatureWindow::new(0, 1_000));
        assert!(got.iter().any(|r| r.values[0] == 1.5));
        // Re-merging what was persisted is a no-op (bloom + exact probe,
        // no rebuilt key set needed).
        let m = loaded.merge("alpha", &[rec(1, 100, 150, 1.5)]);
        assert_eq!(m, MergeStats { inserted: 0, skipped: 1 });
    }

    #[test]
    fn load_files_restores_only_named_segments() {
        let dir = TempDir::new("off-files");
        let s = OfflineStore::with_spill_threshold(2);
        s.merge("alpha", &[rec(1, 100, 150, 1.5), rec(2, 200, 250, -2.5)]);
        s.merge("beta", &[rec(3, 300, 350, 0.25)]);
        s.persist(dir.path()).unwrap();

        // Only alpha is named by the (simulated) manifest; beta's file
        // still on disk is an unreferenced orphan and must stay dead.
        let files = vec![("alpha".to_string(), dir.path().join("alpha.gfseg"))];
        let loaded = OfflineStore::load_files(&files, StoreConfig::default()).unwrap();
        assert_eq!(loaded.tables(), vec!["alpha".to_string()]);
        assert_eq!(loaded.row_count("alpha"), 2);
        assert_eq!(loaded.row_count("beta"), 0);
        // A missing named file is an error, not an empty table.
        let bad = vec![("ghost".to_string(), dir.path().join("ghost.gfseg"))];
        assert!(OfflineStore::load_files(&bad, StoreConfig::default()).is_err());
    }

    #[test]
    fn load_with_preserves_bloom_density() {
        let dir = TempDir::new("off-density");
        let s = OfflineStore::new();
        for i in 0..512i64 {
            s.merge("t", &[rec(i as u64, i, i + 1, 0.0)]);
        }
        s.persist(dir.path()).unwrap();
        let lo = OfflineStore::load_with(
            dir.path(),
            StoreConfig { bloom_bits_per_key: 1, ..Default::default() },
        )
        .unwrap();
        let hi = OfflineStore::load_with(
            dir.path(),
            StoreConfig { bloom_bits_per_key: 16, ..Default::default() },
        )
        .unwrap();
        // Filter memory follows the configured density across a restart
        // (encoded_bytes includes the bloom; key/value planes are
        // identical between the two loads).
        let (e_lo, _) = lo.encoded_bytes("t");
        let (e_hi, _) = hi.encoded_bytes("t");
        assert!(e_lo < e_hi, "1-bit blooms must undercut 16-bit: {e_lo} vs {e_hi}");
        // Dedupe stays exact at either density.
        for loaded in [&lo, &hi] {
            let m = loaded.merge("t", &[rec(7, 7, 8, 0.0)]);
            assert_eq!(m, MergeStats { inserted: 0, skipped: 1 });
        }
    }

    #[test]
    fn load_missing_dir_is_empty_store() {
        let dir = TempDir::new("off-missing");
        let missing = dir.file("nope");
        let loaded = OfflineStore::load(&missing).unwrap();
        assert!(loaded.tables().is_empty());
    }

    #[test]
    fn encoded_bytes_reports_compression() {
        let s = OfflineStore::with_spill_threshold(64);
        // Regular cadence + repetitive values: should compress well.
        for i in 0..512i64 {
            s.merge("t", &[rec((i % 4) as u64, (i / 4) * DAY, (i / 4) * DAY + 600, 1.0)]);
        }
        let (enc, raw) = s.encoded_bytes("t");
        assert!(enc > 0 && raw > 0);
        assert!(enc < raw, "encoded {enc} must undercut raw {raw}");
    }
}
