//! Micro-batching of online lookups.
//!
//! Point lookups arriving within a short window are coalesced into one
//! `get_many` against the store — the standard low-latency serving trick
//! (vLLM-style continuous batching, applied to KV reads).  The batcher is
//! deterministic and pull-based: callers `push` requests and a driver
//! thread (or the test) calls `flush` when either the size or the age
//! trigger fires.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::online_store::OnlineStore;
use crate::types::{EntityId, FeatureRecord, Timestamp};

/// One queued lookup.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchItem {
    pub request_id: u64,
    pub table: String,
    pub entity: EntityId,
    /// Processing-time the request arrived (drives the age trigger).
    pub arrived_at_us: u64,
}

/// Completed lookup.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub request_id: u64,
    pub record: Option<FeatureRecord>,
    /// Queue time + store time, µs (simulated processing timeline).
    pub latency_us: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// Flush when the oldest item has waited this long.
    pub max_wait_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, max_wait_us: 500 }
    }
}

/// FIFO micro-batcher over one online store.
pub struct MicroBatcher {
    cfg: BatcherConfig,
    queue: Mutex<VecDeque<BatchItem>>,
    next_id: Mutex<u64>,
}

impl MicroBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        MicroBatcher { cfg, queue: Mutex::new(VecDeque::new()), next_id: Mutex::new(0) }
    }

    /// Enqueue a lookup; returns its request id.
    pub fn push(&self, table: &str, entity: EntityId, now_us: u64) -> u64 {
        let mut idg = self.next_id.lock().unwrap();
        let id = *idg;
        *idg += 1;
        drop(idg);
        self.queue.lock().unwrap().push_back(BatchItem {
            request_id: id,
            table: table.to_string(),
            entity,
            arrived_at_us: now_us,
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Should the driver flush now?
    pub fn should_flush(&self, now_us: u64) -> bool {
        let q = self.queue.lock().unwrap();
        if q.len() >= self.cfg.max_batch {
            return true;
        }
        q.front().map_or(false, |i| now_us - i.arrived_at_us >= self.cfg.max_wait_us)
    }

    /// Drain up to `max_batch` items and execute them as grouped
    /// `get_many` calls (one per table in the batch).
    pub fn flush(&self, store: &OnlineStore, now: Timestamp, now_us: u64) -> Vec<BatchResult> {
        let items: Vec<BatchItem> = {
            let mut q = self.queue.lock().unwrap();
            let n = q.len().min(self.cfg.max_batch);
            q.drain(..n).collect()
        };
        if items.is_empty() {
            return Vec::new();
        }
        // Group by table preserving original order for the response.
        let mut by_table: Vec<(String, Vec<usize>)> = Vec::new();
        for (i, item) in items.iter().enumerate() {
            match by_table.iter_mut().find(|(t, _)| *t == item.table) {
                Some((_, idxs)) => idxs.push(i),
                None => by_table.push((item.table.clone(), vec![i])),
            }
        }
        let mut results: Vec<Option<BatchResult>> = vec![None; items.len()];
        for (table, idxs) in by_table {
            let entities: Vec<EntityId> = idxs.iter().map(|&i| items[i].entity).collect();
            let t0 = std::time::Instant::now();
            let records = store.get_many(&table, &entities, now);
            let store_us = (t0.elapsed().as_nanos() as u64 / 1_000).max(1);
            for (&i, record) in idxs.iter().zip(records) {
                results[i] = Some(BatchResult {
                    request_id: items[i].request_id,
                    record,
                    latency_us: (now_us - items[i].arrived_at_us) + store_us,
                });
            }
        }
        results.into_iter().map(|r| r.expect("all items answered")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(n: u64) -> OnlineStore {
        let s = OnlineStore::new(4);
        let recs: Vec<FeatureRecord> =
            (0..n).map(|i| FeatureRecord::new(i, 10, 20, vec![i as f32])).collect();
        s.merge("t", &recs, 20);
        s
    }

    #[test]
    fn batches_by_size_trigger() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 4, max_wait_us: 1_000_000 });
        let store = store_with(10);
        for e in 0..3 {
            b.push("t", e, 100);
        }
        assert!(!b.should_flush(100));
        b.push("t", 3, 101);
        assert!(b.should_flush(101));
        let out = b.flush(&store, 50, 150);
        assert_eq!(out.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_by_age_trigger() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 500 });
        b.push("t", 1, 1_000);
        assert!(!b.should_flush(1_400));
        assert!(b.should_flush(1_500));
    }

    #[test]
    fn results_match_requests_in_order() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(5);
        let ids: Vec<u64> = (0..5).map(|e| b.push("t", 4 - e, 10)).collect();
        let out = b.flush(&store, 100, 20);
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.request_id, ids[i]);
            assert_eq!(r.record.as_ref().unwrap().values[0], (4 - i as u64) as f32);
        }
    }

    #[test]
    fn mixed_tables_in_one_batch() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(3);
        let extra = vec![FeatureRecord::new(7, 10, 20, vec![70.0])];
        store.merge("other", &extra, 20);
        b.push("t", 1, 0);
        b.push("other", 7, 0);
        b.push("t", 2, 0);
        let out = b.flush(&store, 100, 5);
        assert_eq!(out[0].record.as_ref().unwrap().values[0], 1.0);
        assert_eq!(out[1].record.as_ref().unwrap().values[0], 70.0);
        assert_eq!(out[2].record.as_ref().unwrap().values[0], 2.0);
    }

    #[test]
    fn latency_includes_queue_wait() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(1);
        b.push("t", 0, 1_000);
        let out = b.flush(&store, 100, 1_800);
        assert!(out[0].latency_us >= 800, "queue wait must count: {}", out[0].latency_us);
    }

    #[test]
    fn drains_at_most_max_batch() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 2, max_wait_us: 0 });
        let store = store_with(10);
        for e in 0..5 {
            b.push("t", e, 0);
        }
        assert_eq!(b.flush(&store, 100, 1).len(), 2);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn empty_flush_is_noop() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(1);
        assert!(b.flush(&store, 100, 0).is_empty());
        assert!(!b.should_flush(1_000_000), "empty queue must never trigger a flush");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn successive_flushes_preserve_fifo_order() {
        // Items pushed across several flush cycles come back in global
        // FIFO order: flush k drains ids [k*max .. k*max + max).
        let b = MicroBatcher::new(BatcherConfig { max_batch: 3, max_wait_us: 0 });
        let store = store_with(10);
        let ids: Vec<u64> = (0..8).map(|e| b.push("t", e, 0)).collect();
        let mut seen = Vec::new();
        while b.pending() > 0 {
            let out = b.flush(&store, 100, 1);
            assert!(out.len() <= 3);
            seen.extend(out.iter().map(|r| r.request_id));
        }
        assert_eq!(seen, ids, "flush cycles must drain in arrival order");
    }

    #[test]
    fn request_ids_are_unique_and_monotonic() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let ids: Vec<u64> = (0..50).map(|e| b.push("t", e % 7, e)).collect();
        for pair in ids.windows(2) {
            assert!(pair[1] > pair[0]);
        }
        assert_eq!(b.pending(), 50);
    }

    #[test]
    fn flush_results_match_per_key_gets() {
        // The grouped get_many execution must be observationally
        // identical to per-key point gets (same records, same store
        // hit/miss accounting for the batch).
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(6);
        for e in [0u64, 9, 3, 5, 11] {
            b.push("t", e, 0);
        }
        let out = b.flush(&store, 100, 5);
        let batched_hits = store.hits.load(std::sync::atomic::Ordering::Relaxed);
        let batched_misses = store.misses.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!((batched_hits, batched_misses), (3, 2));
        for (r, e) in out.iter().zip([0u64, 9, 3, 5, 11]) {
            assert_eq!(r.record, store.get("t", e, 100), "entity {e}");
        }
    }

    #[test]
    fn age_trigger_fires_on_oldest_item() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 500 });
        b.push("t", 1, 1_000);
        b.push("t", 2, 1_400); // younger item must not reset the clock
        assert!(!b.should_flush(1_499));
        assert!(b.should_flush(1_500), "oldest item's age drives the trigger");
    }
}
