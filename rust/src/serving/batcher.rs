//! Micro-batching of online lookups **and writes**.
//!
//! Point lookups arriving within a short window are coalesced into one
//! `get_many` against the store — the standard low-latency serving trick
//! (vLLM-style continuous batching, applied to KV reads).  The same
//! machinery runs the other direction: [`WriteBatcher`] coalesces
//! record upserts (the streaming engine's online-write stage) into one
//! `merge` per table per flush.
//!
//! Both batchers are deterministic and pull-based at the core: callers
//! `push`, and `flush` fires when either the size or the age trigger
//! does. On top of that, [`FlushDriver`] is the real push-based driver
//! (ROADMAP follow-up): a background thread parked on the batcher's
//! wake condvar, kicked by every `push`, that honors `max_wait_us` on
//! the wall clock — a full batch flushes immediately (size trigger +
//! wake), a lone item flushes within ~`max_wait_us`. The pull-based
//! path stays for tests and for engines that want deterministic,
//! simulated-time flushing.
//!
//! Timebases: queue items carry the caller's `now_us`. The pull path
//! may feed a simulated timeline; anything driven by a [`FlushDriver`]
//! must push with [`wall_us`] so ages are measured on the same clock
//! the driver waits on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::online_store::OnlineStore;
use crate::types::{EntityId, FeatureRecord, FsError, Result, Timestamp};
use crate::util::wake::Wake;
use crate::util::Clock;

/// Microseconds since process start — the wall-clock timebase shared by
/// batcher pushes and [`FlushDriver`] waits.
pub fn wall_us() -> u64 {
    static T0: OnceLock<Instant> = OnceLock::new();
    T0.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Background flush thread: parked on a batcher's wake channel, ticks
/// on every push and at least every `period`. The tick closure gets
/// `final_pass = true` exactly once, on shutdown, and must drain then.
pub struct FlushDriver {
    stop: Arc<AtomicBool>,
    wake: Arc<Wake>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl FlushDriver {
    fn spawn(
        name: &str,
        wake: Arc<Wake>,
        period: Duration,
        mut tick: impl FnMut(bool) + Send + 'static,
    ) -> FlushDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let wake2 = wake.clone();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                let mut seen = 0u64;
                loop {
                    if stop2.load(Ordering::Acquire) {
                        tick(true);
                        return;
                    }
                    seen = wake2.wait(seen, period);
                    tick(false);
                }
            })
            .expect("spawn flush driver");
        FlushDriver { stop, wake, handle: Some(handle) }
    }
}

impl Drop for FlushDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.wake.ping();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn driver_period(cfg: &BatcherConfig) -> Duration {
    Duration::from_micros(cfg.max_wait_us.clamp(100, 1_000_000))
}

/// One queued lookup.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchItem {
    pub request_id: u64,
    pub table: String,
    pub entity: EntityId,
    /// Processing-time the request arrived (drives the age trigger).
    pub arrived_at_us: u64,
}

/// Completed lookup.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub request_id: u64,
    pub record: Option<FeatureRecord>,
    /// Queue time + store time, µs (simulated processing timeline).
    pub latency_us: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// Flush when the oldest item has waited this long.
    pub max_wait_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, max_wait_us: 500 }
    }
}

/// FIFO micro-batcher over one online store.
pub struct MicroBatcher {
    cfg: BatcherConfig,
    queue: Mutex<VecDeque<BatchItem>>,
    next_id: Mutex<u64>,
    wake: Arc<Wake>,
}

impl MicroBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        MicroBatcher {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            next_id: Mutex::new(0),
            wake: Arc::new(Wake::default()),
        }
    }

    /// Enqueue a lookup; returns its request id.
    pub fn push(&self, table: &str, entity: EntityId, now_us: u64) -> u64 {
        let mut idg = self.next_id.lock().unwrap();
        let id = *idg;
        *idg += 1;
        drop(idg);
        self.queue.lock().unwrap().push_back(BatchItem {
            request_id: id,
            table: table.to_string(),
            entity,
            arrived_at_us: now_us,
        });
        self.wake.ping();
        id
    }

    /// Backpressure-aware enqueue: sheds with a typed `Overloaded` error
    /// when the queue already holds `max_pending` lookups, instead of
    /// deepening it without bound. The bound is the caller's — different
    /// producers on one batcher can run different depths.
    pub fn try_push(
        &self,
        table: &str,
        entity: EntityId,
        now_us: u64,
        max_pending: usize,
    ) -> Result<u64> {
        if self.pending() >= max_pending {
            return Err(FsError::Overloaded {
                resource: "read batcher".into(),
                reason: format!("pending {} >= {max_pending}", self.pending()),
            });
        }
        Ok(self.push(table, entity, now_us))
    }

    /// Spawn the push-based background flush loop. Completed lookups go
    /// to `sink`. Callers must `push` with [`wall_us`] timestamps. The
    /// driver drains the queue on drop.
    pub fn spawn_driver(
        self: &Arc<Self>,
        store: Arc<OnlineStore>,
        clock: Clock,
        sink: impl Fn(Vec<BatchResult>) + Send + 'static,
    ) -> FlushDriver {
        let b = self.clone();
        let period = driver_period(&b.cfg);
        FlushDriver::spawn("geofs-read-flush", self.wake.clone(), period, move |final_pass| {
            let now_us = wall_us();
            while (final_pass && b.pending() > 0) || b.should_flush(now_us) {
                let out = b.flush(&store, clock.now(), now_us);
                if out.is_empty() {
                    break;
                }
                sink(out);
            }
        })
    }

    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Should the driver flush now?
    pub fn should_flush(&self, now_us: u64) -> bool {
        let q = self.queue.lock().unwrap();
        if q.len() >= self.cfg.max_batch {
            return true;
        }
        // Saturating: with a concurrent driver a push can land between
        // the driver's clock read and this check.
        q.front().map_or(false, |i| now_us.saturating_sub(i.arrived_at_us) >= self.cfg.max_wait_us)
    }

    /// Drain up to `max_batch` items and execute them as grouped
    /// `get_many` calls (one per table in the batch).
    pub fn flush(&self, store: &OnlineStore, now: Timestamp, now_us: u64) -> Vec<BatchResult> {
        let items: Vec<BatchItem> = {
            let mut q = self.queue.lock().unwrap();
            let n = q.len().min(self.cfg.max_batch);
            q.drain(..n).collect()
        };
        if items.is_empty() {
            return Vec::new();
        }
        // Group by table preserving original order for the response.
        let mut by_table: Vec<(String, Vec<usize>)> = Vec::new();
        for (i, item) in items.iter().enumerate() {
            match by_table.iter_mut().find(|(t, _)| *t == item.table) {
                Some((_, idxs)) => idxs.push(i),
                None => by_table.push((item.table.clone(), vec![i])),
            }
        }
        let mut results: Vec<Option<BatchResult>> = vec![None; items.len()];
        for (table, idxs) in by_table {
            let entities: Vec<EntityId> = idxs.iter().map(|&i| items[i].entity).collect();
            let t0 = std::time::Instant::now();
            let records = store.get_many(&table, &entities, now);
            let store_us = (t0.elapsed().as_nanos() as u64 / 1_000).max(1);
            for (&i, record) in idxs.iter().zip(records) {
                results[i] = Some(BatchResult {
                    request_id: items[i].request_id,
                    record,
                    latency_us: now_us.saturating_sub(items[i].arrived_at_us) + store_us,
                });
            }
        }
        results.into_iter().map(|r| r.expect("all items answered")).collect()
    }
}

/// One queued write batch (shared `Arc` so the replication log can hold
/// the same allocation).
#[derive(Debug, Clone)]
struct WriteItem {
    table: String,
    records: Arc<[FeatureRecord]>,
    arrived_at_us: u64,
}

/// Micro-batcher for online **writes** — the streaming engine's
/// online-write stage. Record batches pushed within a short window are
/// coalesced and applied with one [`OnlineStore::merge`] per table per
/// flush (merge groups by shard internally, so shard write locks are
/// taken once per flush per table). Alg 2 is order-independent
/// convergent, so batching never changes the converged state.
///
/// `max_batch` counts *records*, not pushes. [`WriteBatcher::pending`]
/// is the backpressure signal: producers that see it grow past their
/// bound flush inline instead of queueing further.
pub struct WriteBatcher {
    cfg: BatcherConfig,
    queue: Mutex<VecDeque<WriteItem>>,
    pending_records: AtomicUsize,
    wake: Arc<Wake>,
}

impl WriteBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        WriteBatcher {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            pending_records: AtomicUsize::new(0),
            wake: Arc::new(Wake::default()),
        }
    }

    /// Enqueue a record batch; returns the queued record count after the
    /// push (the producer-side backpressure signal).
    pub fn push(&self, table: &str, records: Arc<[FeatureRecord]>, now_us: u64) -> usize {
        if records.is_empty() {
            return self.pending();
        }
        let n = records.len();
        let pending = {
            let mut q = self.queue.lock().unwrap();
            q.push_back(WriteItem { table: table.to_string(), records, arrived_at_us: now_us });
            // Count while holding the queue lock: flush subtracts under
            // the same lock, so the counter can never transiently go
            // negative (wrap) when a concurrent driver flushes the item
            // before this add landed.
            self.pending_records.fetch_add(n, Ordering::Relaxed) + n
        };
        self.wake.ping();
        pending
    }

    /// Backpressure-aware enqueue: sheds with a typed `Overloaded` error
    /// when `max_pending` records are already queued. Producers that
    /// would rather wait than drop keep using [`Self::push`] and flush
    /// inline past their bound (the streaming engine does); front ends
    /// facing untrusted load use this and bounce the overflow.
    pub fn try_push(
        &self,
        table: &str,
        records: Arc<[FeatureRecord]>,
        now_us: u64,
        max_pending: usize,
    ) -> Result<usize> {
        let queued = self.pending();
        if queued + records.len() > max_pending {
            return Err(FsError::Overloaded {
                resource: "write batcher".into(),
                reason: format!("pending {queued} + {} > {max_pending}", records.len()),
            });
        }
        Ok(self.push(table, records, now_us))
    }

    /// Queued records not yet merged.
    pub fn pending(&self) -> usize {
        self.pending_records.load(Ordering::Relaxed)
    }

    /// Size (records ≥ `max_batch`) or age (oldest waited `max_wait_us`)
    /// trigger.
    pub fn should_flush(&self, now_us: u64) -> bool {
        if self.pending() >= self.cfg.max_batch {
            return true;
        }
        let q = self.queue.lock().unwrap();
        q.front().is_some_and(|i| now_us.saturating_sub(i.arrived_at_us) >= self.cfg.max_wait_us)
    }

    /// Drain queued batches (whole batches, until ≥ `max_batch` records
    /// are taken) and merge them, one `OnlineStore::merge` per table in
    /// first-seen order. Returns records written.
    pub fn flush(&self, store: &OnlineStore, now: Timestamp, _now_us: u64) -> u64 {
        let items: Vec<WriteItem> = {
            let mut q = self.queue.lock().unwrap();
            let mut taken = Vec::new();
            let mut n = 0usize;
            while n < self.cfg.max_batch {
                let Some(item) = q.pop_front() else { break };
                n += item.records.len();
                taken.push(item);
            }
            self.pending_records.fetch_sub(n, Ordering::Relaxed);
            taken
        };
        if items.is_empty() {
            return 0;
        }
        // One shard-grouped merge per table, in arrival order.
        let batches: Vec<(&str, &[FeatureRecord])> =
            items.iter().map(|it| (it.table.as_str(), &it.records[..])).collect();
        store.merge_batches(&batches, now);
        items.iter().map(|it| it.records.len() as u64).sum()
    }

    /// Flush until the queue is empty — the checkpoint/drain barrier.
    pub fn drain(&self, store: &OnlineStore, now: Timestamp, now_us: u64) -> u64 {
        let mut written = 0;
        while self.pending() > 0 {
            written += self.flush(store, now, now_us);
        }
        written
    }

    /// Spawn the push-based background flush loop (honors `max_wait_us`
    /// on the wall clock; drains on drop). Producers must push with
    /// [`wall_us`] timestamps.
    pub fn spawn_driver(self: &Arc<Self>, store: Arc<OnlineStore>, clock: Clock) -> FlushDriver {
        let b = self.clone();
        let period = driver_period(&b.cfg);
        FlushDriver::spawn("geofs-write-flush", self.wake.clone(), period, move |final_pass| {
            let now_us = wall_us();
            if final_pass {
                b.drain(&store, clock.now(), now_us);
                return;
            }
            while b.should_flush(now_us) {
                if b.flush(&store, clock.now(), now_us) == 0 {
                    break;
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store_with(n: u64) -> OnlineStore {
        let s = OnlineStore::new(4);
        let recs: Vec<FeatureRecord> =
            (0..n).map(|i| FeatureRecord::new(i, 10, 20, vec![i as f32])).collect();
        s.merge("t", &recs, 20);
        s
    }

    #[test]
    fn batches_by_size_trigger() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 4, max_wait_us: 1_000_000 });
        let store = store_with(10);
        for e in 0..3 {
            b.push("t", e, 100);
        }
        assert!(!b.should_flush(100));
        b.push("t", 3, 101);
        assert!(b.should_flush(101));
        let out = b.flush(&store, 50, 150);
        assert_eq!(out.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_by_age_trigger() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 500 });
        b.push("t", 1, 1_000);
        assert!(!b.should_flush(1_400));
        assert!(b.should_flush(1_500));
    }

    #[test]
    fn results_match_requests_in_order() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(5);
        let ids: Vec<u64> = (0..5).map(|e| b.push("t", 4 - e, 10)).collect();
        let out = b.flush(&store, 100, 20);
        assert_eq!(out.len(), 5);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.request_id, ids[i]);
            assert_eq!(r.record.as_ref().unwrap().values[0], (4 - i as u64) as f32);
        }
    }

    #[test]
    fn mixed_tables_in_one_batch() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(3);
        let extra = vec![FeatureRecord::new(7, 10, 20, vec![70.0])];
        store.merge("other", &extra, 20);
        b.push("t", 1, 0);
        b.push("other", 7, 0);
        b.push("t", 2, 0);
        let out = b.flush(&store, 100, 5);
        assert_eq!(out[0].record.as_ref().unwrap().values[0], 1.0);
        assert_eq!(out[1].record.as_ref().unwrap().values[0], 70.0);
        assert_eq!(out[2].record.as_ref().unwrap().values[0], 2.0);
    }

    #[test]
    fn latency_includes_queue_wait() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(1);
        b.push("t", 0, 1_000);
        let out = b.flush(&store, 100, 1_800);
        assert!(out[0].latency_us >= 800, "queue wait must count: {}", out[0].latency_us);
    }

    #[test]
    fn drains_at_most_max_batch() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 2, max_wait_us: 0 });
        let store = store_with(10);
        for e in 0..5 {
            b.push("t", e, 0);
        }
        assert_eq!(b.flush(&store, 100, 1).len(), 2);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn empty_flush_is_noop() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(1);
        assert!(b.flush(&store, 100, 0).is_empty());
        assert!(!b.should_flush(1_000_000), "empty queue must never trigger a flush");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn successive_flushes_preserve_fifo_order() {
        // Items pushed across several flush cycles come back in global
        // FIFO order: flush k drains ids [k*max .. k*max + max).
        let b = MicroBatcher::new(BatcherConfig { max_batch: 3, max_wait_us: 0 });
        let store = store_with(10);
        let ids: Vec<u64> = (0..8).map(|e| b.push("t", e, 0)).collect();
        let mut seen = Vec::new();
        while b.pending() > 0 {
            let out = b.flush(&store, 100, 1);
            assert!(out.len() <= 3);
            seen.extend(out.iter().map(|r| r.request_id));
        }
        assert_eq!(seen, ids, "flush cycles must drain in arrival order");
    }

    #[test]
    fn request_ids_are_unique_and_monotonic() {
        let b = MicroBatcher::new(BatcherConfig::default());
        let ids: Vec<u64> = (0..50).map(|e| b.push("t", e % 7, e)).collect();
        for pair in ids.windows(2) {
            assert!(pair[1] > pair[0]);
        }
        assert_eq!(b.pending(), 50);
    }

    #[test]
    fn flush_results_match_per_key_gets() {
        // The grouped get_many execution must be observationally
        // identical to per-key point gets (same records, same store
        // hit/miss accounting for the batch).
        let b = MicroBatcher::new(BatcherConfig::default());
        let store = store_with(6);
        for e in [0u64, 9, 3, 5, 11] {
            b.push("t", e, 0);
        }
        let out = b.flush(&store, 100, 5);
        let batched_hits = store.hits.load(std::sync::atomic::Ordering::Relaxed);
        let batched_misses = store.misses.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!((batched_hits, batched_misses), (3, 2));
        for (r, e) in out.iter().zip([0u64, 9, 3, 5, 11]) {
            assert_eq!(r.record, store.get("t", e, 100), "entity {e}");
        }
    }

    #[test]
    fn age_trigger_fires_on_oldest_item() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 500 });
        b.push("t", 1, 1_000);
        b.push("t", 2, 1_400); // younger item must not reset the clock
        assert!(!b.should_flush(1_499));
        assert!(b.should_flush(1_500), "oldest item's age drives the trigger");
    }

    fn recs(lo: u64, hi: u64) -> Arc<[FeatureRecord]> {
        (lo..hi).map(|i| FeatureRecord::new(i, 10, 20, vec![i as f32])).collect()
    }

    #[test]
    fn write_batcher_coalesces_per_table() {
        let store = OnlineStore::new(4);
        let b = WriteBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 0 });
        assert_eq!(b.push("a", recs(0, 3), 0), 3);
        assert_eq!(b.push("b", recs(10, 12), 0), 5);
        assert_eq!(b.push("a", recs(3, 5), 0), 7);
        assert_eq!(b.pending(), 7);
        assert!(b.should_flush(1), "age trigger with max_wait 0");
        let written = b.flush(&store, 100, 1);
        assert_eq!(written, 7);
        assert_eq!(b.pending(), 0);
        for i in 0..5 {
            assert_eq!(store.get("a", i, 100).unwrap().values[0], i as f32);
        }
        assert!(store.get("b", 10, 100).is_some() && store.get("b", 11, 100).is_some());
        // Empty pushes are ignored; empty flush is a no-op.
        assert_eq!(b.push("a", recs(0, 0), 5), 0);
        assert_eq!(b.flush(&store, 100, 5), 0);
    }

    #[test]
    fn write_batcher_size_trigger_counts_records() {
        let b = WriteBatcher::new(BatcherConfig { max_batch: 4, max_wait_us: 1_000_000 });
        b.push("t", recs(0, 3), 0);
        assert!(!b.should_flush(0));
        b.push("t", recs(3, 6), 0); // 6 records ≥ 4
        assert!(b.should_flush(0));
        // Flush takes whole batches until ≥ max_batch records.
        let store = OnlineStore::new(2);
        assert_eq!(b.flush(&store, 100, 0), 6);
    }

    #[test]
    fn write_batcher_flush_equals_direct_merges() {
        // Batched writes converge to exactly the per-batch merge state,
        // duplicates and late versions included (Alg 2 order freedom).
        let direct = OnlineStore::new(2);
        let batched = OnlineStore::new(2);
        let b = WriteBatcher::new(BatcherConfig { max_batch: 3, max_wait_us: 0 });
        let batches: Vec<Arc<[FeatureRecord]>> = vec![
            [FeatureRecord::new(1, 10, 11, vec![1.0])].into(),
            [FeatureRecord::new(1, 10, 30, vec![2.0]), FeatureRecord::new(2, 5, 6, vec![3.0])].into(),
            [FeatureRecord::new(1, 9, 99, vec![9.0])].into(), // stale event: no-op
        ];
        for batch in &batches {
            direct.merge("t", batch, 50);
            b.push("t", batch.clone(), 0);
        }
        b.drain(&batched, 50, 1);
        for e in [1u64, 2] {
            assert_eq!(
                batched.get("t", e, 60).map(|r| (r.version(), r.values.clone())),
                direct.get("t", e, 60).map(|r| (r.version(), r.values.clone())),
            );
        }
    }

    #[test]
    fn read_try_push_sheds_at_depth_bound() {
        let b = MicroBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 1_000_000 });
        let store = store_with(4);
        for e in 0..3 {
            b.try_push("t", e, 0, 3).unwrap();
        }
        match b.try_push("t", 3, 0, 3) {
            Err(FsError::Overloaded { ref resource, .. }) => assert_eq!(resource, "read batcher"),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // Flushing frees the queue; pushes admit again.
        assert_eq!(b.flush(&store, 100, 1).len(), 3);
        b.try_push("t", 3, 2, 3).unwrap();
    }

    #[test]
    fn write_try_push_sheds_at_record_bound() {
        let store = OnlineStore::new(2);
        let b = WriteBatcher::new(BatcherConfig { max_batch: 100, max_wait_us: 0 });
        b.try_push("t", recs(0, 4), 0, 6).unwrap();
        // 4 queued + 3 incoming > 6 → shed, queue untouched.
        assert!(matches!(
            b.try_push("t", recs(4, 7), 0, 6),
            Err(FsError::Overloaded { .. })
        ));
        assert_eq!(b.pending(), 4);
        // A batch that fits the remaining headroom is admitted.
        b.try_push("t", recs(4, 6), 0, 6).unwrap();
        assert_eq!(b.pending(), 6);
        b.drain(&store, 100, 1);
        b.try_push("t", recs(6, 8), 2, 6).unwrap();
    }

    #[test]
    fn write_driver_flushes_in_background() {
        let store = Arc::new(OnlineStore::new(2));
        let b = Arc::new(WriteBatcher::new(BatcherConfig { max_batch: 1_000, max_wait_us: 2_000 }));
        let driver = b.spawn_driver(store.clone(), Clock::fixed(100));
        b.push("t", recs(0, 4), wall_us());
        // Age trigger (~2ms) must fire without any manual flush.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while b.pending() > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(b.pending(), 0, "driver must flush by age");
        assert!(store.get("t", 0, 100).is_some());
        // Drop drains whatever is still queued.
        b.push("t", recs(4, 8), wall_us());
        drop(driver);
        assert_eq!(b.pending(), 0, "driver drop must drain");
        assert!(store.get("t", 7, 100).is_some());
    }

    #[test]
    fn read_driver_delivers_results_to_sink() {
        let store = Arc::new(store_with(8));
        let b = Arc::new(MicroBatcher::new(BatcherConfig { max_batch: 4, max_wait_us: 1_000 }));
        let got: Arc<Mutex<Vec<BatchResult>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = got.clone();
        let driver = b.spawn_driver(store.clone(), Clock::fixed(50), move |out| {
            sink.lock().unwrap().extend(out);
        });
        for e in 0..4 {
            b.push("t", e, wall_us()); // full batch → size trigger + wake
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while got.lock().unwrap().len() < 4 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        drop(driver);
        let results = got.lock().unwrap();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.record.is_some()));
    }
}
