//! Request routing: table → home region + access mechanism.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::geo::access::CrossRegionAccess;
use crate::types::{FsError, Result};

/// Routing table: feature-set table name → its access router.
#[derive(Default)]
pub struct RouteTable {
    routes: RwLock<HashMap<String, Arc<CrossRegionAccess>>>,
}

impl RouteTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, table: &str, access: Arc<CrossRegionAccess>) {
        self.routes.write().unwrap().insert(table.to_string(), access);
    }

    pub fn get(&self, table: &str) -> Result<Arc<CrossRegionAccess>> {
        self.routes
            .read()
            .unwrap()
            .get(table)
            .cloned()
            .ok_or_else(|| FsError::NotFound(format!("route for table '{table}'")))
    }

    pub fn tables(&self) -> Vec<String> {
        let mut t: Vec<_> = self.routes.read().unwrap().keys().cloned().collect();
        t.sort();
        t
    }
}

/// The serving router: consults the route table per request. Thin by
/// design — mechanism choice lives in `geo::access`, so the router's job
/// is table resolution and failover redirection.
pub struct ServingRouter {
    pub routes: Arc<RouteTable>,
}

impl ServingRouter {
    pub fn new(routes: Arc<RouteTable>) -> Self {
        ServingRouter { routes }
    }

    /// Resolve the router for a table, verifying the home region is up
    /// (a down home with no replica is a routable error the caller can
    /// surface distinctly).
    pub fn resolve(&self, table: &str, consumer_region: &str) -> Result<Arc<CrossRegionAccess>> {
        let access = self.routes.get(table)?;
        // If the home region is down and the consumer can't be served
        // locally/replica, surface RegionDown.
        let mech = access.route(consumer_region);
        if mech == crate::geo::access::AccessMechanism::CrossRegion
            && !access.topology.is_up(&access.home_region)
        {
            return Err(FsError::RegionDown(access.home_region.clone()));
        }
        Ok(access)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::topology::GeoTopology;
    use crate::online_store::OnlineStore;

    fn access(home: &str, topology: Arc<GeoTopology>) -> Arc<CrossRegionAccess> {
        Arc::new(CrossRegionAccess {
            topology,
            home_region: home.into(),
            home_store: Arc::new(OnlineStore::new(2)),
            fabric: None,
            geo_fenced: false,
        })
    }

    #[test]
    fn resolves_registered_tables() {
        let topology = Arc::new(GeoTopology::default_four_region());
        let routes = Arc::new(RouteTable::new());
        routes.set("txn:1", access("eastus", topology.clone()));
        let r = ServingRouter::new(routes.clone());
        assert!(r.resolve("txn:1", "westus").is_ok());
        assert!(matches!(r.resolve("nope:1", "westus"), Err(FsError::NotFound(_))));
        assert_eq!(routes.tables(), vec!["txn:1"]);
    }

    #[test]
    fn surfaces_home_region_down() {
        let topology = Arc::new(GeoTopology::default_four_region());
        let routes = Arc::new(RouteTable::new());
        routes.set("txn:1", access("eastus", topology.clone()));
        let r = ServingRouter::new(routes);
        topology.set_down("eastus", true);
        assert!(matches!(r.resolve("txn:1", "westus"), Err(FsError::RegionDown(_))));
        // Local consumer in the down region also fails at lookup time,
        // but resolution for the *home* consumer is the geo layer's call.
    }
}
