//! Admission control for the serving front end (§2.1 "Enterprise grade
//! SLAs"): graceful degradation instead of queueing without bound.
//!
//! A managed store's online path must keep serving its p99 for admitted
//! traffic even when one tenant (or one hot table) offers more load than
//! the store can absorb. The [`AdmissionController`] sits in front of
//! every routed read:
//!
//! * **Per-tenant and per-table token buckets** — sustained rate plus a
//!   burst allowance, refilled continuously from a microsecond
//!   timestamp. A request costs its key count, so batch size and request
//!   count are interchangeable against the same budget.
//! * **Queue-depth-aware shedding** — a bounded in-flight permit count.
//!   When the serving queue is full the request is shed *immediately*
//!   with a typed [`FsError::Overloaded`] rather than parked; latency of
//!   admitted requests stays bounded because nothing waits behind an
//!   unbounded backlog.
//! * **RAII permits** — an admitted request holds a [`Permit`] for its
//!   lifetime; dropping it (normally or on panic/error) releases the
//!   in-flight slot, so shedding recovers as soon as load does.
//!
//! Timestamps are passed in explicitly (`now_us`, microseconds on the
//! [`super::wall_us`] timebase) rather than read inside, which makes the
//! rate+burst bound a deterministic property the admission tests can pin
//! without sleeping.
//!
//! `Overloaded` is intentionally **not** classified transient: the whole
//! point of shedding is to push work back to the caller's backoff loop,
//! not into an inline retry storm (see `types/error.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::types::{FsError, Result};

/// Continuous-refill token bucket: `rate_per_sec` sustained, `burst`
/// capacity. A non-finite rate admits everything (the "unlimited"
/// default), so enabling admission control only constrains the tenants
/// and tables an operator actually bounds.
#[derive(Debug)]
pub struct TokenBucket {
    rate_per_sec: f64,
    burst: f64,
    /// (available tokens, last refill timestamp µs).
    state: Mutex<(f64, u64)>,
}

impl TokenBucket {
    pub fn new(rate_per_sec: f64, burst: f64) -> TokenBucket {
        TokenBucket { rate_per_sec, burst: burst.max(0.0), state: Mutex::new((burst.max(0.0), 0)) }
    }

    /// Take `cost` tokens at `now_us` if available. Never blocks; a
    /// shortfall is a shed, not a wait.
    pub fn try_acquire(&self, cost: f64, now_us: u64) -> bool {
        if !self.rate_per_sec.is_finite() {
            return true;
        }
        let mut st = self.state.lock().unwrap();
        let (ref mut tokens, ref mut last_us) = *st;
        if now_us > *last_us {
            let dt = (now_us - *last_us) as f64 / 1e6;
            *tokens = (*tokens + dt * self.rate_per_sec).min(self.burst);
            *last_us = now_us;
        }
        if *tokens >= cost {
            *tokens -= cost;
            true
        } else {
            false
        }
    }

    /// Currently available tokens (test hook; refills to `now_us` first).
    pub fn available(&self, now_us: u64) -> f64 {
        let mut st = self.state.lock().unwrap();
        let (ref mut tokens, ref mut last_us) = *st;
        if self.rate_per_sec.is_finite() && now_us > *last_us {
            let dt = (now_us - *last_us) as f64 / 1e6;
            *tokens = (*tokens + dt * self.rate_per_sec).min(self.burst);
            *last_us = now_us;
        }
        *tokens
    }
}

/// Admission policy. Defaults are fully open (infinite rates, unbounded
/// queue): wiring the controller in changes nothing until an operator
/// sets a bound.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Sustained per-tenant budget, in key-lookups per second.
    pub tenant_rate: f64,
    /// Per-tenant burst capacity (bucket size), in key-lookups.
    pub tenant_burst: f64,
    /// Sustained per-table budget, in key-lookups per second.
    pub table_rate: f64,
    /// Per-table burst capacity, in key-lookups.
    pub table_burst: f64,
    /// Maximum requests holding permits at once; above this the serving
    /// queue sheds instead of deepening.
    pub max_inflight: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            tenant_rate: f64::INFINITY,
            tenant_burst: f64::INFINITY,
            table_rate: f64::INFINITY,
            table_burst: f64::INFINITY,
            max_inflight: usize::MAX,
        }
    }
}

/// RAII in-flight slot: held for the lifetime of an admitted request,
/// released (even on panic) when dropped.
pub struct Permit {
    inflight: Arc<AtomicUsize>,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let now = self.inflight.fetch_sub(1, Ordering::AcqRel) - 1;
        if let Some(m) = &self.metrics {
            m.set_gauge(MetricKind::System, names::ADMISSION_INFLIGHT, now as f64);
        }
    }
}

/// The serving-front-end admission gate. Cheap to share (`Arc`) and to
/// consult: one atomic for queue depth, one small mutex per bucket.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    tenants: Mutex<HashMap<String, Arc<TokenBucket>>>,
    tables: Mutex<HashMap<String, Arc<TokenBucket>>>,
    inflight: Arc<AtomicUsize>,
    admitted: AtomicU64,
    shed: AtomicU64,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig, metrics: Option<Arc<MetricsRegistry>>) -> Arc<Self> {
        Arc::new(AdmissionController {
            cfg,
            tenants: Mutex::new(HashMap::new()),
            tables: Mutex::new(HashMap::new()),
            inflight: Arc::new(AtomicUsize::new(0)),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            metrics,
        })
    }

    /// Override one tenant's budget (multi-tenant isolation: a noisy
    /// neighbour gets a tighter bucket without touching anyone else).
    pub fn set_tenant_rate(&self, tenant: &str, rate_per_sec: f64, burst: f64) {
        self.tenants
            .lock()
            .unwrap()
            .insert(tenant.to_string(), Arc::new(TokenBucket::new(rate_per_sec, burst)));
    }

    /// Override one table's budget.
    pub fn set_table_rate(&self, table: &str, rate_per_sec: f64, burst: f64) {
        self.tables
            .lock()
            .unwrap()
            .insert(table.to_string(), Arc::new(TokenBucket::new(rate_per_sec, burst)));
    }

    fn bucket(
        map: &Mutex<HashMap<String, Arc<TokenBucket>>>,
        key: &str,
        rate: f64,
        burst: f64,
    ) -> Arc<TokenBucket> {
        let mut map = map.lock().unwrap();
        map.entry(key.to_string())
            .or_insert_with(|| Arc::new(TokenBucket::new(rate, burst)))
            .clone()
    }

    /// Admit or shed one request of `cost` key-lookups. Checks queue
    /// depth first (an over-deep queue sheds regardless of budget), then
    /// the tenant bucket, then the table bucket. On admission the
    /// returned [`Permit`] holds the in-flight slot; tokens already
    /// taken from the tenant bucket are *not* refunded if the table
    /// bucket then sheds — the work of reaching the table gate was real.
    pub fn admit(&self, tenant: &str, table: &str, cost: f64, now_us: u64) -> Result<Permit> {
        // Reserve the slot optimistically; back out on shed.
        let depth = self.inflight.fetch_add(1, Ordering::AcqRel);
        if depth >= self.cfg.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(self.shed(
                "serving queue",
                format!("inflight {} >= {}", depth, self.cfg.max_inflight),
            ));
        }
        let tb = Self::bucket(&self.tenants, tenant, self.cfg.tenant_rate, self.cfg.tenant_burst);
        if !tb.try_acquire(cost, now_us) {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(self.shed(
                &format!("tenant '{tenant}'"),
                format!("rate budget exhausted (cost {cost})"),
            ));
        }
        let tbl = Self::bucket(&self.tables, table, self.cfg.table_rate, self.cfg.table_burst);
        if !tbl.try_acquire(cost, now_us) {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(self.shed(
                &format!("table '{table}'"),
                format!("rate budget exhausted (cost {cost})"),
            ));
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.inc(MetricKind::System, names::ADMISSION_ADMITTED, 1);
            m.set_gauge(MetricKind::System, names::ADMISSION_INFLIGHT, (depth + 1) as f64);
        }
        Ok(Permit { inflight: self.inflight.clone(), metrics: self.metrics.clone() })
    }

    fn shed(&self, resource: &str, reason: String) -> FsError {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.inc(MetricKind::System, names::ADMISSION_SHED, 1);
        }
        FsError::Overloaded { resource: resource.to_string(), reason }
    }

    /// Requests admitted since construction.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Requests shed since construction.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Requests currently holding permits.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_burst_then_refill() {
        let b = TokenBucket::new(10.0, 5.0); // 10/s, burst 5
        for _ in 0..5 {
            assert!(b.try_acquire(1.0, 0));
        }
        assert!(!b.try_acquire(1.0, 0), "burst exhausted");
        // 300ms refills 3 tokens.
        assert!(b.try_acquire(3.0, 300_000));
        assert!(!b.try_acquire(1.0, 300_000));
        // Refill caps at burst no matter how long we wait.
        assert!((b.available(100_000_000) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn bucket_infinite_rate_always_admits() {
        let b = TokenBucket::new(f64::INFINITY, 0.0);
        for _ in 0..1000 {
            assert!(b.try_acquire(1e9, 0));
        }
    }

    #[test]
    fn bucket_ignores_time_regression() {
        let b = TokenBucket::new(10.0, 2.0);
        assert!(b.try_acquire(2.0, 1_000_000));
        // An earlier timestamp must not mint tokens.
        assert!(!b.try_acquire(1.0, 0));
    }

    #[test]
    fn default_config_is_fully_open() {
        let ctrl = AdmissionController::new(AdmissionConfig::default(), None);
        for _ in 0..100 {
            let p = ctrl.admit("anyone", "any_table", 1e6, 0).expect("open by default");
            drop(p);
        }
        assert_eq!(ctrl.admitted(), 100);
        assert_eq!(ctrl.shed_count(), 0);
    }

    #[test]
    fn queue_depth_sheds_and_recovers() {
        let cfg = AdmissionConfig { max_inflight: 2, ..Default::default() };
        let ctrl = AdmissionController::new(cfg, None);
        let p1 = ctrl.admit("a", "t", 1.0, 0).unwrap();
        let _p2 = ctrl.admit("a", "t", 1.0, 0).unwrap();
        assert_eq!(ctrl.inflight(), 2);
        let err = ctrl.admit("a", "t", 1.0, 0).unwrap_err();
        match err {
            FsError::Overloaded { ref resource, .. } => {
                assert_eq!(resource, "serving queue")
            }
            other => panic!("expected Overloaded, got {other}"),
        }
        drop(p1);
        assert_eq!(ctrl.inflight(), 1);
        let _p3 = ctrl.admit("a", "t", 1.0, 0).expect("slot freed by drop");
    }

    #[test]
    fn tenant_isolation() {
        let cfg = AdmissionConfig {
            tenant_rate: 0.0,
            tenant_burst: 3.0,
            ..Default::default()
        };
        let ctrl = AdmissionController::new(cfg, None);
        for _ in 0..3 {
            ctrl.admit("greedy", "t", 1.0, 0).unwrap();
        }
        assert!(matches!(
            ctrl.admit("greedy", "t", 1.0, 0),
            Err(FsError::Overloaded { .. })
        ));
        // A different tenant's bucket is untouched.
        ctrl.admit("polite", "t", 1.0, 0).expect("separate tenant budget");
    }

    #[test]
    fn table_bucket_sheds_after_tenant_admits() {
        let cfg = AdmissionConfig {
            table_rate: 0.0,
            table_burst: 2.0,
            ..Default::default()
        };
        let ctrl = AdmissionController::new(cfg, None);
        ctrl.admit("a", "hot", 1.0, 0).unwrap();
        ctrl.admit("b", "hot", 1.0, 0).unwrap();
        let err = ctrl.admit("c", "hot", 1.0, 0).unwrap_err();
        assert!(err.to_string().contains("hot"), "{err}");
        ctrl.admit("c", "cold", 1.0, 0).expect("separate table budget");
        assert_eq!(ctrl.admitted(), 3);
        assert_eq!(ctrl.shed_count(), 1);
    }

    #[test]
    fn per_tenant_override() {
        let ctrl = AdmissionController::new(AdmissionConfig::default(), None);
        ctrl.set_tenant_rate("noisy", 0.0, 1.0);
        ctrl.admit("noisy", "t", 1.0, 0).unwrap();
        assert!(ctrl.admit("noisy", "t", 1.0, 0).is_err());
        ctrl.admit("other", "t", 100.0, 0).expect("default stays open");
    }

    #[test]
    fn counters_and_metrics() {
        let metrics = Arc::new(MetricsRegistry::new());
        let cfg = AdmissionConfig { tenant_rate: 0.0, tenant_burst: 1.0, ..Default::default() };
        let ctrl = AdmissionController::new(cfg, Some(metrics.clone()));
        let p = ctrl.admit("a", "t", 1.0, 0).unwrap();
        assert!(ctrl.admit("a", "t", 1.0, 0).is_err());
        assert_eq!(ctrl.admitted(), 1);
        assert_eq!(ctrl.shed_count(), 1);
        assert_eq!(metrics.counter("admission_admitted"), 1);
        assert_eq!(metrics.counter("admission_shed"), 1);
        assert_eq!(metrics.gauge("admission_inflight"), Some(1.0));
        drop(p);
        assert_eq!(metrics.gauge("admission_inflight"), Some(0.0));
    }
}
