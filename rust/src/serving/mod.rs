//! Online serving (§2.1 "Online feature retrieval to support feature
//! retrieval with low latency").
//!
//! The request path: [`router`] picks the region/mechanism (delegating to
//! `geo::access`), [`batcher`] micro-batches point lookups to amortize
//! store access, and [`service`] ties them together with latency metrics
//! feeding the SLA machinery.

pub mod batcher;
pub mod router;
pub mod service;

pub use batcher::{BatchItem, MicroBatcher};
pub use router::{RouteTable, ServingRouter};
pub use service::OnlineServing;
