//! Online serving (§2.1 "Online feature retrieval to support feature
//! retrieval with low latency").
//!
//! # The batched read path
//!
//! The request hot path is built around batches end to end:
//!
//! 1. [`batcher`] — point lookups arriving within a short window are
//!    coalesced by the [`MicroBatcher`]; a flush drains up to
//!    `max_batch` queued lookups and issues **one** `get_many` per
//!    table in the batch.
//! 2. [`router`] — resolves the table to its geo access router once per
//!    request/batch (home region, replica, or cross-region, per
//!    compliance policy) and surfaces region outages.
//! 3. [`service`] — [`OnlineServing::lookup_batch`] executes the routed
//!    batch via `CrossRegionAccess::lookup_many`, paying the WAN round
//!    trip **once per batch** instead of once per key, and feeds
//!    latency + hit/miss metrics into the SLA machinery.
//!
//! Underneath, `OnlineStore::get_many` groups the batch's keys by shard
//! and takes each shard lock exactly once; point reads never take a
//! store-global lock (see the `online_store` module docs for the
//! snapshot/generation design). Together this makes batch size the
//! lever that amortizes *both* store synchronization and simulated WAN
//! cost — experiment E9 in `benches/online_retrieval.rs` measures it.
//!
//! # Overload behavior
//!
//! In front of the routed read sits [`admission`]: per-tenant/per-table
//! token buckets plus a bounded in-flight permit count. Past saturation
//! the front end sheds with a typed `Overloaded` error instead of
//! letting queues deepen, so the p99 of *admitted* requests stays
//! bounded (experiment E-LOAD in `benches/load_harness.rs` measures
//! the shed/latency trade under ≥2× saturation). The batchers expose
//! the same contract on the write side via `try_push` pending-depth
//! bounds.

pub mod admission;
pub mod batcher;
pub mod router;
pub mod service;

pub use admission::{AdmissionConfig, AdmissionController, Permit, TokenBucket};
pub use batcher::{wall_us, BatchItem, BatcherConfig, FlushDriver, MicroBatcher, WriteBatcher};
pub use router::{RouteTable, ServingRouter};
pub use service::OnlineServing;
