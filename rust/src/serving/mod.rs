//! Online serving (§2.1 "Online feature retrieval to support feature
//! retrieval with low latency").
//!
//! # The batched read path
//!
//! The request hot path is built around batches end to end:
//!
//! 1. [`batcher`] — point lookups arriving within a short window are
//!    coalesced by the [`MicroBatcher`]; a flush drains up to
//!    `max_batch` queued lookups and issues **one** `get_many` per
//!    table in the batch.
//! 2. [`router`] — resolves the table to its geo access router once per
//!    request/batch (home region, replica, or cross-region, per
//!    compliance policy) and surfaces region outages.
//! 3. [`service`] — [`OnlineServing::lookup_batch`] executes the routed
//!    batch via `CrossRegionAccess::lookup_many`, paying the WAN round
//!    trip **once per batch** instead of once per key, and feeds
//!    latency + hit/miss metrics into the SLA machinery.
//!
//! Underneath, `OnlineStore` reads are wait-free with respect to
//! writers — seqlock bucket probes, no reader-visible locks at all —
//! and `get_many` amortizes the snapshot load and TTL resolution over
//! the batch (see the `online_store` module docs for the
//! seqlock/snapshot design). Together this makes batch size the lever
//! that amortizes per-request overhead and simulated WAN cost —
//! experiments E9a–E9f in `benches/online_retrieval.rs` measure it,
//! E9f specifically the read-vs-write non-interference.
//!
//! # Overload behavior
//!
//! In front of the routed read sits [`admission`]: per-tenant/per-table
//! token buckets plus a bounded in-flight permit count. Past saturation
//! the front end sheds with a typed `Overloaded` error instead of
//! letting queues deepen, so the p99 of *admitted* requests stays
//! bounded (experiment E-LOAD in `benches/load_harness.rs` measures
//! the shed/latency trade under ≥2× saturation). The batchers expose
//! the same contract on the write side via `try_push` pending-depth
//! bounds.

pub mod admission;
pub mod batcher;
pub mod router;
pub mod service;

pub use admission::{AdmissionConfig, AdmissionController, Permit, TokenBucket};
pub use batcher::{wall_us, BatchItem, BatcherConfig, FlushDriver, MicroBatcher, WriteBatcher};
pub use router::{RouteTable, ServingRouter};
pub use service::OnlineServing;
