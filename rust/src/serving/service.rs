//! The online serving front end: router + geo access + metrics.
//!
//! Two read paths:
//!
//! * [`OnlineServing::lookup`] — one point read, one routing decision.
//! * [`OnlineServing::lookup_batch`] / [`OnlineServing::lookup_many`] —
//!   the batched path: one routing decision and **one** WAN round trip
//!   for the whole key set, served by the store's lock-free `get_many`.
//!   This is what the [`super::batcher::MicroBatcher`] drains into.

use std::sync::Arc;

use super::admission::AdmissionController;
use super::batcher::wall_us;
use super::router::ServingRouter;
use crate::geo::access::{AccessMechanism, ReadConsistency, RoutedBatch, RoutedLookup};
use crate::monitor::metrics::{Counter, LatencyHandle, MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::monitor::trace::{TraceContext, Tracer};
use crate::types::{EntityId, Result, Timestamp};

const MECHS: [AccessMechanism; 3] =
    [AccessMechanism::Local, AccessMechanism::CrossRegion, AccessMechanism::Replica];

fn mech_label(m: AccessMechanism) -> &'static str {
    match m {
        AccessMechanism::Local => "local",
        AccessMechanism::CrossRegion => "xregion",
        AccessMechanism::Replica => "replica",
    }
}

fn mech_idx(m: AccessMechanism) -> usize {
    match m {
        AccessMechanism::Local => 0,
        AccessMechanism::CrossRegion => 1,
        AccessMechanism::Replica => 2,
    }
}

/// Hot-path metric handles, pre-registered at construction so a lookup
/// records its latency and hit/miss outcome with a few relaxed atomic
/// RMWs — no name lookup, no lock, no allocation. Pre-registration also
/// means every serving series exists in `export()` from the first
/// scrape, whether or not its mechanism has been exercised yet.
struct ServingMetrics {
    hits: Counter,
    misses: Counter,
    batches: Counter,
    /// Point-lookup latency per access mechanism, indexed by `mech_idx`.
    latency: [LatencyHandle; 3],
    /// Batch-lookup latency per access mechanism, indexed by `mech_idx`.
    batch_latency: [LatencyHandle; 3],
}

impl ServingMetrics {
    fn new(m: &MetricsRegistry) -> Self {
        ServingMetrics {
            hits: m.counter_handle(MetricKind::System, names::SERVING_HITS),
            misses: m.counter_handle(MetricKind::System, names::SERVING_MISSES),
            batches: m.counter_handle(MetricKind::System, names::SERVING_BATCHES),
            latency: MECHS.map(|mech| {
                m.latency_handle(MetricKind::System, &names::serving_latency_us(mech_label(mech)))
            }),
            batch_latency: MECHS.map(|mech| {
                m.latency_handle(
                    MetricKind::System,
                    &names::serving_batch_latency_us(mech_label(mech)),
                )
            }),
        }
    }
}

/// Serving facade used by the coordinator and the benches.
pub struct OnlineServing {
    pub router: ServingRouter,
    pub metrics: Arc<MetricsRegistry>,
    /// Admission gate for tenant-attributed reads; `None` = fully open.
    pub admission: Option<Arc<AdmissionController>>,
    /// Request tracer for the admitted batch path; `None` = untraced.
    pub tracer: Option<Arc<Tracer>>,
    stats: ServingMetrics,
}

impl OnlineServing {
    pub fn new(router: ServingRouter, metrics: Arc<MetricsRegistry>) -> Self {
        let stats = ServingMetrics::new(&metrics);
        OnlineServing { router, metrics, admission: None, tracer: None, stats }
    }

    /// A serving front end with an admission gate in front of the
    /// tenant-attributed batch path.
    pub fn with_admission(
        router: ServingRouter,
        metrics: Arc<MetricsRegistry>,
        admission: Arc<AdmissionController>,
    ) -> Self {
        let stats = ServingMetrics::new(&metrics);
        OnlineServing { router, metrics, admission: Some(admission), tracer: None, stats }
    }

    /// One online feature lookup from `consumer_region` under a
    /// consistency policy. Records latency and hit/miss metrics per
    /// mechanism.
    pub fn lookup(
        &self,
        table: &str,
        entity: EntityId,
        consumer_region: &str,
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<RoutedLookup> {
        let access = self.router.resolve(table, consumer_region)?;
        let out = access.lookup(consumer_region, table, entity, now, consistency)?;
        // store ns in the histogram
        self.stats.latency[mech_idx(out.mechanism)].observe(out.latency_us * 1_000);
        if out.record.is_some() {
            self.stats.hits.inc(1);
        } else {
            self.stats.misses.inc(1);
        }
        Ok(out)
    }

    /// The batched lookup endpoint: resolve the route once, then serve
    /// the whole key set with one `CrossRegionAccess::lookup_many` (one
    /// WAN round trip, one snapshot load; the per-key probes are
    /// lock-free). Records batch latency and per-key hit/miss metrics.
    pub fn lookup_batch(
        &self,
        table: &str,
        entities: &[EntityId],
        consumer_region: &str,
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<RoutedBatch> {
        self.lookup_batch_traced(table, entities, consumer_region, now, consistency, None)
    }

    fn lookup_batch_traced(
        &self,
        table: &str,
        entities: &[EntityId],
        consumer_region: &str,
        now: Timestamp,
        consistency: &ReadConsistency,
        trace: Option<&TraceContext>,
    ) -> Result<RoutedBatch> {
        let access = self.router.resolve(table, consumer_region)?;
        let out =
            access.lookup_many_traced(consumer_region, table, entities, now, consistency, trace)?;
        // store ns in the histogram
        self.stats.batch_latency[mech_idx(out.mechanism)].observe(out.latency_us * 1_000);
        let hits = out.records.iter().filter(|r| r.is_some()).count() as u64;
        self.stats.hits.inc(hits);
        self.stats.misses.inc(out.records.len() as u64 - hits);
        self.stats.batches.inc(1);
        Ok(out)
    }

    /// The tenant-attributed batch endpoint: pass the request through
    /// the admission gate (cost = key count), then serve it as one
    /// routed batch. The permit is held for the duration of the lookup
    /// so the in-flight bound tracks requests actually being served.
    /// Sheds with a typed `Overloaded` error; with no admission
    /// controller configured it is exactly [`Self::lookup_batch`].
    ///
    /// This is the traced entry point: when a [`Tracer`] is wired and
    /// samples the request, the admission wait, the routing decision
    /// (with chosen consistency/staleness) and the store fan-out all
    /// land in one span tree.
    pub fn lookup_batch_admitted(
        &self,
        tenant: &str,
        table: &str,
        entities: &[EntityId],
        consumer_region: &str,
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<RoutedBatch> {
        let trace = self.tracer.as_ref().and_then(|t| t.maybe_trace("online_read"));
        if let Some(t) = &trace {
            t.event(
                "request",
                format!(
                    "tenant={tenant} table={table} keys={} region={consumer_region}",
                    entities.len()
                ),
            );
        }
        let _permit = match &self.admission {
            Some(ctrl) => {
                let g = trace.as_ref().map(|t| t.span("admission"));
                match ctrl.admit(tenant, table, entities.len() as f64, wall_us()) {
                    Ok(p) => {
                        drop(g);
                        Some(p)
                    }
                    Err(e) => {
                        drop(g);
                        if let Some(t) = &trace {
                            t.event("shed", format!("{e}"));
                            t.finish();
                        }
                        return Err(e);
                    }
                }
            }
            None => None,
        };
        let out = self.lookup_batch_traced(
            table,
            entities,
            consumer_region,
            now,
            consistency,
            trace.as_deref(),
        );
        if let Some(t) = &trace {
            t.finish();
        }
        out
    }

    /// Batched lookup of many entities (bulk inference). Returns
    /// per-entity results in order. Internally a single routed batch —
    /// each returned item carries the batch's mechanism/latency, not a
    /// per-key WAN cost.
    pub fn lookup_many(
        &self,
        table: &str,
        entities: &[EntityId],
        consumer_region: &str,
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<Vec<RoutedLookup>> {
        let batch = self.lookup_batch(table, entities, consumer_region, now, consistency)?;
        Ok(batch
            .records
            .into_iter()
            .map(|record| RoutedLookup {
                record,
                mechanism: batch.mechanism,
                latency_us: batch.latency_us,
                staleness_secs: batch.staleness_secs,
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::access::CrossRegionAccess;
    use crate::geo::topology::GeoTopology;
    use crate::online_store::OnlineStore;
    use crate::serving::router::RouteTable;
    use crate::types::FeatureRecord;

    fn serving() -> (OnlineServing, Arc<OnlineStore>) {
        let topology = Arc::new(GeoTopology::default_four_region());
        let store = Arc::new(OnlineStore::new(2));
        store.merge("t", &[FeatureRecord::new(1, 10, 20, vec![5.0])], 20);
        let routes = Arc::new(RouteTable::new());
        routes.set(
            "t",
            Arc::new(CrossRegionAccess {
                topology,
                home_region: "eastus".into(),
                home_store: store.clone(),
                fabric: None,
                geo_fenced: false,
            }),
        );
        (
            OnlineServing::new(ServingRouter::new(routes), Arc::new(MetricsRegistry::new())),
            store,
        )
    }

    #[test]
    fn lookup_records_metrics() {
        let (s, _) = serving();
        let out = s.lookup("t", 1, "eastus", 100, &ReadConsistency::default()).unwrap();
        assert_eq!(out.record.unwrap().values[0], 5.0);
        let _ = s.lookup("t", 999, "westus", 100, &ReadConsistency::default()).unwrap();
        assert_eq!(s.metrics.counter("serving_hits"), 1);
        assert_eq!(s.metrics.counter("serving_misses"), 1);
        assert!(s.metrics.latency_quantile("serving_latency_us_local", 0.5).is_some());
        assert!(s.metrics.latency_quantile("serving_latency_us_xregion", 0.5).is_some());
    }

    #[test]
    fn lookup_many_ordered() {
        let (s, store) = serving();
        store.merge("t", &[FeatureRecord::new(2, 10, 20, vec![6.0])], 20);
        let out = s.lookup_many("t", &[2, 1], "eastus", 100, &ReadConsistency::default()).unwrap();
        assert_eq!(out[0].record.as_ref().unwrap().values[0], 6.0);
        assert_eq!(out[1].record.as_ref().unwrap().values[0], 5.0);
    }

    #[test]
    fn lookup_batch_records_batch_metrics() {
        let (s, store) = serving();
        store.merge("t", &[FeatureRecord::new(2, 10, 20, vec![6.0])], 20);
        let batch = s.lookup_batch("t", &[1, 2, 42], "westus", 100, &ReadConsistency::default()).unwrap();
        assert_eq!(batch.mechanism, AccessMechanism::CrossRegion);
        assert_eq!(batch.records.len(), 3);
        assert_eq!(s.metrics.counter("serving_hits"), 2);
        assert_eq!(s.metrics.counter("serving_misses"), 1);
        assert_eq!(s.metrics.counter("serving_batches"), 1);
        assert!(s.metrics.latency_quantile("serving_batch_latency_us_xregion", 0.5).is_some());
        // One WAN round trip (60ms for eastus↔westus) for the whole batch.
        assert!(batch.latency_us >= 60_000 && batch.latency_us < 120_000, "{}", batch.latency_us);
    }

    #[test]
    fn admitted_batch_path_sheds_past_burst() {
        use crate::serving::admission::{AdmissionConfig, AdmissionController};
        use crate::types::FsError;
        let (open, _) = serving();
        // Rebuild with a tight tenant budget: 3 key-lookups, no refill.
        let cfg = AdmissionConfig { tenant_rate: 0.0, tenant_burst: 3.0, ..Default::default() };
        let s = OnlineServing::with_admission(
            ServingRouter::new(open.router.routes.clone()),
            open.metrics.clone(),
            AdmissionController::new(cfg, None),
        );
        let c = ReadConsistency::default();
        // 2-key batch + 1-key batch fit the burst; the next must shed typed.
        s.lookup_batch_admitted("alice", "t", &[1, 2], "eastus", 100, &c).unwrap();
        s.lookup_batch_admitted("alice", "t", &[1], "eastus", 100, &c).unwrap();
        match s.lookup_batch_admitted("alice", "t", &[1], "eastus", 100, &c) {
            Err(FsError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // A different tenant still gets served.
        s.lookup_batch_admitted("bob", "t", &[1], "eastus", 100, &c).unwrap();
        // No admission controller → same call is fully open.
        open.lookup_batch_admitted("alice", "t", &[1], "eastus", 100, &c).unwrap();
    }

    #[test]
    fn admitted_path_emits_traces() {
        use crate::monitor::trace::{TraceConfig, Tracer};
        let (mut s, _) = serving();
        let tracer = Tracer::new(TraceConfig {
            sample_every: 1,
            slow_threshold_us: 0, // everything lands in the slow ring
            ring_capacity: 8,
        });
        s.tracer = Some(tracer.clone());
        s.lookup_batch_admitted("t1", "t", &[1, 2], "eastus", 100, &ReadConsistency::default())
            .unwrap();
        let slow = tracer.slow_ops();
        assert_eq!(slow.len(), 1);
        let r = slow[0].render();
        assert!(r.contains("request"), "{r}");
        assert!(r.contains("route") && r.contains("mech=Local"), "{r}");
        assert!(r.contains("store_read") && r.contains("keys=2 hits=1"), "{r}");
        // The same trace also sits in the completed ring.
        assert_eq!(tracer.recent().len(), 1);
    }

    #[test]
    fn unknown_table_errors() {
        let (s, _) = serving();
        assert!(s.lookup("nope", 1, "eastus", 0, &ReadConsistency::default()).is_err());
        assert!(s.lookup_batch("nope", &[1], "eastus", 0, &ReadConsistency::default()).is_err());
    }
}
