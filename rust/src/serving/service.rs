//! The online serving front end: router + geo access + metrics.

use std::sync::Arc;

use super::router::ServingRouter;
use crate::geo::access::{AccessMechanism, RoutedLookup};
use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::types::{EntityId, Result, Timestamp};

/// Serving facade used by the coordinator and the benches.
pub struct OnlineServing {
    pub router: ServingRouter,
    pub metrics: Arc<MetricsRegistry>,
}

impl OnlineServing {
    pub fn new(router: ServingRouter, metrics: Arc<MetricsRegistry>) -> Self {
        OnlineServing { router, metrics }
    }

    /// One online feature lookup from `consumer_region`. Records latency
    /// and hit/miss metrics per mechanism.
    pub fn lookup(
        &self,
        table: &str,
        entity: EntityId,
        consumer_region: &str,
        now: Timestamp,
    ) -> Result<RoutedLookup> {
        let access = self.router.resolve(table, consumer_region)?;
        let out = access.lookup(consumer_region, table, entity, now)?;
        let mech = match out.mechanism {
            AccessMechanism::Local => "local",
            AccessMechanism::CrossRegion => "xregion",
            AccessMechanism::Replica => "replica",
        };
        self.metrics.observe_latency(
            MetricKind::System,
            &format!("serving_latency_us_{mech}"),
            out.latency_us * 1_000, // store ns in the histogram
        );
        self.metrics.inc(
            MetricKind::System,
            if out.record.is_some() { "serving_hits" } else { "serving_misses" },
            1,
        );
        Ok(out)
    }

    /// Batched lookup of many entities (training-adjacent or bulk
    /// inference). Returns per-entity results in order.
    pub fn lookup_many(
        &self,
        table: &str,
        entities: &[EntityId],
        consumer_region: &str,
        now: Timestamp,
    ) -> Result<Vec<RoutedLookup>> {
        entities.iter().map(|&e| self.lookup(table, e, consumer_region, now)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::access::CrossRegionAccess;
    use crate::geo::topology::GeoTopology;
    use crate::online_store::OnlineStore;
    use crate::serving::router::RouteTable;
    use crate::types::FeatureRecord;

    fn serving() -> (OnlineServing, Arc<OnlineStore>) {
        let topology = Arc::new(GeoTopology::default_four_region());
        let store = Arc::new(OnlineStore::new(2));
        store.merge("t", &[FeatureRecord::new(1, 10, 20, vec![5.0])], 20);
        let routes = Arc::new(RouteTable::new());
        routes.set(
            "t",
            Arc::new(CrossRegionAccess {
                topology,
                home_region: "eastus".into(),
                home_store: store.clone(),
                replicator: None,
                geo_fenced: false,
            }),
        );
        (
            OnlineServing::new(ServingRouter::new(routes), Arc::new(MetricsRegistry::new())),
            store,
        )
    }

    #[test]
    fn lookup_records_metrics() {
        let (s, _) = serving();
        let out = s.lookup("t", 1, "eastus", 100).unwrap();
        assert_eq!(out.record.unwrap().values[0], 5.0);
        let _ = s.lookup("t", 999, "westus", 100).unwrap();
        assert_eq!(s.metrics.counter("serving_hits"), 1);
        assert_eq!(s.metrics.counter("serving_misses"), 1);
        assert!(s.metrics.latency_quantile("serving_latency_us_local", 0.5).is_some());
        assert!(s.metrics.latency_quantile("serving_latency_us_xregion", 0.5).is_some());
    }

    #[test]
    fn lookup_many_ordered() {
        let (s, store) = serving();
        store.merge("t", &[FeatureRecord::new(2, 10, 20, vec![6.0])], 20);
        let out = s.lookup_many("t", &[2, 1], "eastus", 100).unwrap();
        assert_eq!(out[0].record.as_ref().unwrap().values[0], 6.0);
        assert_eq!(out[1].record.as_ref().unwrap().values[0], 5.0);
    }

    #[test]
    fn unknown_table_errors() {
        let (s, _) = serving();
        assert!(s.lookup("nope", 1, "eastus", 0).is_err());
    }
}
