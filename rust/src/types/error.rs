//! Library-wide error type.

use crate::types::window::FeatureWindow;

#[derive(Debug, thiserror::Error)]
pub enum FsError {
    #[error("asset not found: {0}")]
    NotFound(String),

    #[error("asset already exists: {0}")]
    AlreadyExists(String),

    #[error("immutable property '{prop}' of {asset} cannot change; bump the version instead")]
    ImmutableProperty { asset: String, prop: String },

    #[error("schema violation: {0}")]
    Schema(String),

    #[error("window {got} conflicts with active job window {active}")]
    WindowConflict { got: FeatureWindow, active: FeatureWindow },

    #[error("invalid argument: {0}")]
    InvalidArg(String),

    #[error("permission denied: principal '{principal}' lacks '{action}' on {resource}")]
    AccessDenied { principal: String, action: String, resource: String },

    #[error("region '{0}' is unavailable")]
    RegionDown(String),

    #[error("store I/O error: {0}")]
    Io(#[from] std::io::Error),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime execution error: {0}")]
    Runtime(String),

    #[error("dsl error: {0}")]
    Dsl(String),

    #[error("injected fault: {0}")]
    InjectedFault(String),

    #[error("{0}")]
    Other(String),
}

impl FsError {
    /// Transient errors are retried by the scheduler/merge machinery
    /// (§3.1.3 "retry failed actions"); permanent ones raise alerts.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            FsError::InjectedFault(_) | FsError::Io(_) | FsError::RegionDown(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification() {
        assert!(FsError::InjectedFault("x".into()).is_transient());
        assert!(FsError::RegionDown("eastus".into()).is_transient());
        assert!(!FsError::NotFound("a".into()).is_transient());
        assert!(!FsError::ImmutableProperty { asset: "fs".into(), prop: "code".into() }
            .is_transient());
    }

    #[test]
    fn messages_render() {
        let e = FsError::WindowConflict {
            got: FeatureWindow::new(0, 10),
            active: FeatureWindow::new(5, 15),
        };
        assert!(e.to_string().contains("[0, 10)"));
    }
}
