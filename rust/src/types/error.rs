//! Library-wide error type.
//!
//! Hand-implemented `Display`/`Error` (the `thiserror` derive crate is
//! not available in the offline build environment); message formats are
//! part of the API surface — tests assert on them.

use std::fmt;

use crate::types::window::FeatureWindow;

#[derive(Debug)]
pub enum FsError {
    NotFound(String),
    AlreadyExists(String),
    ImmutableProperty { asset: String, prop: String },
    Schema(String),
    WindowConflict { got: FeatureWindow, active: FeatureWindow },
    InvalidArg(String),
    AccessDenied { principal: String, action: String, resource: String },
    RegionDown(String),
    Io(std::io::Error),
    Artifact(String),
    Runtime(String),
    Dsl(String),
    InjectedFault(String),
    Overloaded { resource: String, reason: String },
    /// On-disk state failed validation (bad magic, checksum mismatch,
    /// torn record in a sealed fragment). Never transient: retrying the
    /// read returns the same bytes — recovery must fall back to an older
    /// manifest generation or fail closed.
    Corrupt(String),
    Other(String),
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::NotFound(s) => write!(f, "asset not found: {s}"),
            FsError::AlreadyExists(s) => write!(f, "asset already exists: {s}"),
            FsError::ImmutableProperty { asset, prop } => write!(
                f,
                "immutable property '{prop}' of {asset} cannot change; bump the version instead"
            ),
            FsError::Schema(s) => write!(f, "schema violation: {s}"),
            FsError::WindowConflict { got, active } => {
                write!(f, "window {got} conflicts with active job window {active}")
            }
            FsError::InvalidArg(s) => write!(f, "invalid argument: {s}"),
            FsError::AccessDenied { principal, action, resource } => write!(
                f,
                "permission denied: principal '{principal}' lacks '{action}' on {resource}"
            ),
            FsError::RegionDown(r) => write!(f, "region '{r}' is unavailable"),
            FsError::Io(e) => write!(f, "store I/O error: {e}"),
            FsError::Artifact(s) => write!(f, "artifact error: {s}"),
            FsError::Runtime(s) => write!(f, "runtime execution error: {s}"),
            FsError::Dsl(s) => write!(f, "dsl error: {s}"),
            FsError::InjectedFault(s) => write!(f, "injected fault: {s}"),
            FsError::Overloaded { resource, reason } => {
                write!(f, "overloaded: {resource} shed request ({reason})")
            }
            FsError::Corrupt(s) => write!(f, "corrupt store state: {s}"),
            FsError::Other(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for FsError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FsError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FsError {
    fn from(e: std::io::Error) -> Self {
        FsError::Io(e)
    }
}

impl FsError {
    /// Transient errors are retried by the scheduler/merge machinery
    /// (§3.1.3 "retry failed actions"); permanent ones raise alerts.
    ///
    /// `Overloaded` is deliberately NOT transient: admission control sheds
    /// load to push work back to the caller's backoff loop, and an inline
    /// retry storm would amplify exactly the overload being shed.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            FsError::InjectedFault(_) | FsError::Io(_) | FsError::RegionDown(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification() {
        assert!(FsError::InjectedFault("x".into()).is_transient());
        assert!(FsError::RegionDown("eastus".into()).is_transient());
        assert!(!FsError::NotFound("a".into()).is_transient());
        assert!(!FsError::ImmutableProperty { asset: "fs".into(), prop: "code".into() }
            .is_transient());
        // Shed load must bounce to the caller's backoff, never a hot retry.
        assert!(!FsError::Overloaded { resource: "serving".into(), reason: "q".into() }
            .is_transient());
        // Corruption is deterministic: a retry reads the same bad bytes.
        assert!(!FsError::Corrupt("checksum mismatch".into()).is_transient());
    }

    #[test]
    fn corrupt_renders_prefix() {
        let e = FsError::Corrupt("fragment frame 3 checksum".into());
        assert!(e.to_string().starts_with("corrupt store state:"), "{e}");
    }

    #[test]
    fn overloaded_renders_resource_and_reason() {
        let e = FsError::Overloaded {
            resource: "serving queue".into(),
            reason: "inflight 128 >= 128".into(),
        };
        let s = e.to_string();
        assert!(s.starts_with("overloaded:"), "{s}");
        assert!(s.contains("serving queue") && s.contains("inflight"), "{s}");
    }

    #[test]
    fn messages_render() {
        let e = FsError::WindowConflict {
            got: FeatureWindow::new(0, 10),
            active: FeatureWindow::new(5, 15),
        };
        assert!(e.to_string().contains("[0, 10)"));
    }

    #[test]
    fn io_source_chain() {
        let e = FsError::from(std::io::Error::new(std::io::ErrorKind::Other, "disk"));
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().starts_with("store I/O error:"));
    }
}
