//! Feature windows: half-open intervals on the event timeline.

use super::time::{Granularity, Timestamp};

/// Half-open `[start, end)` window of event time (Algorithm 1's
/// `feature_window_start_ts` / `feature_window_end_ts`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FeatureWindow {
    pub start: Timestamp,
    pub end: Timestamp,
}

impl FeatureWindow {
    pub fn new(start: Timestamp, end: Timestamp) -> Self {
        assert!(start <= end, "window start {start} > end {end}");
        FeatureWindow { start, end }
    }

    pub fn len(&self) -> i64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn contains(&self, ts: Timestamp) -> bool {
        ts >= self.start && ts < self.end
    }

    /// Overlap test — the scheduler's non-overlap invariant (§4.3) is
    /// phrased in terms of this.
    pub fn overlaps(&self, other: &FeatureWindow) -> bool {
        self.start < other.end && other.start < self.end
    }

    pub fn intersect(&self, other: &FeatureWindow) -> Option<FeatureWindow> {
        let s = self.start.max(other.start);
        let e = self.end.min(other.end);
        if s < e {
            Some(FeatureWindow::new(s, e))
        } else {
            None
        }
    }

    /// Union of two *adjacent or overlapping* windows.
    pub fn merge(&self, other: &FeatureWindow) -> Option<FeatureWindow> {
        if self.start > other.end || other.start > self.end {
            return None;
        }
        Some(FeatureWindow::new(self.start.min(other.start), self.end.max(other.end)))
    }

    /// Expand to bin boundaries (start floors, end ceils).
    pub fn align(&self, g: Granularity) -> FeatureWindow {
        FeatureWindow::new(g.floor(self.start), g.ceil(self.end))
    }

    /// The source read window per Algorithm 1:
    /// `source_window_start = feature_window_start - lookback`.
    pub fn source_window(&self, lookback: i64) -> FeatureWindow {
        assert!(lookback >= 0);
        FeatureWindow::new(self.start - lookback, self.end)
    }

    /// Number of bins when aligned to `g`.
    pub fn bins(&self, g: Granularity) -> i64 {
        debug_assert!(g.aligned(self.start) && g.aligned(self.end));
        (self.end - self.start) / g.secs()
    }

    /// Split into at most `max_bins`-wide aligned chunks — the scheduler's
    /// context-aware partitioning unit (§3.1.1).
    pub fn split(&self, g: Granularity, max_bins: i64) -> Vec<FeatureWindow> {
        assert!(max_bins > 0);
        let w = self.align(g);
        let step = max_bins * g.secs();
        let mut out = Vec::new();
        let mut s = w.start;
        while s < w.end {
            let e = (s + step).min(w.end);
            out.push(FeatureWindow::new(s, e));
            s = e;
        }
        out
    }
}

impl std::fmt::Display for FeatureWindow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}, {})", self.start, self.end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::HOUR;

    #[test]
    fn overlap_semantics_half_open() {
        let a = FeatureWindow::new(0, 10);
        let b = FeatureWindow::new(10, 20); // adjacent: no overlap
        let c = FeatureWindow::new(9, 11);
        assert!(!a.overlaps(&b));
        assert!(a.overlaps(&c) && c.overlaps(&b));
        assert!(!a.contains(10) && a.contains(9));
    }

    #[test]
    fn intersect_merge() {
        let a = FeatureWindow::new(0, 10);
        let b = FeatureWindow::new(5, 15);
        assert_eq!(a.intersect(&b), Some(FeatureWindow::new(5, 10)));
        assert_eq!(a.merge(&b), Some(FeatureWindow::new(0, 15)));
        let far = FeatureWindow::new(20, 30);
        assert_eq!(a.intersect(&far), None);
        assert_eq!(a.merge(&far), None);
        // adjacent merges
        assert_eq!(
            a.merge(&FeatureWindow::new(10, 12)),
            Some(FeatureWindow::new(0, 12))
        );
    }

    #[test]
    fn align_and_bins() {
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(100, 2 * HOUR + 5).align(g);
        assert_eq!(w, FeatureWindow::new(0, 3 * HOUR));
        assert_eq!(w.bins(g), 3);
    }

    #[test]
    fn source_window_lookback() {
        let w = FeatureWindow::new(1_000, 2_000);
        assert_eq!(w.source_window(500), FeatureWindow::new(500, 2_000));
    }

    #[test]
    fn split_covers_exactly() {
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(0, 10 * HOUR);
        let parts = w.split(g, 4);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], FeatureWindow::new(0, 4 * HOUR));
        assert_eq!(parts[2], FeatureWindow::new(8 * HOUR, 10 * HOUR));
        // contiguous, non-overlapping, covering
        for pair in parts.windows(2) {
            assert_eq!(pair[0].end, pair[1].start);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_inverted() {
        FeatureWindow::new(10, 0);
    }
}
