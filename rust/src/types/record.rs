//! The materialized feature-set record (paper §4.5.1) and entity keys.

use std::collections::HashMap;
use std::sync::RwLock;

use super::time::Timestamp;

/// Interned entity key. The paper's records carry "multiple ID (index)
/// columns"; we intern the joined index-column values to a dense u64 so
/// the storage/serving hot paths never touch strings.
pub type EntityId = u64;

/// A materialized feature-set record (§4.5.1):
/// IDs + event_timestamp + creation_timestamp is the uniqueness key
/// offline; online keeps `max(tuple(event_ts, creation_ts))` per entity.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureRecord {
    pub entity: EntityId,
    /// End of the aggregation bin on the event timeline.
    pub event_ts: Timestamp,
    /// Materialization time; always > event_ts for time-series features.
    pub creation_ts: Timestamp,
    /// Feature columns, in feature-set schema order.
    pub values: Box<[f32]>,
}

impl FeatureRecord {
    pub fn new(
        entity: EntityId,
        event_ts: Timestamp,
        creation_ts: Timestamp,
        values: impl Into<Box<[f32]>>,
    ) -> Self {
        FeatureRecord { entity, event_ts, creation_ts, values: values.into() }
    }

    /// Offline uniqueness key (§4.5.1).
    pub fn unique_key(&self) -> (EntityId, Timestamp, Timestamp) {
        (self.entity, self.event_ts, self.creation_ts)
    }

    /// Ordering tuple used by the online store (Eq. 2): a record wins if
    /// its `(event_ts, creation_ts)` is larger.
    pub fn version(&self) -> (Timestamp, Timestamp) {
        (self.event_ts, self.creation_ts)
    }
}

/// Bidirectional string↔id interner for entity index values.
///
/// Index columns are joined with `\x1f` (ASCII unit separator) before
/// interning, matching the multi-ID records of §4.5.1.
#[derive(Debug, Default)]
pub struct EntityInterner {
    inner: RwLock<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    by_key: HashMap<String, EntityId>,
    by_id: Vec<String>,
}

pub const ID_SEP: char = '\x1f';

impl EntityInterner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Join multi-column index values into the canonical key string.
    pub fn join_key(cols: &[&str]) -> String {
        cols.join(&ID_SEP.to_string())
    }

    /// Intern (or look up) a key, returning its dense id.
    pub fn intern(&self, key: &str) -> EntityId {
        if let Some(&id) = self.inner.read().unwrap().by_key.get(key) {
            return id;
        }
        let mut g = self.inner.write().unwrap();
        if let Some(&id) = g.by_key.get(key) {
            return id; // raced
        }
        let id = g.by_id.len() as EntityId;
        g.by_id.push(key.to_string());
        g.by_key.insert(key.to_string(), id);
        id
    }

    /// Reverse lookup.
    pub fn resolve(&self, id: EntityId) -> Option<String> {
        self.inner.read().unwrap().by_id.get(id as usize).cloned()
    }

    pub fn lookup(&self, key: &str) -> Option<EntityId> {
        self.inner.read().unwrap().by_key.get(key).copied()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().by_id.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All interned ids (0..len).
    pub fn ids(&self) -> Vec<EntityId> {
        (0..self.len() as EntityId).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_keys() {
        let r = FeatureRecord::new(7, 100, 150, vec![1.0, 2.0]);
        assert_eq!(r.unique_key(), (7, 100, 150));
        assert_eq!(r.version(), (100, 150));
    }

    #[test]
    fn version_ordering_matches_alg2() {
        // Alg 2: newer event_ts wins; tie on event_ts → newer creation_ts.
        let old = FeatureRecord::new(1, 100, 200, vec![]);
        let newer_event = FeatureRecord::new(1, 110, 150, vec![]);
        let late_arriving = FeatureRecord::new(1, 100, 300, vec![]);
        assert!(newer_event.version() > old.version());
        assert!(late_arriving.version() > old.version());
        assert!(newer_event.version() > late_arriving.version());
    }

    #[test]
    fn interner_roundtrip() {
        let i = EntityInterner::new();
        let a = i.intern("cust_1");
        let b = i.intern("cust_2");
        assert_ne!(a, b);
        assert_eq!(i.intern("cust_1"), a);
        assert_eq!(i.resolve(a).as_deref(), Some("cust_1"));
        assert_eq!(i.lookup("cust_2"), Some(b));
        assert_eq!(i.lookup("nope"), None);
        assert_eq!(i.len(), 2);
    }

    #[test]
    fn multi_column_keys_do_not_collide() {
        // ("ab","c") must differ from ("a","bc") — the separator ensures it.
        let k1 = EntityInterner::join_key(&["ab", "c"]);
        let k2 = EntityInterner::join_key(&["a", "bc"]);
        assert_ne!(k1, k2);
    }

    #[test]
    fn interner_dense_ids() {
        let i = EntityInterner::new();
        for n in 0..100 {
            assert_eq!(i.intern(&format!("e{n}")), n as EntityId);
        }
        assert_eq!(i.ids().len(), 100);
    }

    #[test]
    fn interner_concurrent() {
        use std::sync::Arc;
        let i = Arc::new(EntityInterner::new());
        let hs: Vec<_> = (0..8)
            .map(|t| {
                let i = i.clone();
                std::thread::spawn(move || {
                    for n in 0..200 {
                        i.intern(&format!("e{}", (n + t) % 100));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(i.len(), 100);
        // Bijective: every id resolves to a key that interns back to it.
        for id in i.ids() {
            let k = i.resolve(id).unwrap();
            assert_eq!(i.lookup(&k), Some(id));
        }
    }
}
