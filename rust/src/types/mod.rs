//! Core domain types: timestamps, feature windows, records, errors.

pub mod error;
pub mod record;
pub mod time;
pub mod window;

pub use error::FsError;
pub use record::{EntityId, EntityInterner, FeatureRecord};
pub use time::{Granularity, Timestamp, DAY, HOUR, MINUTE};
pub use window::FeatureWindow;

/// Result alias used across the library.
pub type Result<T> = std::result::Result<T, FsError>;
