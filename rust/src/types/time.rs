//! Event-timeline time: epoch-second timestamps and bin granularity.

/// Epoch seconds. The paper's event/creation timestamps (§4.5.1).
pub type Timestamp = i64;

pub const MINUTE: i64 = 60;
pub const HOUR: i64 = 3_600;
pub const DAY: i64 = 86_400;

/// Aggregation bin width of a feature set ("daily aggregation Feature
/// Set" in §4.5.1). Feature windows must be aligned to this.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Granularity(pub i64);

impl Granularity {
    pub const fn secs(self) -> i64 {
        self.0
    }

    /// Floor `ts` to a bin boundary.
    pub fn floor(self, ts: Timestamp) -> Timestamp {
        ts.div_euclid(self.0) * self.0
    }

    /// Ceil `ts` to a bin boundary.
    pub fn ceil(self, ts: Timestamp) -> Timestamp {
        self.floor(ts + self.0 - 1)
    }

    /// Is `ts` on a bin boundary?
    pub fn aligned(self, ts: Timestamp) -> bool {
        ts.rem_euclid(self.0) == 0
    }

    /// Index of the bin containing `ts`, relative to `origin` (which must
    /// be aligned).
    pub fn bin_index(self, origin: Timestamp, ts: Timestamp) -> i64 {
        debug_assert!(self.aligned(origin));
        (ts - origin).div_euclid(self.0)
    }

    /// The *event timestamp* of the bin containing `ts`: the end of the
    /// bin, per §4.5.1 ("in a daily aggregation Feature Set, this will be
    /// the timestamp of the end of day").
    pub fn bin_event_ts(self, ts: Timestamp) -> Timestamp {
        self.floor(ts) + self.0
    }

    pub fn hourly() -> Self {
        Granularity(super::time::HOUR)
    }
    pub fn daily() -> Self {
        Granularity(super::time::DAY)
    }
}

/// Render a duration in human units (for logs / bench tables).
pub fn fmt_secs(mut s: i64) -> String {
    let neg = s < 0;
    if neg {
        s = -s;
    }
    let out = if s % DAY == 0 {
        format!("{}d", s / DAY)
    } else if s % HOUR == 0 {
        format!("{}h", s / HOUR)
    } else if s % MINUTE == 0 {
        format!("{}m", s / MINUTE)
    } else {
        format!("{s}s")
    };
    if neg {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_ceil_aligned() {
        let g = Granularity(HOUR);
        assert_eq!(g.floor(3_661), 3_600);
        assert_eq!(g.floor(3_600), 3_600);
        assert_eq!(g.ceil(3_601), 7_200);
        assert_eq!(g.ceil(3_600), 3_600);
        assert!(g.aligned(7_200));
        assert!(!g.aligned(7_201));
    }

    #[test]
    fn negative_timestamps() {
        let g = Granularity(HOUR);
        assert_eq!(g.floor(-1), -3_600);
        assert_eq!(g.ceil(-1), 0);
        assert_eq!(g.bin_index(0, -1), -1);
    }

    #[test]
    fn bin_event_ts_is_bin_end() {
        let g = Granularity(DAY);
        // Any instant during day 0 maps to event_ts = end of day 0.
        assert_eq!(g.bin_event_ts(0), DAY);
        assert_eq!(g.bin_event_ts(DAY - 1), DAY);
        assert_eq!(g.bin_event_ts(DAY), 2 * DAY);
    }

    #[test]
    fn bin_index() {
        let g = Granularity(HOUR);
        assert_eq!(g.bin_index(0, 0), 0);
        assert_eq!(g.bin_index(0, HOUR - 1), 0);
        assert_eq!(g.bin_index(0, HOUR), 1);
        assert_eq!(g.bin_index(7_200, 7_200 + HOUR), 1);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_secs(DAY * 30), "30d");
        assert_eq!(fmt_secs(HOUR * 5), "5h");
        assert_eq!(fmt_secs(90), "90s");
        assert_eq!(fmt_secs(-HOUR), "-1h");
    }
}
