//! Test support: a small property-testing framework (proptest is not
//! available offline), an RAII temp-dir guard, and shared fixtures.

pub mod faultfs;
pub mod prop;

pub use prop::{forall, Gen};

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::source::{Event, SourceConnector};
use crate::types::{FeatureWindow, Result, Timestamp};

/// Fixed-event batch source: serves exactly the given events, honoring
/// window + `as_of` visibility. Shared by the consistency tests and the
/// stream bench so their batch-vs-stream differentials read the same
/// source semantics (same role as [`TempDir`]: one fixture, no drift).
pub struct FixedSource(pub Vec<Event>);

impl SourceConnector for FixedSource {
    fn read(&self, window: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>> {
        Ok(self
            .0
            .iter()
            .filter(|e| window.contains(e.ts) && e.ts <= as_of)
            .cloned()
            .collect())
    }

    fn describe(&self) -> String {
        format!("fixed({} events)", self.0.len())
    }
}

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// RAII temporary directory: unique per instance, removed on drop —
/// including drop during unwinding, so a failing assertion in the middle
/// of a persistence test no longer strands files in `$TMPDIR` (and a
/// rerun never sees a stale directory).
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `$TMPDIR/geofs-<tag>-<pid>-<seq>`, fresh and empty.
    pub fn new(tag: &str) -> TempDir {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "geofs-{tag}-{}-{seq}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A file path inside the directory (not created).
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_is_fresh_and_cleaned() {
        let kept;
        {
            let d = TempDir::new("unit");
            kept = d.path().to_path_buf();
            assert!(kept.exists());
            std::fs::write(d.file("x.bin"), b"data").unwrap();
        }
        assert!(!kept.exists(), "guard must remove the directory on drop");
    }

    #[test]
    fn tempdir_cleans_on_panic() {
        let kept = std::sync::Arc::new(std::sync::Mutex::new(PathBuf::new()));
        let k2 = kept.clone();
        let result = std::panic::catch_unwind(move || {
            let d = TempDir::new("panic");
            *k2.lock().unwrap() = d.path().to_path_buf();
            std::fs::write(d.file("y.bin"), b"data").unwrap();
            panic!("boom");
        });
        assert!(result.is_err());
        assert!(!kept.lock().unwrap().exists(), "guard must clean up during unwinding");
    }

    #[test]
    fn tempdirs_are_unique() {
        let a = TempDir::new("uniq");
        let b = TempDir::new("uniq");
        assert_ne!(a.path(), b.path());
    }
}
