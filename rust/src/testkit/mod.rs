//! Test support: a small property-testing framework (proptest is not
//! available offline) and shared fixtures.

pub mod prop;

pub use prop::{forall, Gen};
