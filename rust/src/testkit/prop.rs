//! Mini property-testing framework.
//!
//! `proptest` is not fetchable in this environment, so coordinator
//! invariants (routing, batching, merge/state semantics) are checked with
//! this in-tree harness: seeded case generation, a fixed case budget, and
//! greedy input shrinking on failure.  Failures print the seed so a case
//! can be replayed by setting `GEOFS_PROP_SEED`.

use crate::util::rng::Rng;

/// Case generator: produces a random instance of `T` from an `Rng`.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

/// Common generators.
pub mod gens {
    use super::Gen;

    pub fn i64_in(lo: i64, hi: i64) -> Gen<i64> {
        Gen::new(move |r| r.range(lo, hi))
    }

    pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
        Gen::new(move |r| r.range(lo as i64, hi as i64) as usize)
    }

    pub fn f32_unit() -> Gen<f32> {
        Gen::new(|r| r.f32())
    }

    pub fn vec_of<T: 'static>(item: Gen<T>, max_len: usize) -> Gen<Vec<T>> {
        Gen::new(move |r| {
            let n = r.below(max_len as u64 + 1) as usize;
            (0..n).map(|_| item.sample(r)).collect()
        })
    }
}

/// Outcome of a property check on one case.
pub type PropResult = Result<(), String>;

/// Shrinkable inputs: propose structurally smaller candidates.
pub trait Shrink: Sized + Clone {
    /// Candidates strictly "smaller" than `self`; empty when minimal.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl<T: Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            return vec![];
        }
        let mut out = Vec::new();
        // halves — only when strictly smaller than self (a 1-element vec's
        // second half IS the vec; re-proposing it would loop forever)
        if self.len() >= 2 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        // drop one element (up to 8 positions to bound work)
        let step = (self.len() / 8).max(1);
        for i in (0..self.len()).step_by(step) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        out
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2]
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2]
        }
    }
}

/// Run `prop` on `cases` generated instances; shrink on failure; panic
/// with the minimal failing case (Debug) and the seed.
pub fn forall<T>(name: &str, cases: usize, gen: &Gen<T>, prop: impl Fn(&T) -> PropResult)
where
    T: Shrink + std::fmt::Debug + 'static,
{
    let seed = std::env::var("GEOFS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfeed_face_u64);
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first smaller failing input.
            let mut minimal = input;
            let mut minimal_msg = msg;
            'outer: loop {
                for cand in minimal.shrink() {
                    if let Err(m) = prop(&cand) {
                        minimal = cand;
                        minimal_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case_idx}, seed {seed}):\n  \
                 error: {minimal_msg}\n  minimal input: {minimal:?}\n  \
                 replay: GEOFS_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 200, &vec_of(i64_in(-100, 100), 20), |v| {
            let mut r = v.clone();
            r.reverse();
            if v.iter().sum::<i64>() == r.iter().sum::<i64>() {
                Ok(())
            } else {
                Err("sum not reversal-invariant".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let caught = std::panic::catch_unwind(|| {
            forall("no-big", 500, &vec_of(i64_in(0, 1000), 30), |v| {
                if v.iter().any(|&x| x >= 500) {
                    Err("contains big".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal input"), "{msg}");
        // Shrinking should get the witness down to a single element.
        let after = msg.split("minimal input: ").nth(1).unwrap();
        let commas = after.split(']').next().unwrap().matches(',').count();
        assert!(commas <= 1, "not shrunk: {msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = || {
            let mut got = Vec::new();
            let g = i64_in(0, 1_000_000);
            let mut rng = Rng::new(77);
            for _ in 0..10 {
                got.push(g.sample(&mut rng));
            }
            got
        };
        assert_eq!(collect(), collect());
    }

    #[test]
    fn gen_map() {
        let g = i64_in(1, 10).map(|x| x * 2);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let v = g.sample(&mut rng);
            assert!(v % 2 == 0 && (2..20).contains(&v));
        }
    }
}
