//! Fault-injecting [`Vfs`] for durability torture tests.
//!
//! Wraps the real filesystem and injects three failure modes beneath
//! the storage layer, all seeded and deterministic:
//!
//! * **Crash points** — after `fail_after_ops` filesystem operations,
//!   the "process" crashes: the op in flight fails, and every later op
//!   fails too (`FsError::InjectedFault("crashed")`). A crashing write
//!   may first persist a random *prefix* of its buffer (a torn write),
//!   exactly what a power cut does to an in-flight page.
//! * **Torn writes** — independently of crashes, a write may persist a
//!   prefix and fail, with probability `torn_write_rate`.
//! * **Transient errors** — any op may fail with probability
//!   `transient_error_rate` without crashing; a retry (e.g. via
//!   `util::backoff`) then succeeds. These pin the drivers'
//!   retry-on-transient behavior.
//!
//! Recovery tests reopen the directory with a plain `RealFs`: the
//! crash leaves real on-disk state behind, and recovery must cope with
//! whatever prefix survived.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::storage::vfs::{RealFs, Vfs, VfsFile};
use crate::types::{FsError, Result};
use crate::util::rng::Rng;

/// Injection knobs (all off by default).
#[derive(Debug, Clone)]
pub struct FaultConfig {
    pub seed: u64,
    /// Crash after this many successful filesystem ops (`None` = never).
    pub fail_after_ops: Option<u64>,
    /// Per-op probability of a transient (retryable) failure.
    pub transient_error_rate: f64,
    /// Per-write probability of persisting only a prefix and failing.
    pub torn_write_rate: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            fail_after_ops: None,
            transient_error_rate: 0.0,
            torn_write_rate: 0.0,
        }
    }
}

struct FaultState {
    cfg: FaultConfig,
    ops: AtomicU64,
    crashed: AtomicBool,
    rng: Mutex<Rng>,
}

impl FaultState {
    /// Account one op; decide its fate. `Ok(())` means proceed normally.
    fn gate(&self) -> Result<()> {
        if self.crashed.load(Ordering::Acquire) {
            return Err(FsError::InjectedFault("crashed".into()));
        }
        let n = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(limit) = self.cfg.fail_after_ops {
            if n > limit {
                self.crashed.store(true, Ordering::Release);
                return Err(FsError::InjectedFault("crashed".into()));
            }
        }
        if self.cfg.transient_error_rate > 0.0
            && self.rng.lock().unwrap().bool(self.cfg.transient_error_rate)
        {
            return Err(FsError::InjectedFault("transient io error".into()));
        }
        Ok(())
    }

    /// For a failing write: how many bytes of `len` still hit the disk.
    fn torn_prefix(&self, len: usize) -> usize {
        self.rng.lock().unwrap().below(len as u64 + 1) as usize
    }
}

/// Seeded fault-injecting filesystem (see module docs).
pub struct FaultFs {
    inner: RealFs,
    st: Arc<FaultState>,
}

impl FaultFs {
    pub fn new(cfg: FaultConfig) -> Arc<FaultFs> {
        let rng = Rng::new(cfg.seed);
        Arc::new(FaultFs {
            inner: RealFs,
            st: Arc::new(FaultState {
                cfg,
                ops: AtomicU64::new(0),
                crashed: AtomicBool::new(false),
                rng: Mutex::new(rng),
            }),
        })
    }

    /// Has the injected crash point been hit?
    pub fn crashed(&self) -> bool {
        self.st.crashed.load(Ordering::Acquire)
    }

    /// Filesystem ops performed so far (for sizing crash-point sweeps).
    pub fn ops(&self) -> u64 {
        self.st.ops.load(Ordering::Relaxed)
    }
}

struct FaultFile {
    inner: Box<dyn VfsFile>,
    st: Arc<FaultState>,
}

impl VfsFile for FaultFile {
    fn append(&mut self, buf: &[u8]) -> Result<()> {
        match self.st.gate() {
            Ok(()) => {
                // An un-crashed op may still tear independently.
                if self.st.cfg.torn_write_rate > 0.0
                    && self.st.rng.lock().unwrap().bool(self.st.cfg.torn_write_rate)
                {
                    let keep = self.st.torn_prefix(buf.len());
                    let _ = self.inner.append(&buf[..keep]);
                    return Err(FsError::InjectedFault("torn write".into()));
                }
                self.inner.append(buf)
            }
            Err(e) => {
                // A crashing write may leave a torn prefix on disk first.
                if matches!(&e, FsError::InjectedFault(s) if s == "crashed") {
                    let keep = self.st.torn_prefix(buf.len());
                    if keep > 0 {
                        let _ = self.inner.append(&buf[..keep]);
                        let _ = self.inner.sync();
                    }
                }
                Err(e)
            }
        }
    }

    fn sync(&mut self) -> Result<()> {
        self.st.gate()?;
        self.inner.sync()
    }
}

impl Vfs for FaultFs {
    fn create(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
        self.st.gate()?;
        let inner = self.inner.create(path)?;
        Ok(Box::new(FaultFile { inner, st: self.st.clone() }))
    }
    fn open_append(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
        self.st.gate()?;
        let inner = self.inner.open_append(path)?;
        Ok(Box::new(FaultFile { inner, st: self.st.clone() }))
    }
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        self.st.gate()?;
        self.inner.read(path)
    }
    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        // A crashing rename simply does not happen (rename is atomic).
        self.st.gate()?;
        self.inner.rename(from, to)
    }
    fn remove(&self, path: &Path) -> Result<()> {
        self.st.gate()?;
        self.inner.remove(path)
    }
    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        self.st.gate()?;
        self.inner.list(dir)
    }
    fn sync_dir(&self, dir: &Path) -> Result<()> {
        self.st.gate()?;
        self.inner.sync_dir(dir)
    }
    fn exists(&self, path: &Path) -> bool {
        // Existence probes don't mutate state; no fault accounting, so
        // crash-point sweeps step over write ops, not read probes.
        self.inner.exists(path)
    }
    fn create_dir_all(&self, dir: &Path) -> Result<()> {
        self.st.gate()?;
        self.inner.create_dir_all(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    #[test]
    fn crash_point_fails_everything_after() {
        let dir = TempDir::new("faultfs");
        let fs = FaultFs::new(FaultConfig { seed: 1, fail_after_ops: Some(2), ..Default::default() });
        let mut f = fs.create(&dir.file("a")).unwrap(); // op 1
        f.append(b"ok").unwrap(); // op 2
        assert!(f.append(b"boom").is_err(), "op past the crash point fails");
        assert!(fs.crashed());
        assert!(fs.read(&dir.file("a")).is_err(), "crashed fs stays down");
        // The prefix written before the crash is real on-disk state.
        let bytes = std::fs::read(dir.file("a")).unwrap();
        assert!(bytes.starts_with(b"ok"), "pre-crash write survives: {bytes:?}");
    }

    #[test]
    fn transient_errors_are_retryable() {
        let dir = TempDir::new("faultfs-tr");
        let fs = FaultFs::new(FaultConfig {
            seed: 7,
            transient_error_rate: 0.5,
            ..Default::default()
        });
        let path = dir.file("b");
        let out = crate::util::backoff::retry(&crate::util::backoff::Backoff::immediate(64), || {
            let mut f = fs.create(&path)?;
            f.append(b"payload")?;
            f.sync()?;
            Ok(())
        });
        out.unwrap();
        assert!(!fs.crashed(), "transient errors never crash the fs");
        assert_eq!(std::fs::read(&path).unwrap(), b"payload");
    }

    #[test]
    fn torn_write_persists_a_strict_prefix() {
        let dir = TempDir::new("faultfs-torn");
        // torn_write_rate = 1: every append tears.
        let fs = FaultFs::new(FaultConfig { seed: 3, torn_write_rate: 1.0, ..Default::default() });
        let path = dir.file("c");
        let mut f = fs.create(&path).unwrap();
        assert!(f.append(b"0123456789").is_err());
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.len() <= 10, "at most the buffer persists");
        assert_eq!(&bytes[..], &b"0123456789"[..bytes.len()], "persisted bytes are a prefix");
    }

    #[test]
    fn deterministic_for_seed() {
        // Same seed + same op sequence → same failure schedule.
        let run = |seed: u64| {
            let dir = TempDir::new("faultfs-det");
            let fs =
                FaultFs::new(FaultConfig { seed, transient_error_rate: 0.3, ..Default::default() });
            (0..32)
                .map(|i| {
                    let r = fs
                        .create(&dir.file(&format!("f{i}")))
                        .and_then(|mut f| f.append(b"x"));
                    r.is_ok()
                })
                .collect::<Vec<bool>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12), "different seeds give different schedules");
    }
}
