//! `geofs` — the managed geo-distributed feature store CLI (Layer 3
//! entrypoint).
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! geofs demo       [--customers N] [--days N] [--no-engine]
//! geofs serve      [--config FILE] [--requests N]
//! geofs materialize [--config FILE] [--days N]
//! geofs backfill   [--days N]       one-time backfill over history
//! geofs bootstrap  [--direction offline-to-online|online-to-offline]
//! geofs search     <text>           asset search
//! geofs metrics                     dump the metrics registry
//! geofs artifacts                   list AOT artifacts
//! ```

use std::sync::Arc;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::metadata::catalog::SearchQuery;
use geofs::query::pit::PitConfig;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::{fmt_secs, DAY};
use geofs::types::FeatureWindow;
use geofs::util::init_logging;

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
    positional: Vec<String>,
}

fn parse_args() -> Args {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    let mut flags = std::collections::HashMap::new();
    let mut positional = Vec::new();
    let rest: Vec<String> = args.collect();
    let mut i = 0;
    while i < rest.len() {
        if let Some(name) = rest[i].strip_prefix("--") {
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.insert(name.to_string(), rest[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(rest[i].clone());
            i += 1;
        }
    }
    Args { cmd, flags, positional }
}

impl Args {
    fn usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn i64(&self, name: &str, default: i64) -> i64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn bool(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v == "true").unwrap_or(false)
    }
    fn str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }
}

fn load_config(args: &Args) -> anyhow::Result<Config> {
    Ok(match args.str("config") {
        Some(path) => Config::load(path)?,
        None => Config::default_geo(),
    })
}

fn open_with_workload(
    args: &Args,
) -> anyhow::Result<(Arc<FeatureStore>, ChurnWorkload)> {
    let config = load_config(args)?;
    let fs = FeatureStore::open(
        config,
        OpenOptions { with_engine: !args.bool("no-engine"), ..Default::default() },
    )?;
    let workload = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig {
            customers: args.usize("customers", 64),
            days: args.i64("days", 14),
            seed: args.i64("seed", 42) as u64,
            ..Default::default()
        },
    )?;
    Ok((fs, workload))
}

/// Replay the deployment's life day by day: each daily tick materializes
/// the previous day, so records carry realistic creation timestamps (a
/// one-shot tick at the end would stamp everything "now" and PIT would
/// correctly refuse to serve it to earlier observations).
fn materialize_history(fs: &FeatureStore, w: &ChurnWorkload, days: i64) -> anyhow::Result<()> {
    let mut jobs = [0usize; 2];
    let mut records = [0u64; 2];
    for day in 1..=days {
        fs.clock.set(day * DAY);
        for (i, table) in [&w.txn_table, &w.interactions_table].iter().enumerate() {
            let outcomes = fs.materialize_tick(table)?;
            jobs[i] += outcomes.len();
            records[i] += outcomes.iter().map(|o| o.records).sum::<u64>();
        }
    }
    for (i, table) in [&w.txn_table, &w.interactions_table].iter().enumerate() {
        let f = fs.table_freshness(table).unwrap();
        println!(
            "materialized {table}: {} job(s), {} records, staleness={}, within_sla={}",
            jobs[i],
            records[i],
            fmt_secs(f.staleness_secs),
            f.within_sla
        );
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    let days = args.i64("days", 14);
    println!("== geofs demo: churn workload ({} customers, {days} days) ==", w.cfg.customers);
    materialize_history(&fs, &w, days)?;

    // Online reads from every region.
    let regions: Vec<String> = fs.config.regions.clone();
    for (key, region) in w.serving_trace(8, &regions) {
        let out = fs.get_online(&w.principal, &w.txn_table, &key, &region)?;
        println!(
            "lookup {key} from {region:<14} mechanism={:?} latency={}µs hit={}",
            out.mechanism,
            out.latency_us,
            out.record.is_some()
        );
    }

    // PIT training frame.
    let spine = w.observation_spine(32);
    let observations: Vec<(String, i64)> =
        spine.iter().map(|(k, ts, _)| (k.clone(), *ts)).collect();
    let frame = fs.get_training_frame(
        &w.principal,
        Some(geofs::lineage::ModelId { name: "churn".into(), version: 1 }),
        &observations,
        &w.model_features(),
        PitConfig::default(),
        fs.config.home_region(),
    )?;
    println!(
        "training frame: {} rows × {} features, fill_rate={:.2}",
        frame.len(),
        frame.columns.len(),
        frame.fill_rate()
    );
    println!("\n{}", fs.metrics.render(None));
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    let days = args.i64("days", 7);
    materialize_history(&fs, &w, days)?;
    let n = args.usize("requests", 10_000);
    let regions: Vec<String> = fs.config.regions.clone();
    let trace = w.serving_trace(n, &regions);
    let t0 = std::time::Instant::now();
    let mut hits = 0u64;
    for (key, region) in &trace {
        if fs.get_online(&w.principal, &w.txn_table, key, region)?.record.is_some() {
            hits += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "served {n} lookups in {:.2?} ({:.0}/s), hit_rate={:.2}",
        dt,
        n as f64 / dt.as_secs_f64(),
        hits as f64 / n as f64
    );
    println!("\n{}", fs.metrics.render(None));
    Ok(())
}

fn cmd_materialize(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    materialize_history(&fs, &w, args.i64("days", 14))
}

fn cmd_backfill(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    let days = args.i64("days", 14);
    fs.clock.set(days * DAY);
    let window = FeatureWindow::new(0, days * DAY);
    let outcomes = fs.backfill(&w.txn_table, window)?;
    println!(
        "backfill {}: {} job(s), {} records",
        w.txn_table,
        outcomes.len(),
        outcomes.iter().map(|o| o.records).sum::<u64>()
    );
    Ok(())
}

fn cmd_bootstrap(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    materialize_history(&fs, &w, args.i64("days", 7))?;
    let direction = args.str("direction").unwrap_or("offline-to-online");
    let stats = match direction {
        "offline-to-online" => fs.bootstrap_online_from_offline(&w.txn_table)?,
        "online-to-offline" => fs.bootstrap_offline_from_online(&w.txn_table),
        other => anyhow::bail!("unknown --direction '{other}'"),
    };
    println!("bootstrap {direction}: inserted={} skipped={}", stats.inserted, stats.skipped);
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let (fs, _w) = open_with_workload(args)?;
    let text = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: geofs search <text>"))?;
    for hit in fs.catalog.search(&SearchQuery::text(text)) {
        println!(
            "{:<13} {}{} (store {})",
            hit.kind,
            hit.name,
            hit.version.map(|v| format!(":{v}")).unwrap_or_default(),
            hit.store
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let config = load_config(args)?;
    let manifest = geofs::runtime::Manifest::load(&config.artifacts_dir)?;
    println!("{} artifact(s) in {}:", manifest.artifacts.len(), manifest.dir.display());
    for a in &manifest.artifacts {
        println!(
            "  {:<22} variant={:<6} shape=[{}, {}+{}] window={}",
            a.name,
            a.variant.as_str(),
            a.entities,
            a.time_bins,
            a.window - 1,
            a.window
        );
    }
    Ok(())
}

fn cmd_metrics(args: &Args) -> anyhow::Result<()> {
    let (fs, w) = open_with_workload(args)?;
    materialize_history(&fs, &w, args.i64("days", 7))?;
    println!("{}", fs.metrics.render(None));
    Ok(())
}

fn help() {
    println!(
        "geofs — managed geo-distributed feature store (paper reproduction)\n\n\
         usage: geofs <command> [flags]\n\n\
         commands:\n  \
         demo         end-to-end churn scenario (materialize + serve + PIT)\n  \
         serve        materialize then serve a lookup trace\n  \
         materialize  run scheduled materialization over history\n  \
         backfill     one-time backfill over history\n  \
         bootstrap    --direction offline-to-online|online-to-offline\n  \
         search       <text>  asset search\n  \
         artifacts    list AOT artifacts\n  \
         metrics      run a short workload and dump metrics\n\n\
         common flags: --config FILE --customers N --days N --seed N --no-engine"
    );
}

fn main() {
    init_logging();
    let args = parse_args();
    let out = match args.cmd.as_str() {
        "demo" => cmd_demo(&args),
        "serve" => cmd_serve(&args),
        "materialize" => cmd_materialize(&args),
        "backfill" => cmd_backfill(&args),
        "bootstrap" => cmd_bootstrap(&args),
        "search" => cmd_search(&args),
        "artifacts" => cmd_artifacts(&args),
        "metrics" => cmd_metrics(&args),
        _ => {
            help();
            Ok(())
        }
    };
    if let Err(e) = out {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
