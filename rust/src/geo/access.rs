//! Cross-region access vs local-replica access (§4.1.2, Fig 4).
//!
//! Two mechanisms for a consuming workspace in region C to read assets of
//! a feature store homed in region H:
//!
//! * **CrossRegion** — data stays in H (geo-fence compliant); C pays
//!   `rtt(C, H)` per lookup, staleness 0 relative to H.
//! * **Replica** — reads a geo-replicated copy in C; local latency,
//!   staleness up to the replication lag; not allowed for geo-fenced
//!   stores.
//!
//! Routing prefers the mechanism the store's compliance policy allows,
//! then the lower-latency option.

use std::sync::Arc;

use super::replication::GeoReplicator;
use super::topology::GeoTopology;
use crate::online_store::OnlineStore;
use crate::types::{EntityId, FeatureRecord, Result, Timestamp};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMechanism {
    Local,
    CrossRegion,
    Replica,
}

/// Result of one routed lookup.
#[derive(Debug, Clone)]
pub struct RoutedLookup {
    pub record: Option<FeatureRecord>,
    pub mechanism: AccessMechanism,
    /// Simulated end-to-end latency (topology WAN cost + local lookup).
    pub latency_us: u64,
    /// Replica staleness at read time (0 for local/cross-region).
    pub staleness_secs: i64,
}

/// Result of one routed *batched* lookup: many keys, one routing
/// decision, and — crucially — one WAN round trip for the whole batch.
#[derive(Debug, Clone)]
pub struct RoutedBatch {
    /// Per-entity results, in input order.
    pub records: Vec<Option<FeatureRecord>>,
    pub mechanism: AccessMechanism,
    /// Simulated end-to-end latency of the whole batch (one WAN round
    /// trip + one batched store read).
    pub latency_us: u64,
    /// Replica staleness at read time (0 for local/cross-region).
    pub staleness_secs: i64,
}

/// Router for online reads against a store homed in `home_region`.
pub struct CrossRegionAccess {
    pub topology: Arc<GeoTopology>,
    pub home_region: String,
    pub home_store: Arc<OnlineStore>,
    /// Present when geo-replication is enabled for this store.
    pub replicator: Option<Arc<GeoReplicator>>,
    /// Geo-fenced stores must not be replicated out of region (§4.1.2
    /// "data compliance issues").
    pub geo_fenced: bool,
}

impl CrossRegionAccess {
    /// Decide the mechanism for a consumer region.
    pub fn route(&self, consumer_region: &str) -> AccessMechanism {
        if consumer_region == self.home_region {
            return AccessMechanism::Local;
        }
        if !self.geo_fenced {
            if let Some(rep) = &self.replicator {
                if rep.replica(consumer_region).is_some() {
                    return AccessMechanism::Replica;
                }
            }
        }
        AccessMechanism::CrossRegion
    }

    /// Resolve `consumer_region` to the store to read from, the
    /// simulated wire round-trip cost, and the staleness bound — the
    /// single source of routing truth shared by the point and batched
    /// lookups.
    fn route_target(
        &self,
        consumer_region: &str,
        now: Timestamp,
    ) -> Result<(AccessMechanism, &Arc<OnlineStore>, u64, i64)> {
        let mechanism = self.route(consumer_region);
        Ok(match mechanism {
            AccessMechanism::Local => (
                mechanism,
                &self.home_store,
                self.topology.rtt_us(consumer_region, consumer_region)?,
                0,
            ),
            AccessMechanism::CrossRegion => (
                mechanism,
                &self.home_store,
                // Pay the WAN round trip to the home region.
                self.topology.rtt_us(consumer_region, &self.home_region)?,
                0,
            ),
            AccessMechanism::Replica => {
                let rep = self.replicator.as_ref().expect("routed to replica");
                let store = rep.replica(consumer_region).expect("replica exists");
                (
                    mechanism,
                    store,
                    self.topology.rtt_us(consumer_region, consumer_region)?,
                    rep.staleness_secs(consumer_region, now),
                )
            }
        })
    }

    /// Routed lookup with simulated latency accounting.
    pub fn lookup(
        &self,
        consumer_region: &str,
        table: &str,
        entity: EntityId,
        now: Timestamp,
    ) -> Result<RoutedLookup> {
        let (mechanism, store, wire_us, staleness_secs) =
            self.route_target(consumer_region, now)?;
        let t0 = std::time::Instant::now();
        let record = store.get(table, entity, now);
        let compute = t0.elapsed().as_micros() as u64;
        Ok(RoutedLookup { record, mechanism, latency_us: wire_us + compute, staleness_secs })
    }

    /// Routed **batched** lookup: route once, then serve every entity
    /// through one `get_many` against the chosen store. A cross-region
    /// batch pays the WAN round trip once instead of once per key —
    /// this is the serving batcher's remote-read amortization.
    pub fn lookup_many(
        &self,
        consumer_region: &str,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
    ) -> Result<RoutedBatch> {
        let (mechanism, store, wire_us, staleness_secs) =
            self.route_target(consumer_region, now)?;
        let t0 = std::time::Instant::now();
        let records = store.get_many(table, entities, now);
        let compute = t0.elapsed().as_micros() as u64;
        Ok(RoutedBatch { records, mechanism, latency_us: wire_us + compute, staleness_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn setup(geo_fenced: bool, with_replica: bool) -> (CrossRegionAccess, Arc<OnlineStore>) {
        let topology = Arc::new(GeoTopology::default_four_region());
        let home = Arc::new(OnlineStore::new(2));
        home.merge("t", &[rec(1, 100, 150, 42.0)], 150);
        let replicator = with_replica.then(|| {
            let eu = Arc::new(OnlineStore::new(2));
            let r = Arc::new(GeoReplicator::new(vec![("westeurope".into(), eu, 30)]));
            r.enqueue("t", &[rec(1, 100, 150, 42.0)], 150);
            r.pump(1_000); // caught up
            r
        });
        (
            CrossRegionAccess {
                topology,
                home_region: "eastus".into(),
                home_store: home.clone(),
                replicator,
                geo_fenced,
            },
            home,
        )
    }

    #[test]
    fn local_reads_are_cheap() {
        let (a, _) = setup(false, false);
        let out = a.lookup("eastus", "t", 1, 1_000).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Local);
        assert!(out.latency_us < 5_000, "local should be sub-ms-ish: {}", out.latency_us);
        assert_eq!(out.record.unwrap().values[0], 42.0);
    }

    #[test]
    fn cross_region_pays_wan_rtt() {
        let (a, _) = setup(false, false);
        let out = a.lookup("westeurope", "t", 1, 1_000).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert!(out.latency_us >= 80_000, "must include 80ms RTT: {}", out.latency_us);
        assert_eq!(out.staleness_secs, 0);
        assert!(out.record.is_some());
    }

    #[test]
    fn replica_is_local_latency_but_stale() {
        let (a, _) = setup(false, true);
        let out = a.lookup("westeurope", "t", 1, 1_000).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Replica);
        assert!(out.latency_us < 5_000);
        assert!(out.record.is_some());

        // New write not yet pumped → replica still answers old data and
        // reports staleness.
        let rep = a.replicator.as_ref().unwrap();
        a.home_store.merge("t", &[rec(1, 200, 250, 99.0)], 1_500);
        rep.enqueue("t", &[rec(1, 200, 250, 99.0)], 1_500);
        let out = a.lookup("westeurope", "t", 1, 1_510).unwrap();
        assert_eq!(out.record.unwrap().values[0], 42.0); // stale value
        assert_eq!(out.staleness_secs, 10);
    }

    #[test]
    fn geo_fence_forces_cross_region() {
        let (a, _) = setup(true, true);
        let out = a.lookup("westeurope", "t", 1, 1_000).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
    }

    #[test]
    fn region_without_replica_goes_cross_region() {
        let (a, _) = setup(false, true);
        let out = a.lookup("southeastasia", "t", 1, 1_000).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert!(out.latency_us >= 220_000);
    }

    #[test]
    fn home_region_down_fails_cross_region_reads() {
        let (a, _) = setup(false, false);
        a.topology.set_down("eastus", true);
        assert!(a.lookup("westeurope", "t", 1, 0).is_err());
    }

    #[test]
    fn batched_lookup_matches_point_lookups() {
        let (a, home) = setup(false, true);
        home.merge("t", &[rec(2, 100, 150, 7.0)], 150);
        for region in ["eastus", "westeurope", "southeastasia"] {
            let batch = a.lookup_many(region, "t", &[1, 2, 9], 1_000).unwrap();
            assert_eq!(batch.records.len(), 3);
            for (i, &e) in [1u64, 2, 9].iter().enumerate() {
                let point = a.lookup(region, "t", e, 1_000).unwrap();
                assert_eq!(batch.mechanism, point.mechanism, "{region}");
                assert_eq!(
                    batch.records[i].as_ref().map(|r| r.entity),
                    point.record.as_ref().map(|r| r.entity),
                    "{region} entity {e}"
                );
            }
        }
    }

    #[test]
    fn batched_cross_region_pays_one_rtt() {
        let (a, _) = setup(false, false);
        // 32 keys from westeurope: one 80ms RTT for the whole batch, not 32.
        let keys: Vec<u64> = (0..32).collect();
        let batch = a.lookup_many("westeurope", "t", &keys, 1_000).unwrap();
        assert_eq!(batch.mechanism, AccessMechanism::CrossRegion);
        assert!(batch.latency_us >= 80_000, "must include one RTT: {}", batch.latency_us);
        assert!(
            batch.latency_us < 2 * 80_000,
            "batch must not pay per-key RTTs: {}",
            batch.latency_us
        );
    }

    #[test]
    fn batched_lookup_respects_outage() {
        let (a, _) = setup(false, false);
        a.topology.set_down("eastus", true);
        assert!(a.lookup_many("westeurope", "t", &[1], 0).is_err());
    }
}
