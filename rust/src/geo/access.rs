//! Cross-region access vs local-replica access (§4.1.2, Fig 4), routed
//! under an explicit consistency policy.
//!
//! Two mechanisms for a consuming workspace in region C to read assets of
//! a feature store homed in region H:
//!
//! * **CrossRegion** — data stays in H (geo-fence compliant); C pays
//!   `rtt(C, H)` per lookup, staleness 0 relative to H.
//! * **Replica** — reads a fabric-replicated copy in C; local latency,
//!   staleness up to the replication lag; not allowed for geo-fenced
//!   stores.
//!
//! The choice between them is no longer just "replica if it exists":
//! every read carries a [`ReadConsistency`] policy and the router
//! consults the replication fabric's log positions to honor it —
//!
//! * [`ReadConsistency::Strong`] always reads the home region (one WAN
//!   RTT from elsewhere, staleness 0).
//! * [`ReadConsistency::BoundedStaleness`]`(secs)` serves from the local
//!   replica only while its log-position staleness is within the bound;
//!   a replica past the bound **falls back to cross-region** instead of
//!   serving stale data.
//! * [`ReadConsistency::ReadYourWrites`]`(token)` serves from a replica
//!   only once its cursors cover the session token the write returned;
//!   otherwise the read crosses to the home region, so a session never
//!   observes state older than its own writes.
//!
//! Geo-fencing and region health still dominate: a geo-fenced store
//! never routes to a replica, and outages surface as errors from the
//! topology.

use std::sync::Arc;

use super::replication::{ReplicationFabric, SessionToken};
use super::topology::GeoTopology;
use crate::monitor::trace::TraceContext;
use crate::online_store::OnlineStore;
use crate::types::{EntityId, FeatureRecord, Result, Timestamp};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessMechanism {
    Local,
    CrossRegion,
    Replica,
}

/// Per-read consistency policy (threaded through `OnlineServing` and
/// `FeatureStore::get_online_many{_mixed}`).
#[derive(Debug, Clone, PartialEq)]
pub enum ReadConsistency {
    /// Always read the home region: staleness 0, one WAN RTT from
    /// non-home regions.
    Strong,
    /// Serve from a local replica only while its log-position staleness
    /// is within the bound (seconds); else fall back to cross-region.
    BoundedStaleness(i64),
    /// Serve from a replica only once it covers the session token
    /// (per-partition fabric offsets) returned by the session's writes.
    ReadYourWrites(SessionToken),
}

impl Default for ReadConsistency {
    /// Eventual consistency: any replica, however stale — the pre-policy
    /// routing behavior.
    fn default() -> Self {
        ReadConsistency::BoundedStaleness(i64::MAX)
    }
}

/// Result of one routed lookup.
#[derive(Debug, Clone)]
pub struct RoutedLookup {
    pub record: Option<FeatureRecord>,
    pub mechanism: AccessMechanism,
    /// Simulated end-to-end latency (topology WAN cost + local lookup).
    pub latency_us: u64,
    /// Replica staleness at read time (0 for local/cross-region).
    pub staleness_secs: i64,
}

/// Result of one routed *batched* lookup: many keys, one routing
/// decision, and — crucially — one WAN round trip for the whole batch.
#[derive(Debug, Clone)]
pub struct RoutedBatch {
    /// Per-entity results, in input order.
    pub records: Vec<Option<FeatureRecord>>,
    pub mechanism: AccessMechanism,
    /// Simulated end-to-end latency of the whole batch (one WAN round
    /// trip + one batched store read).
    pub latency_us: u64,
    /// Replica staleness at read time (0 for local/cross-region).
    pub staleness_secs: i64,
}

/// Router for online reads against a store homed in `home_region`.
pub struct CrossRegionAccess {
    pub topology: Arc<GeoTopology>,
    pub home_region: String,
    pub home_store: Arc<OnlineStore>,
    /// Present when geo-replication is enabled for this store — the
    /// single replication plane whose cursors/staleness drive policy
    /// routing.
    pub fabric: Option<Arc<ReplicationFabric>>,
    /// Geo-fenced stores must not be replicated out of region (§4.1.2
    /// "data compliance issues").
    pub geo_fenced: bool,
}

impl CrossRegionAccess {
    /// Capability routing: the mechanism a consumer region *could* use,
    /// ignoring staleness (a replica exists and compliance allows it).
    pub fn route(&self, consumer_region: &str) -> AccessMechanism {
        if consumer_region == self.home_region {
            return AccessMechanism::Local;
        }
        if !self.geo_fenced {
            if let Some(f) = &self.fabric {
                if f.replica(consumer_region).is_some() {
                    return AccessMechanism::Replica;
                }
            }
        }
        AccessMechanism::CrossRegion
    }

    /// Policy routing: the mechanism this read actually uses. A replica
    /// is eligible only when the capability route allows it **and** the
    /// policy's freshness requirement holds against the fabric's log
    /// positions at `now`.
    pub fn route_policy(
        &self,
        consumer_region: &str,
        consistency: &ReadConsistency,
        now: Timestamp,
    ) -> AccessMechanism {
        self.policy_route(consumer_region, consistency, now).0
    }

    /// [`CrossRegionAccess::route_policy`] plus the replica staleness it
    /// already had to compute (0 for local/cross-region) — the lookups
    /// use this so the hot path consults the fabric's cursors once per
    /// routing decision, not twice.
    fn policy_route(
        &self,
        consumer_region: &str,
        consistency: &ReadConsistency,
        now: Timestamp,
    ) -> (AccessMechanism, i64) {
        let mech = self.route(consumer_region);
        if mech != AccessMechanism::Replica {
            return (mech, 0);
        }
        let fabric = self.fabric.as_ref().expect("replica route implies fabric");
        match consistency {
            ReadConsistency::Strong => (AccessMechanism::CrossRegion, 0),
            ReadConsistency::BoundedStaleness(bound) => {
                let staleness = fabric.staleness_secs(consumer_region, now);
                if staleness <= *bound {
                    (AccessMechanism::Replica, staleness)
                } else {
                    (AccessMechanism::CrossRegion, 0)
                }
            }
            ReadConsistency::ReadYourWrites(token) => {
                if fabric.covers(consumer_region, token) {
                    (AccessMechanism::Replica, fabric.staleness_secs(consumer_region, now))
                } else {
                    (AccessMechanism::CrossRegion, 0)
                }
            }
        }
    }

    /// Resolve `consumer_region` + policy to the store to read from, the
    /// simulated wire round-trip cost, and the staleness bound — the
    /// single source of routing truth shared by the point and batched
    /// lookups.
    fn route_target(
        &self,
        consumer_region: &str,
        consistency: &ReadConsistency,
        now: Timestamp,
    ) -> Result<(AccessMechanism, &Arc<OnlineStore>, u64, i64)> {
        let (mechanism, staleness_secs) = self.policy_route(consumer_region, consistency, now);
        Ok(match mechanism {
            AccessMechanism::Local => (
                mechanism,
                &self.home_store,
                self.topology.rtt_us(consumer_region, consumer_region)?,
                0,
            ),
            AccessMechanism::CrossRegion => (
                mechanism,
                &self.home_store,
                // Pay the WAN round trip to the home region.
                self.topology.rtt_us(consumer_region, &self.home_region)?,
                0,
            ),
            AccessMechanism::Replica => {
                let fabric = self.fabric.as_ref().expect("routed to replica");
                let store = fabric.replica(consumer_region).expect("replica exists");
                (
                    mechanism,
                    store,
                    self.topology.rtt_us(consumer_region, consumer_region)?,
                    staleness_secs,
                )
            }
        })
    }

    /// Routed lookup with simulated latency accounting.
    pub fn lookup(
        &self,
        consumer_region: &str,
        table: &str,
        entity: EntityId,
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<RoutedLookup> {
        let (mechanism, store, wire_us, staleness_secs) =
            self.route_target(consumer_region, consistency, now)?;
        let t0 = std::time::Instant::now();
        let record = store.get(table, entity, now);
        let compute = t0.elapsed().as_micros() as u64;
        Ok(RoutedLookup { record, mechanism, latency_us: wire_us + compute, staleness_secs })
    }

    /// Routed **batched** lookup: route once, then serve every entity
    /// through one `get_many` against the chosen store. A cross-region
    /// batch pays the WAN round trip once instead of once per key —
    /// this is the serving batcher's remote-read amortization.
    pub fn lookup_many(
        &self,
        consumer_region: &str,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
        consistency: &ReadConsistency,
    ) -> Result<RoutedBatch> {
        self.lookup_many_traced(consumer_region, table, entities, now, consistency, None)
    }

    /// [`Self::lookup_many`] with request tracing: when the request was
    /// sampled, records the routing decision (mechanism, consistency
    /// policy, replica staleness, simulated wire cost) and a timed span
    /// around the store read with its hit count.
    pub fn lookup_many_traced(
        &self,
        consumer_region: &str,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
        consistency: &ReadConsistency,
        trace: Option<&TraceContext>,
    ) -> Result<RoutedBatch> {
        let (mechanism, store, wire_us, staleness_secs) =
            self.route_target(consumer_region, consistency, now)?;
        if let Some(t) = trace {
            t.event(
                "route",
                format!(
                    "mech={mechanism:?} consistency={consistency:?} \
                     staleness={staleness_secs}s wire_us={wire_us}"
                ),
            );
        }
        let g = trace.map(|t| t.span("store_read"));
        let t0 = std::time::Instant::now();
        let records = store.get_many(table, entities, now);
        let compute = t0.elapsed().as_micros() as u64;
        if let Some(g) = &g {
            let hits = records.iter().filter(|r| r.is_some()).count();
            g.note(format!("keys={} hits={hits}", entities.len()));
        }
        Ok(RoutedBatch { records, mechanism, latency_us: wire_us + compute, staleness_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::replication::ReplicationFabric;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn eventual() -> ReadConsistency {
        ReadConsistency::default()
    }

    fn setup(geo_fenced: bool, with_replica: bool) -> (CrossRegionAccess, Arc<OnlineStore>) {
        let topology = Arc::new(GeoTopology::default_four_region());
        let home = Arc::new(OnlineStore::new(2));
        home.merge("t", &[rec(1, 100, 150, 42.0)], 150);
        let fabric = with_replica.then(|| {
            let eu = Arc::new(OnlineStore::new(2));
            let f = ReplicationFabric::new(2, vec![("westeurope".into(), eu, 30)], None);
            f.append("t", &[rec(1, 100, 150, 42.0)], 150).unwrap();
            f.pump(1_000); // caught up
            f
        });
        (
            CrossRegionAccess {
                topology,
                home_region: "eastus".into(),
                home_store: home.clone(),
                fabric,
                geo_fenced,
            },
            home,
        )
    }

    #[test]
    fn local_reads_are_cheap() {
        let (a, _) = setup(false, false);
        let out = a.lookup("eastus", "t", 1, 1_000, &eventual()).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Local);
        assert!(out.latency_us < 5_000, "local should be sub-ms-ish: {}", out.latency_us);
        assert_eq!(out.record.unwrap().values[0], 42.0);
    }

    #[test]
    fn cross_region_pays_wan_rtt() {
        let (a, _) = setup(false, false);
        let out = a.lookup("westeurope", "t", 1, 1_000, &eventual()).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert!(out.latency_us >= 80_000, "must include 80ms RTT: {}", out.latency_us);
        assert_eq!(out.staleness_secs, 0);
        assert!(out.record.is_some());
    }

    #[test]
    fn replica_is_local_latency_but_stale() {
        let (a, _) = setup(false, true);
        let out = a.lookup("westeurope", "t", 1, 1_000, &eventual()).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Replica);
        assert!(out.latency_us < 5_000);
        assert!(out.record.is_some());

        // New write not yet applied → replica still answers old data and
        // reports staleness.
        let fabric = a.fabric.as_ref().unwrap();
        a.home_store.merge("t", &[rec(1, 200, 250, 99.0)], 1_500);
        fabric.append("t", &[rec(1, 200, 250, 99.0)], 1_500).unwrap();
        let out = a.lookup("westeurope", "t", 1, 1_510, &eventual()).unwrap();
        assert_eq!(out.record.unwrap().values[0], 42.0); // stale value
        assert_eq!(out.staleness_secs, 10);
    }

    #[test]
    fn strong_always_reads_home() {
        let (a, _) = setup(false, true);
        let out = a.lookup("westeurope", "t", 1, 1_000, &ReadConsistency::Strong).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert!(out.latency_us >= 80_000);
        assert_eq!(out.staleness_secs, 0);
        // Home consumers stay local under every policy.
        let out = a.lookup("eastus", "t", 1, 1_000, &ReadConsistency::Strong).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Local);
    }

    #[test]
    fn bounded_staleness_falls_back_past_the_bound() {
        let (a, home) = setup(false, true);
        let fabric = a.fabric.as_ref().unwrap().clone();
        // A write at t=1500 not yet applied: staleness grows with now.
        home.merge("t", &[rec(1, 200, 250, 99.0)], 1_500);
        fabric.append("t", &[rec(1, 200, 250, 99.0)], 1_500).unwrap();
        // Within the bound: replica serves (stale data is acceptable).
        let out = a
            .lookup("westeurope", "t", 1, 1_510, &ReadConsistency::BoundedStaleness(60))
            .unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Replica);
        assert_eq!(out.record.unwrap().values[0], 42.0);
        // Past the bound: fall back to cross-region, fresh data.
        let out = a
            .lookup("westeurope", "t", 1, 1_510, &ReadConsistency::BoundedStaleness(5))
            .unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert_eq!(out.record.unwrap().values[0], 99.0);
        // Replica catches up → bound satisfied again.
        fabric.pump(1_540);
        let out = a
            .lookup("westeurope", "t", 1, 1_545, &ReadConsistency::BoundedStaleness(5))
            .unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Replica);
        assert_eq!(out.record.unwrap().values[0], 99.0);
    }

    #[test]
    fn read_your_writes_gates_on_the_token() {
        let (a, home) = setup(false, true);
        let fabric = a.fabric.as_ref().unwrap().clone();
        home.merge("t", &[rec(1, 200, 250, 99.0)], 1_500);
        let token = fabric.append("t", &[rec(1, 200, 250, 99.0)], 1_500).unwrap();
        // Replica does not cover the token yet: read crosses to home and
        // sees the session's own write.
        let rw = ReadConsistency::ReadYourWrites(token.clone());
        let out = a.lookup("westeurope", "t", 1, 1_510, &rw).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert_eq!(out.record.unwrap().values[0], 99.0);
        // Once the cursors cover the token, the replica serves locally.
        fabric.pump(1_530);
        let out = a.lookup("westeurope", "t", 1, 1_540, &rw).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::Replica);
        assert_eq!(out.record.unwrap().values[0], 99.0);
    }

    #[test]
    fn geo_fence_forces_cross_region() {
        let (a, _) = setup(true, true);
        let out = a.lookup("westeurope", "t", 1, 1_000, &eventual()).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
    }

    #[test]
    fn region_without_replica_goes_cross_region() {
        let (a, _) = setup(false, true);
        let out = a.lookup("southeastasia", "t", 1, 1_000, &eventual()).unwrap();
        assert_eq!(out.mechanism, AccessMechanism::CrossRegion);
        assert!(out.latency_us >= 220_000);
    }

    #[test]
    fn home_region_down_fails_cross_region_reads() {
        let (a, _) = setup(false, false);
        a.topology.set_down("eastus", true);
        assert!(a.lookup("westeurope", "t", 1, 0, &eventual()).is_err());
    }

    #[test]
    fn batched_lookup_matches_point_lookups() {
        let (a, home) = setup(false, true);
        home.merge("t", &[rec(2, 100, 150, 7.0)], 150);
        for region in ["eastus", "westeurope", "southeastasia"] {
            let batch = a.lookup_many(region, "t", &[1, 2, 9], 1_000, &eventual()).unwrap();
            assert_eq!(batch.records.len(), 3);
            for (i, &e) in [1u64, 2, 9].iter().enumerate() {
                let point = a.lookup(region, "t", e, 1_000, &eventual()).unwrap();
                assert_eq!(batch.mechanism, point.mechanism, "{region}");
                assert_eq!(
                    batch.records[i].as_ref().map(|r| r.entity),
                    point.record.as_ref().map(|r| r.entity),
                    "{region} entity {e}"
                );
            }
        }
    }

    #[test]
    fn batched_cross_region_pays_one_rtt() {
        let (a, _) = setup(false, false);
        // 32 keys from westeurope: one 80ms RTT for the whole batch, not 32.
        let keys: Vec<u64> = (0..32).collect();
        let batch = a.lookup_many("westeurope", "t", &keys, 1_000, &eventual()).unwrap();
        assert_eq!(batch.mechanism, AccessMechanism::CrossRegion);
        assert!(batch.latency_us >= 80_000, "must include one RTT: {}", batch.latency_us);
        assert!(
            batch.latency_us < 2 * 80_000,
            "batch must not pay per-key RTTs: {}",
            batch.latency_us
        );
    }

    #[test]
    fn batched_lookup_respects_outage() {
        let (a, _) = setup(false, false);
        a.topology.set_down("eastus", true);
        assert!(a.lookup_many("westeurope", "t", &[1], 0, &eventual()).is_err());
    }
}
