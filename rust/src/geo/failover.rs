//! Region failover (§3.1.2): "when one region is down, we may want to
//! use the resources from cross regions to ensure high availability.
//! Also, when the runtime comes back up, we need to make sure it can
//! safely resume from where it left off without any data loss."
//!
//! The unit of recovery is the [`RegionCheckpoint`]: metadata snapshot +
//! scheduler coverage + durable offline segments.  A standby region
//! restores the checkpoint and resumes scheduled materialization from the
//! exact high-water mark; the offline store reloads from segments and the
//! online store is rebuilt via the §4.5.5 bootstrap.

use std::path::PathBuf;
use std::sync::Arc;

use super::topology::GeoTopology;
use crate::materialize::bootstrap_offline_to_online;
use crate::offline_store::{CompactionDriver, OfflineStore};
use crate::online_store::OnlineStore;
use crate::scheduler::Scheduler;
use crate::types::{FeatureWindow, FsError, Result, Timestamp};

/// Everything a promoted standby runs with after [`FailoverManager::failover`]:
/// the restored stores plus the background compaction driver the
/// restored offline store needs as the new write target (segment
/// folding is background-only — without a driver the promoted region
/// would accumulate segments without bound, exactly like
/// `FeatureStore::open` would without its own driver). Dropping the
/// outcome stops the driver.
pub struct PromotedRegion {
    pub region: String,
    pub offline: Arc<OfflineStore>,
    pub online: Arc<OnlineStore>,
    pub compaction: CompactionDriver,
}

/// Everything a standby region needs to take over.
#[derive(Debug, Clone)]
pub struct RegionCheckpoint {
    pub region: String,
    pub taken_at: Timestamp,
    /// Scheduler data-state: per-table materialized coverage.
    pub coverage: Vec<(String, Vec<FeatureWindow>)>,
    /// Directory of persisted offline segments.
    pub offline_dir: PathBuf,
}

/// Orchestrates checkpoint/restore across regions.
pub struct FailoverManager {
    pub topology: Arc<GeoTopology>,
}

impl FailoverManager {
    pub fn new(topology: Arc<GeoTopology>) -> Self {
        FailoverManager { topology }
    }

    /// Periodic checkpoint of the active region (cheap: coverage list +
    /// segment flush).
    pub fn checkpoint(
        &self,
        region: &str,
        scheduler: &Scheduler,
        offline: &OfflineStore,
        offline_dir: PathBuf,
        now: Timestamp,
    ) -> Result<RegionCheckpoint> {
        // Capture scheduler coverage BEFORE flushing segments: the
        // offline store locks per table now, so a merge can land midway
        // through the dump. With coverage-first ordering such a merge
        // only adds rows beyond the recorded coverage — a restore then
        // re-materializes those windows (idempotently) instead of
        // trusting coverage for rows the dump may have missed.
        let coverage = scheduler.checkpoint();
        offline.persist(&offline_dir)?;
        Ok(RegionCheckpoint { region: region.to_string(), taken_at: now, coverage, offline_dir })
    }

    /// Fail over to the nearest up standby. Restores scheduler coverage
    /// and the offline store (with its own background compaction
    /// driver); rebuilds the online store from offline (bootstrap
    /// §4.5.5).
    pub fn failover(
        &self,
        checkpoint: &RegionCheckpoint,
        standby_scheduler: &Scheduler,
        online_shards: usize,
        now: Timestamp,
    ) -> Result<PromotedRegion> {
        if self.topology.is_up(&checkpoint.region) {
            log::warn!("failover requested while '{}' is up", checkpoint.region);
        }
        let standby = self
            .topology
            .nearest_standby(&checkpoint.region)
            .ok_or_else(|| FsError::Other("no standby region available".into()))?;

        // 1. Restore durable offline data.
        let offline = Arc::new(OfflineStore::load(&checkpoint.offline_dir)?);
        // 2. Restore scheduler data-state (resume point, no re-work, no gaps).
        standby_scheduler.restore(&checkpoint.coverage);
        // 3. Rebuild online serving state from offline (bootstrap).
        let online = Arc::new(OnlineStore::new(online_shards));
        for table in offline.tables() {
            bootstrap_offline_to_online(&offline, &online, &table, now);
        }
        log::info!(
            "failover: '{}' → '{}' restored {} table(s)",
            checkpoint.region,
            standby,
            offline.tables().len()
        );
        // 4. The promoted store is the new write target: give it the
        // background tier folding every live store needs.
        let compaction =
            CompactionDriver::spawn(offline.clone(), std::time::Duration::from_millis(100));
        Ok(PromotedRegion { region: standby, offline, online, compaction })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{RetryPolicy, ThreadPool};
    use crate::testkit::TempDir;
    use crate::types::FeatureRecord;
    use crate::util::Clock;

    fn scheduler() -> Scheduler {
        Scheduler::new(Arc::new(ThreadPool::new(2)), Clock::fixed(0), RetryPolicy::default())
    }

    #[test]
    fn checkpoint_restore_no_data_loss() {
        let topology = Arc::new(GeoTopology::default_four_region());
        let fm = FailoverManager::new(topology.clone());

        // Active region state: offline rows + scheduler coverage.
        let offline = OfflineStore::new();
        offline.merge(
            "txn:1",
            &[
                FeatureRecord::new(1, 100, 150, vec![1.0]),
                FeatureRecord::new(1, 200, 250, vec![2.0]),
                FeatureRecord::new(2, 100, 160, vec![3.0]),
            ],
        );
        let active = scheduler();
        // Mark coverage by claiming+completing.
        active.restore(&[("txn:1".to_string(), vec![FeatureWindow::new(0, 300)])]);

        let dir = TempDir::new("fo-a");
        let cp = fm
            .checkpoint("eastus", &active, &offline, dir.path().to_path_buf(), 500)
            .unwrap();

        // Region goes down; fail over.
        topology.set_down("eastus", true);
        let standby_sched = scheduler();
        let promoted = fm.failover(&cp, &standby_sched, 4, 600).unwrap();
        let (off2, on2) = (promoted.offline.clone(), promoted.online.clone());
        assert_eq!(promoted.region, "westus");
        // No data loss offline.
        assert_eq!(off2.row_count("txn:1"), 3);
        // Online rebuilt to Eq. 2 state.
        assert_eq!(on2.get("txn:1", 1, 700).unwrap().version(), (200, 250));
        // Scheduler resumes from the checkpointed high-water: nothing
        // before 300 is re-materialized.
        assert!(standby_sched.is_materialized("txn:1", &FeatureWindow::new(0, 300)));
        assert_eq!(
            standby_sched.gaps("txn:1", FeatureWindow::new(0, 400)),
            vec![FeatureWindow::new(300, 400)]
        );
    }

    #[test]
    fn failover_needs_a_standby() {
        let topology = Arc::new(GeoTopology::new(&["solo"], &[], 100));
        let fm = FailoverManager::new(topology.clone());
        topology.set_down("solo", true);
        let dir = TempDir::new("fo-b");
        let cp = RegionCheckpoint {
            region: "solo".into(),
            taken_at: 0,
            coverage: vec![],
            offline_dir: dir.file("never-written"),
        };
        assert!(fm.failover(&cp, &scheduler(), 2, 0).is_err());
    }
}
