//! Region failover (§3.1.2): "when one region is down, we may want to
//! use the resources from cross regions to ensure high availability.
//! Also, when the runtime comes back up, we need to make sure it can
//! safely resume from where it left off without any data loss."
//!
//! The unit of recovery is the [`RegionCheckpoint`]: metadata snapshot +
//! scheduler coverage + durable offline segments.  A standby region
//! restores the checkpoint and resumes scheduled materialization from the
//! exact high-water mark; the offline store reloads from segments and the
//! online store is rebuilt via the §4.5.5 bootstrap.
//!
//! With a replication fabric attached, failover additionally **replays
//! the fabric log** ([`FailoverManager::failover_with`]): acked writes
//! that reached the fabric but had not replicated everywhere (or were
//! newer than the last checkpoint) are merged back into the restored
//! stores before promotion, so promotion loses no acked write. The
//! promoted region comes back as a first-class home: the standby's
//! replica store (which already holds the applied prefix) is promoted
//! in place, and a fresh fabric over the surviving regions starts with
//! its own running [`ReplicationDriver`].
//!
//! **Restarting the *same* region** (process crash, not region loss) no
//! longer needs a [`RegionCheckpoint`] at all: a store opened with
//! [`crate::coordinator::OpenOptions::durability`] recovers from its
//! manifest-addressed WAL — newest valid manifest + fragment tail
//! replay above the recorded cursors (see [`crate::storage`]). The
//! full-dump checkpoint here remains the cross-region hand-off format.

use std::path::PathBuf;
use std::sync::Arc;

use super::replication::{ReplicationDriver, ReplicationFabric};
use super::topology::GeoTopology;
use crate::exec::ThreadPool;
use crate::materialize::bootstrap_offline_to_online;
use crate::monitor::metrics::MetricsRegistry;
use crate::offline_store::{CompactionDriver, OfflineStore};
use crate::online_store::OnlineStore;
use crate::scheduler::Scheduler;
use crate::types::{FeatureWindow, FsError, Result, Timestamp};
use crate::util::backoff::{retry, Backoff};
use crate::util::Clock;

/// Everything a promoted standby runs with after [`FailoverManager::failover`]:
/// the restored stores plus the background drivers the new home needs —
/// a [`CompactionDriver`] (segment folding is background-only; without
/// one the promoted region would accumulate segments without bound) and,
/// when failover ran with a fabric, the promoted region's own
/// [`ReplicationFabric`] + running [`ReplicationDriver`] over the
/// surviving replica regions. Dropping the outcome stops the drivers.
pub struct PromotedRegion {
    pub region: String,
    pub offline: Arc<OfflineStore>,
    pub online: Arc<OnlineStore>,
    pub compaction: CompactionDriver,
    /// The new home's replication plane (surviving regions only; the
    /// dead home re-joins via bootstrap when it returns). `None` when
    /// failover ran without a fabric.
    pub fabric: Option<Arc<ReplicationFabric>>,
    pub replication: Option<ReplicationDriver>,
}

/// Everything a standby region needs to take over.
#[derive(Debug, Clone)]
pub struct RegionCheckpoint {
    pub region: String,
    pub taken_at: Timestamp,
    /// Scheduler data-state: per-table materialized coverage.
    pub coverage: Vec<(String, Vec<FeatureWindow>)>,
    /// Directory of persisted offline segments.
    pub offline_dir: PathBuf,
}

/// Orchestrates checkpoint/restore across regions.
pub struct FailoverManager {
    pub topology: Arc<GeoTopology>,
}

impl FailoverManager {
    pub fn new(topology: Arc<GeoTopology>) -> Self {
        FailoverManager { topology }
    }

    /// Periodic checkpoint of the active region (cheap: coverage list +
    /// segment flush).
    pub fn checkpoint(
        &self,
        region: &str,
        scheduler: &Scheduler,
        offline: &OfflineStore,
        offline_dir: PathBuf,
        now: Timestamp,
    ) -> Result<RegionCheckpoint> {
        // Capture scheduler coverage BEFORE flushing segments: the
        // offline store locks per table now, so a merge can land midway
        // through the dump. With coverage-first ordering such a merge
        // only adds rows beyond the recorded coverage — a restore then
        // re-materializes those windows (idempotently) instead of
        // trusting coverage for rows the dump may have missed.
        let coverage = scheduler.checkpoint();
        offline.persist(&offline_dir)?;
        Ok(RegionCheckpoint { region: region.to_string(), taken_at: now, coverage, offline_dir })
    }

    /// Fail over to the nearest up standby without a replication fabric
    /// (checkpoint + bootstrap only; see [`FailoverManager::failover_with`]).
    pub fn failover(
        &self,
        checkpoint: &RegionCheckpoint,
        standby_scheduler: &Scheduler,
        online_shards: usize,
        now: Timestamp,
    ) -> Result<PromotedRegion> {
        self.failover_with(
            checkpoint,
            standby_scheduler,
            online_shards,
            now,
            None,
            Clock::fixed(now),
            None,
            None,
        )
    }

    /// Fail over to the nearest up standby. Restores scheduler coverage
    /// and the offline store; promotes the standby's fabric replica
    /// store (or bootstraps a fresh one from offline, §4.5.5); then
    /// replays the retained fabric log — the full history into the
    /// offline store (durability for acked writes newer than the
    /// checkpoint) and the tail above the standby's applied cursor into
    /// the online store (acked writes that had not replicated yet).
    /// Both replays are idempotent: offline dedupes on the uniqueness
    /// key, online's Eq. 2 merge is a monotone no-op. The promoted
    /// region gets its own fabric over the surviving replica regions
    /// with a running [`ReplicationDriver`] (ticking on `clock`,
    /// gauging through `metrics`), and the retained log is forwarded
    /// into it so survivors whose cursors trailed the promoted region's
    /// also converge on every acked write.
    ///
    /// With a `pool`, the per-partition log replay fans out across it —
    /// the replay is the dominant cost of a failover on a deep log, and
    /// partitions are independent (replay order matters only *within*
    /// one; all three sinks absorb cross-partition interleavings
    /// idempotently, which is exactly what
    /// `parallel_fabric_replay_is_equivalent_to_sequential` pins).
    #[allow(clippy::too_many_arguments)]
    pub fn failover_with(
        &self,
        checkpoint: &RegionCheckpoint,
        standby_scheduler: &Scheduler,
        online_shards: usize,
        now: Timestamp,
        fabric: Option<&Arc<ReplicationFabric>>,
        clock: Clock,
        metrics: Option<Arc<MetricsRegistry>>,
        pool: Option<&Arc<ThreadPool>>,
    ) -> Result<PromotedRegion> {
        if self.topology.is_up(&checkpoint.region) {
            log::warn!("failover requested while '{}' is up", checkpoint.region);
        }
        let standby = self
            .topology
            .nearest_standby(&checkpoint.region)
            .ok_or_else(|| FsError::Other("no standby region available".into()))?;

        // 1. Restore durable offline data.
        let offline = Arc::new(OfflineStore::load(&checkpoint.offline_dir)?);
        // 2. Restore scheduler data-state (resume point, no re-work, no gaps).
        standby_scheduler.restore(&checkpoint.coverage);
        // 3. Online serving state: promote the standby's replica store in
        // place when the fabric has one (it already applied the log
        // prefix below its cursor); else start fresh. Either way,
        // bootstrap from offline fills history from before replication.
        let online = fabric
            .and_then(|f| f.replica(&standby).cloned())
            .unwrap_or_else(|| Arc::new(OnlineStore::new(online_shards)));
        for table in offline.tables() {
            bootstrap_offline_to_online(&offline, &online, &table, now);
        }
        // 4. Re-home replication: a fresh fabric over the surviving
        // regions, driven by the promoted region's own driver thread.
        let (new_fabric, replication) = match fabric {
            Some(f) => {
                let survivors: Vec<_> =
                    f.replica_set().into_iter().filter(|(r, _, _)| *r != standby).collect();
                let nf = ReplicationFabric::new(f.partitions(), survivors, metrics);
                let driver = ReplicationDriver::spawn(
                    nf.clone(),
                    clock,
                    std::time::Duration::from_millis(20),
                );
                (Some(nf), Some(driver))
            }
            None => (None, None),
        };
        // 5. Replay the retained fabric log: no acked write is lost even
        // if it post-dates the checkpoint and never reached a replica.
        // Every retained entry goes into the restored offline store
        // (durability), entries above the standby's applied cursor go
        // into the promoted online store (the below-cursor prefix is
        // already applied there), and everything is forwarded into the
        // new fabric so surviving replicas — whose old cursors may trail
        // the promoted region's — converge through their new cursors.
        // All three sinks absorb duplicates idempotently.
        let mut replayed = 0u64;
        if let Some(f) = fabric {
            let cursors = f.cursors(&standby);
            let counts: Vec<Result<u64>> = match pool {
                Some(pool) if f.partitions() > 1 => {
                    let handles: Vec<_> = (0..f.partitions())
                        .map(|p| {
                            let f = f.clone();
                            let offline = offline.clone();
                            let online = online.clone();
                            let nf = new_fabric.clone();
                            let cursor = cursors[p];
                            pool.submit(move || {
                                replay_fabric_partition(
                                    &f,
                                    p,
                                    cursor,
                                    &offline,
                                    &online,
                                    nf.as_ref(),
                                    now,
                                )
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                }
                _ => (0..f.partitions())
                    .map(|p| {
                        replay_fabric_partition(
                            f,
                            p,
                            cursors[p],
                            &offline,
                            &online,
                            new_fabric.as_ref(),
                            now,
                        )
                    })
                    .collect(),
            };
            for c in counts {
                replayed += c?;
            }
        }
        log::info!(
            "failover: '{}' → '{}' restored {} table(s), replayed {} fabric record(s)",
            checkpoint.region,
            standby,
            offline.tables().len(),
            replayed
        );
        // 6. The promoted store is the new write target: give it the
        // background tier folding every live store needs.
        let compaction =
            CompactionDriver::spawn(offline.clone(), std::time::Duration::from_millis(100));
        Ok(PromotedRegion {
            region: standby,
            offline,
            online,
            compaction,
            fabric: new_fabric,
            replication,
        })
    }
}

/// Replay one retained-fabric partition (step 5 of
/// [`FailoverManager::failover_with`]): the full history into the
/// offline store, the tail at or above `cursor` into the promoted
/// online store, everything forwarded into the new fabric. Returns the
/// record count merged online. Partitions never share entries, so
/// running this for different partitions concurrently is safe — order
/// matters only within one partition, and every sink absorbs
/// cross-partition interleavings idempotently.
fn replay_fabric_partition(
    f: &ReplicationFabric,
    p: usize,
    cursor: u64,
    offline: &OfflineStore,
    online: &OnlineStore,
    new_fabric: Option<&Arc<ReplicationFabric>>,
    now: Timestamp,
) -> Result<u64> {
    let mut replayed = 0u64;
    let mut cur = 0u64;
    loop {
        let entries = f.read_tail(p, cur, 256);
        if entries.is_empty() {
            return Ok(replayed);
        }
        for (off, batch) in entries {
            offline.merge(&batch.table, &batch.records);
            if off >= cursor {
                online.merge(&batch.table, &batch.records, now);
                replayed += batch.records.len() as u64;
            }
            if let Some(nf) = new_fabric {
                // The new fabric is RAM-backed here, but the append
                // surface is fallible (durable backings exist):
                // transient errors retry, persistent ones abort the
                // failover before promotion claims convergence.
                retry(&Backoff::default(), || {
                    nf.append_shared(&batch.table, batch.records.clone(), now)
                })?;
            }
            cur = off + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::RetryPolicy;
    use crate::testkit::TempDir;
    use crate::types::FeatureRecord;
    use crate::util::Clock;

    fn scheduler() -> Scheduler {
        Scheduler::new(Arc::new(ThreadPool::new(2)), Clock::fixed(0), RetryPolicy::default())
    }

    #[test]
    fn checkpoint_restore_no_data_loss() {
        let topology = Arc::new(GeoTopology::default_four_region());
        let fm = FailoverManager::new(topology.clone());

        // Active region state: offline rows + scheduler coverage.
        let offline = OfflineStore::new();
        offline.merge(
            "txn:1",
            &[
                FeatureRecord::new(1, 100, 150, vec![1.0]),
                FeatureRecord::new(1, 200, 250, vec![2.0]),
                FeatureRecord::new(2, 100, 160, vec![3.0]),
            ],
        );
        let active = scheduler();
        // Mark coverage by claiming+completing.
        active.restore(&[("txn:1".to_string(), vec![FeatureWindow::new(0, 300)])]);

        let dir = TempDir::new("fo-a");
        let cp = fm
            .checkpoint("eastus", &active, &offline, dir.path().to_path_buf(), 500)
            .unwrap();

        // Region goes down; fail over.
        topology.set_down("eastus", true);
        let standby_sched = scheduler();
        let promoted = fm.failover(&cp, &standby_sched, 4, 600).unwrap();
        let (off2, on2) = (promoted.offline.clone(), promoted.online.clone());
        assert_eq!(promoted.region, "westus");
        assert!(promoted.fabric.is_none() && promoted.replication.is_none());
        // No data loss offline.
        assert_eq!(off2.row_count("txn:1"), 3);
        // Online rebuilt to Eq. 2 state.
        assert_eq!(on2.get("txn:1", 1, 700).unwrap().version(), (200, 250));
        // Scheduler resumes from the checkpointed high-water: nothing
        // before 300 is re-materialized.
        assert!(standby_sched.is_materialized("txn:1", &FeatureWindow::new(0, 300)));
        assert_eq!(
            standby_sched.gaps("txn:1", FeatureWindow::new(0, 400)),
            vec![FeatureWindow::new(300, 400)]
        );
    }

    #[test]
    fn failover_replays_unreplicated_fabric_tail() {
        let topology = Arc::new(GeoTopology::default_four_region());
        let fm = FailoverManager::new(topology.clone());

        let offline = OfflineStore::new();
        offline.merge("t:1", &[FeatureRecord::new(1, 100, 150, vec![1.0])]);
        let active = scheduler();
        let dir = TempDir::new("fo-tail");
        let cp = fm
            .checkpoint("eastus", &active, &offline, dir.path().to_path_buf(), 500)
            .unwrap();

        // Fabric with the nearest standby (westus) as a replica. One
        // batch replicated, one acked write still in the log when the
        // home dies — the checkpoint predates both.
        let westus = Arc::new(OnlineStore::new(2));
        let fabric =
            ReplicationFabric::new(2, vec![("westus".into(), westus.clone(), 10)], None);
        fabric.append("t:1", &[FeatureRecord::new(1, 200, 250, vec![2.0])], 600).unwrap();
        fabric.pump(700); // applied to the replica
        fabric
            .append("t:1", &[FeatureRecord::new(2, 300, 350, vec![3.0])], 800)
            .unwrap(); // unreplicated

        topology.set_down("eastus", true);
        let promoted = fm
            .failover_with(&cp, &scheduler(), 4, 900, Some(&fabric), Clock::fixed(900), None, None)
            .unwrap();
        assert_eq!(promoted.region, "westus");
        // The promoted online store is the replica itself, now holding
        // checkpointed history + applied prefix + the replayed tail.
        assert!(Arc::ptr_eq(&promoted.online, &westus));
        assert_eq!(promoted.online.get("t:1", 1, 1_000).unwrap().version(), (200, 250));
        assert_eq!(promoted.online.get("t:1", 2, 1_000).unwrap().values[0], 3.0);
        // Offline durability: every fabric record landed there too.
        assert_eq!(promoted.offline.row_count("t:1"), 3);
        // The new home replicates onward: fabric over the survivors
        // (none here — the only replica was promoted), driver running,
        // and the retained history forwarded as future replay material.
        let nf = promoted.fabric.as_ref().unwrap();
        assert!(nf.regions().is_empty());
        assert_eq!(nf.log_len(), 2, "retained entries forwarded into the new fabric");
        assert!(promoted.replication.is_some());
    }

    /// Satellite pin for the parallel replay: two identically-built
    /// fixtures, one replayed sequentially and one fanned out over the
    /// shared pool, must converge to the same promoted state — same
    /// offline rows, same Eq. 2 online winners, same forwarded log
    /// depth. (Cross-partition *order* may differ; final state may not.)
    #[test]
    fn parallel_fabric_replay_is_equivalent_to_sequential() {
        let fixture = || {
            let topology = Arc::new(GeoTopology::default_four_region());
            let fm = FailoverManager::new(topology.clone());
            let offline = OfflineStore::new();
            offline.merge("t:1", &[FeatureRecord::new(1, 100, 150, vec![1.0])]);
            let dir = TempDir::new("fo-eq");
            let cp = fm
                .checkpoint("eastus", &scheduler(), &offline, dir.path().to_path_buf(), 500)
                .unwrap();
            // Batches spread over tables (→ fabric partitions) with an
            // applied prefix and an unreplicated tail.
            let westus = Arc::new(OnlineStore::new(2));
            let fabric =
                ReplicationFabric::new(4, vec![("westus".into(), westus, 10)], None);
            for i in 0..24u64 {
                let table = format!("t:{}", i % 5);
                let rec =
                    FeatureRecord::new(i % 7, 100 + i as i64, 200 + i as i64, vec![i as f32]);
                fabric.append(&table, &[rec], 600).unwrap();
                if i == 11 {
                    fabric.pump(700);
                }
            }
            topology.set_down("eastus", true);
            (fm, cp, fabric, dir)
        };
        let (fm_s, cp_s, fab_s, _dir_s) = fixture();
        let seq = fm_s
            .failover_with(&cp_s, &scheduler(), 4, 900, Some(&fab_s), Clock::fixed(900), None, None)
            .unwrap();
        let (fm_p, cp_p, fab_p, _dir_p) = fixture();
        let pool = Arc::new(ThreadPool::new(3));
        let par = fm_p
            .failover_with(
                &cp_p,
                &scheduler(),
                4,
                900,
                Some(&fab_p),
                Clock::fixed(900),
                None,
                Some(&pool),
            )
            .unwrap();
        assert_eq!(par.region, seq.region);
        for t in 0..5 {
            let table = format!("t:{t}");
            assert_eq!(
                par.offline.row_count(&table),
                seq.offline.row_count(&table),
                "offline rows diverge for {table}"
            );
            for e in 0..7u64 {
                let a = seq.online.get(&table, e, 2_000);
                let b = par.online.get(&table, e, 2_000);
                assert_eq!(
                    b.as_ref().map(|r| (r.version(), r.values.to_vec())),
                    a.as_ref().map(|r| (r.version(), r.values.to_vec())),
                    "online state diverges for {table} entity {e}"
                );
            }
        }
        assert_eq!(
            par.fabric.as_ref().unwrap().log_len(),
            seq.fabric.as_ref().unwrap().log_len(),
            "forwarded log depth diverges"
        );
    }

    #[test]
    fn failover_needs_a_standby() {
        let topology = Arc::new(GeoTopology::new(&["solo"], &[], 100));
        let fm = FailoverManager::new(topology.clone());
        topology.set_down("solo", true);
        let dir = TempDir::new("fo-b");
        let cp = RegionCheckpoint {
            region: "solo".into(),
            taken_at: 0,
            coverage: vec![],
            offline_dir: dir.file("never-written"),
        };
        assert!(fm.failover(&cp, &scheduler(), 2, 0).is_err());
    }
}
