//! Asynchronous geo-replication of online-store data (§4.1.2's
//! geo-replication mechanism, on the paper's roadmap).
//!
//! The home region's merges are enqueued and become visible in each
//! replica after the replication lag (WAN transfer + apply).  Reads in a
//! replica region are local-latency but may be stale by up to the lag —
//! the trade experiment E6 measures against cross-region access.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::online_store::OnlineStore;
use crate::types::{FeatureRecord, Timestamp};

struct Pending {
    table: String,
    records: Vec<FeatureRecord>,
    visible_at: Timestamp,
}

/// Replicates online merges from a home store to replica stores.
pub struct GeoReplicator {
    replicas: HashMap<String, Arc<OnlineStore>>,
    /// Per-replica apply queue.
    queues: Mutex<HashMap<String, VecDeque<Pending>>>,
    /// Replication lag per replica region (seconds on the processing
    /// timeline).
    lag_secs: HashMap<String, i64>,
}

impl GeoReplicator {
    pub fn new(replicas: Vec<(String, Arc<OnlineStore>, i64)>) -> Self {
        let mut map = HashMap::new();
        let mut lag = HashMap::new();
        let mut queues = HashMap::new();
        for (region, store, lag_secs) in replicas {
            map.insert(region.clone(), store);
            lag.insert(region.clone(), lag_secs);
            queues.insert(region, VecDeque::new());
        }
        GeoReplicator { replicas: map, queues: Mutex::new(queues), lag_secs: lag }
    }

    pub fn replica(&self, region: &str) -> Option<&Arc<OnlineStore>> {
        self.replicas.get(region)
    }

    pub fn regions(&self) -> Vec<String> {
        let mut r: Vec<_> = self.replicas.keys().cloned().collect();
        r.sort();
        r
    }

    /// Called after every home-region merge: enqueue for each replica.
    pub fn enqueue(&self, table: &str, records: &[FeatureRecord], now: Timestamp) {
        if records.is_empty() {
            return;
        }
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            queue.push_back(Pending {
                table: table.to_string(),
                records: records.to_vec(),
                visible_at: now + self.lag_secs[region],
            });
        }
    }

    /// Apply every queued batch that has become visible by `now`.
    /// Returns records applied per region.
    pub fn pump(&self, now: Timestamp) -> HashMap<String, u64> {
        let mut applied = HashMap::new();
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            let store = &self.replicas[region];
            let mut n = 0u64;
            while queue.front().map_or(false, |p| p.visible_at <= now) {
                let p = queue.pop_front().unwrap();
                let stats = store.merge(&p.table, &p.records, now);
                n += stats.inserted + stats.skipped;
            }
            applied.insert(region.clone(), n);
        }
        applied
    }

    /// Worst-case staleness of a replica at `now`: age of its oldest
    /// unapplied batch (0 when fully caught up).
    pub fn staleness_secs(&self, region: &str, now: Timestamp) -> i64 {
        let q = self.queues.lock().unwrap();
        q.get(region)
            .and_then(|queue| queue.front())
            .map(|p| (now - (p.visible_at - self.lag_secs[region])).max(0))
            .unwrap_or(0)
    }

    pub fn backlog(&self, region: &str) -> usize {
        self.queues.lock().unwrap().get(region).map(|q| q.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn replicator(lag: i64) -> (GeoReplicator, Arc<OnlineStore>) {
        let store = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![("westeurope".into(), store.clone(), lag)]);
        (r, store)
    }

    #[test]
    fn records_visible_after_lag() {
        let (r, store) = replicator(60);
        r.enqueue("t", &[rec(1, 100, 150, 1.0)], 1_000);
        r.pump(1_030);
        assert!(store.get("t", 1, 1_030).is_none(), "not visible before lag");
        assert_eq!(r.backlog("westeurope"), 1);
        r.pump(1_060);
        assert_eq!(store.get("t", 1, 1_060).unwrap().values[0], 1.0);
        assert_eq!(r.backlog("westeurope"), 0);
    }

    #[test]
    fn staleness_measures_oldest_pending() {
        let (r, _) = replicator(120);
        assert_eq!(r.staleness_secs("westeurope", 0), 0);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 1_000);
        r.enqueue("t", &[rec(2, 1, 2, 1.0)], 1_050);
        assert_eq!(r.staleness_secs("westeurope", 1_080), 80);
        r.pump(1_120); // first batch applies
        assert_eq!(r.staleness_secs("westeurope", 1_130), 80); // second pending, enqueued 1050
        r.pump(1_200);
        assert_eq!(r.staleness_secs("westeurope", 1_300), 0);
    }

    #[test]
    fn replication_preserves_alg2_ordering() {
        // Batches applied in order converge replicas to the home state
        // even when a late-arriving record was merged in between.
        let (r, store) = replicator(10);
        r.enqueue("t", &[rec(1, 100, 110, 1.0)], 0);
        r.enqueue("t", &[rec(1, 100, 300, 2.0)], 5); // recompute
        r.enqueue("t", &[rec(1, 90, 400, 0.5)], 6); // older event: no-op
        r.pump(1_000);
        let got = store.get("t", 1, 1_000).unwrap();
        assert_eq!(got.version(), (100, 300));
        assert_eq!(got.values[0], 2.0);
    }

    #[test]
    fn multiple_replicas_independent_lag() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![
            ("westeurope".into(), eu.clone(), 30),
            ("southeastasia".into(), asia.clone(), 90),
        ]);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 100);
        r.pump(140);
        assert!(eu.get("t", 1, 140).is_some());
        assert!(asia.get("t", 1, 140).is_none());
        r.pump(190);
        assert!(asia.get("t", 1, 190).is_some());
        assert_eq!(r.regions(), vec!["southeastasia", "westeurope"]);
    }
}
