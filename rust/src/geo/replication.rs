//! Asynchronous geo-replication of online-store data (§4.1.2's
//! geo-replication mechanism, on the paper's roadmap).
//!
//! The home region's merges are enqueued and become visible in each
//! replica after the replication lag (WAN transfer + apply).  Reads in a
//! replica region are local-latency but may be stale by up to the lag —
//! the trade experiment E6 measures against cross-region access.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::online_store::OnlineStore;
use crate::types::{FeatureRecord, Timestamp};

struct Pending {
    table: String,
    /// One shared copy of the batch for *all* replica queues (the
    /// write-path symmetry follow-up: enqueue used to clone the record
    /// vector once per region).
    records: Arc<[FeatureRecord]>,
    visible_at: Timestamp,
}

/// Replicates online merges from a home store to replica stores.
pub struct GeoReplicator {
    replicas: HashMap<String, Arc<OnlineStore>>,
    /// Per-replica apply queue.
    queues: Mutex<HashMap<String, VecDeque<Pending>>>,
    /// Replication lag per replica region (seconds on the processing
    /// timeline).
    lag_secs: HashMap<String, i64>,
}

impl GeoReplicator {
    pub fn new(replicas: Vec<(String, Arc<OnlineStore>, i64)>) -> Self {
        let mut map = HashMap::new();
        let mut lag = HashMap::new();
        let mut queues = HashMap::new();
        for (region, store, lag_secs) in replicas {
            map.insert(region.clone(), store);
            lag.insert(region.clone(), lag_secs);
            queues.insert(region, VecDeque::new());
        }
        GeoReplicator { replicas: map, queues: Mutex::new(queues), lag_secs: lag }
    }

    pub fn replica(&self, region: &str) -> Option<&Arc<OnlineStore>> {
        self.replicas.get(region)
    }

    pub fn regions(&self) -> Vec<String> {
        let mut r: Vec<_> = self.replicas.keys().cloned().collect();
        r.sort();
        r
    }

    /// Called after every home-region merge: enqueue for each replica.
    /// The batch is copied **once** into a shared `Arc` — every replica
    /// queue holds the same allocation, mirroring how the read path
    /// shares one routed batch across a region's key set.
    pub fn enqueue(&self, table: &str, records: &[FeatureRecord], now: Timestamp) {
        if records.is_empty() {
            return;
        }
        let shared: Arc<[FeatureRecord]> = records.into();
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            queue.push_back(Pending {
                table: table.to_string(),
                records: shared.clone(),
                visible_at: now + self.lag_secs[region],
            });
        }
    }

    /// Apply every queued batch that has become visible by `now`.
    /// Returns records applied per region.
    ///
    /// Visible batches are drained first and coalesced per table in
    /// arrival order, then applied with **one** `OnlineStore::merge` per
    /// table — which groups records by shard internally, so a
    /// replication pump locks each destination shard once per table
    /// instead of once per batch (the `merge`/`get_many` symmetry from
    /// the ROADMAP). Alg 2 is order-independent-convergent, and the
    /// concatenation preserves arrival order, so the converged state is
    /// identical to per-batch application.
    pub fn pump(&self, now: Timestamp) -> HashMap<String, u64> {
        let mut applied = HashMap::new();
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            let store = &self.replicas[region];
            let mut visible: Vec<Pending> = Vec::new();
            while queue.front().map_or(false, |p| p.visible_at <= now) {
                visible.push(queue.pop_front().unwrap());
            }
            // Batch indices per table, in arrival order.
            let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
            for (i, p) in visible.iter().enumerate() {
                match groups.iter_mut().find(|(t, _)| *t == p.table) {
                    Some((_, idxs)) => idxs.push(i),
                    None => groups.push((p.table.as_str(), vec![i])),
                }
            }
            let mut n = 0u64;
            for (table, idxs) in &groups {
                let stats = if let &[i] = &idxs[..] {
                    // Single visible batch for this table (the common
                    // case): apply the shared slice directly, no copies.
                    store.merge(table, &visible[i].records, now)
                } else {
                    let mut records: Vec<FeatureRecord> =
                        Vec::with_capacity(idxs.iter().map(|&i| visible[i].records.len()).sum());
                    for &i in idxs {
                        records.extend_from_slice(&visible[i].records);
                    }
                    store.merge(table, &records, now)
                };
                n += stats.inserted + stats.skipped;
            }
            applied.insert(region.clone(), n);
        }
        applied
    }

    /// Worst-case staleness of a replica at `now`: age of its oldest
    /// unapplied batch (0 when fully caught up).
    pub fn staleness_secs(&self, region: &str, now: Timestamp) -> i64 {
        let q = self.queues.lock().unwrap();
        q.get(region)
            .and_then(|queue| queue.front())
            .map(|p| (now - (p.visible_at - self.lag_secs[region])).max(0))
            .unwrap_or(0)
    }

    pub fn backlog(&self, region: &str) -> usize {
        self.queues.lock().unwrap().get(region).map(|q| q.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn replicator(lag: i64) -> (GeoReplicator, Arc<OnlineStore>) {
        let store = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![("westeurope".into(), store.clone(), lag)]);
        (r, store)
    }

    #[test]
    fn records_visible_after_lag() {
        let (r, store) = replicator(60);
        r.enqueue("t", &[rec(1, 100, 150, 1.0)], 1_000);
        r.pump(1_030);
        assert!(store.get("t", 1, 1_030).is_none(), "not visible before lag");
        assert_eq!(r.backlog("westeurope"), 1);
        r.pump(1_060);
        assert_eq!(store.get("t", 1, 1_060).unwrap().values[0], 1.0);
        assert_eq!(r.backlog("westeurope"), 0);
    }

    #[test]
    fn staleness_measures_oldest_pending() {
        let (r, _) = replicator(120);
        assert_eq!(r.staleness_secs("westeurope", 0), 0);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 1_000);
        r.enqueue("t", &[rec(2, 1, 2, 1.0)], 1_050);
        assert_eq!(r.staleness_secs("westeurope", 1_080), 80);
        r.pump(1_120); // first batch applies
        assert_eq!(r.staleness_secs("westeurope", 1_130), 80); // second pending, enqueued 1050
        r.pump(1_200);
        assert_eq!(r.staleness_secs("westeurope", 1_300), 0);
    }

    #[test]
    fn replication_preserves_alg2_ordering() {
        // Batches applied in order converge replicas to the home state
        // even when a late-arriving record was merged in between.
        let (r, store) = replicator(10);
        r.enqueue("t", &[rec(1, 100, 110, 1.0)], 0);
        r.enqueue("t", &[rec(1, 100, 300, 2.0)], 5); // recompute
        r.enqueue("t", &[rec(1, 90, 400, 0.5)], 6); // older event: no-op
        r.pump(1_000);
        let got = store.get("t", 1, 1_000).unwrap();
        assert_eq!(got.version(), (100, 300));
        assert_eq!(got.values[0], 2.0);
    }

    #[test]
    fn pump_coalesces_batches_per_table_per_region() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![
            ("westeurope".into(), eu.clone(), 10),
            ("southeastasia".into(), asia.clone(), 10),
        ]);
        // Three batches for "a" (including a same-event recompute and a
        // stale event) and one for "b", all visible at once: one merge
        // per table per region must converge exactly as per-batch
        // application would.
        r.enqueue("a", &[rec(1, 100, 110, 1.0)], 0);
        r.enqueue("a", &[rec(1, 100, 300, 2.0), rec(2, 10, 20, 9.0)], 1);
        r.enqueue("b", &[rec(1, 5, 6, 3.0)], 2);
        r.enqueue("a", &[rec(1, 90, 400, 0.5)], 3); // older event: no-op
        let applied = r.pump(1_000);
        assert_eq!(applied["westeurope"], 5);
        assert_eq!(applied["southeastasia"], 5);
        for store in [&eu, &asia] {
            let got = store.get("a", 1, 1_000).unwrap();
            assert_eq!(got.version(), (100, 300));
            assert_eq!(got.values[0], 2.0);
            assert_eq!(store.get("a", 2, 1_000).unwrap().values[0], 9.0);
            assert_eq!(store.get("b", 1, 1_000).unwrap().values[0], 3.0);
        }
        assert_eq!(r.backlog("westeurope"), 0);
        assert_eq!(r.backlog("southeastasia"), 0);
    }

    #[test]
    fn multiple_replicas_independent_lag() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![
            ("westeurope".into(), eu.clone(), 30),
            ("southeastasia".into(), asia.clone(), 90),
        ]);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 100);
        r.pump(140);
        assert!(eu.get("t", 1, 140).is_some());
        assert!(asia.get("t", 1, 140).is_none());
        r.pump(190);
        assert!(asia.get("t", 1, 190).is_some());
        assert_eq!(r.regions(), vec!["southeastasia", "westeurope"]);
    }
}
