//! The geo-replication **fabric** (§4.1.2's geo-replication mechanism):
//! one durable record log, per-region cursors, and a background
//! replication driver.
//!
//! Earlier revisions had two parallel delivery mechanisms feeding the
//! same replica stores — per-region `VecDeque` push queues for the
//! batch path and an engine-local tailed log for the streaming path —
//! both caller-driven. This module collapses them into a single plane:
//!
//! * Every home-region online merge (batch scheduler job, streaming
//!   dual-write, coordinator bootstrap) appends a [`ReplBatch`] to one
//!   shared [`PartitionedLog`] owned by the fabric. The log is the
//!   replayable history: it outlives any stream engine, serves any
//!   number of regions, and is what failover replays to recover acked
//!   writes that had not reached every replica.
//! * Per-region apply state is just **cursors** (one per log partition)
//!   behind a **per-region lock** — one slow region's merge never
//!   blocks another region's apply, and two pumps of different regions
//!   run fully in parallel.
//! * A [`ReplicationDriver`] thread drives delivery: push-woken on
//!   every append (`util::wake`) plus periodic lag ticks, so batches
//!   become visible `lag` seconds after append without any caller
//!   pumping. Each driver tick also truncates the log below the minimum
//!   applied cursor, bounding log memory by the slowest region's lag.
//! * [`SessionToken`]s capture per-partition log positions at write
//!   time; `geo::access` uses them (and the fabric's staleness/cursor
//!   introspection) to route reads under an explicit
//!   [`super::access::ReadConsistency`] policy.
//!
//! A batch becomes *visible* to a region `lag_secs` after it was
//! appended (the WAN transfer + apply simulation), and apply order is
//! log order per partition — prefix semantics, like a real log tail.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::monitor::trace::Tracer;
use crate::online_store::OnlineStore;
use crate::storage::DurableLog;
use crate::stream::log::PartitionedLog;
use crate::types::{FeatureRecord, Result, Timestamp};
use crate::util::wake::Wake;
use crate::util::Clock;

/// One replicable unit in the fabric log: the records one home-region
/// merge produced for a table, stamped with the processing time it was
/// appended (drives lag-based visibility).
#[derive(Debug, Clone)]
pub struct ReplBatch {
    pub table: String,
    /// Shared with the producing write path — the log never copies
    /// record data.
    pub records: Arc<[FeatureRecord]>,
    pub appended_at: Timestamp,
}

/// A causal position in the fabric log: the per-partition offsets a
/// session's writes reached. A replica may serve a
/// `ReadYourWrites(token)` read only once its cursors cover the token.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SessionToken {
    offsets: Vec<u64>,
}

impl SessionToken {
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Merge another token in (per-partition max) — a session that
    /// wrote through several paths carries one combined token.
    pub fn join(&mut self, other: &SessionToken) {
        if self.offsets.len() < other.offsets.len() {
            self.offsets.resize(other.offsets.len(), 0);
        }
        for (mine, theirs) in self.offsets.iter_mut().zip(&other.offsets) {
            *mine = (*mine).max(*theirs);
        }
    }
}

/// One replica region's state: the destination store, its simulated
/// replication lag, and its apply cursors — **individually locked** so
/// pumping one region never serializes behind another's merge.
struct RegionState {
    name: String,
    store: Arc<OnlineStore>,
    lag_secs: i64,
    cursors: Mutex<Vec<u64>>,
}

/// The fabric log's bytes: plain RAM (the original in-process plane) or
/// a crash-safe WAL whose in-RAM mirror serves every read — pumps and
/// tails never touch disk, only appends pay for the fsync ack.
enum Backing {
    Mem(PartitionedLog<ReplBatch>),
    Durable(Arc<DurableLog<ReplBatch>>),
}

impl Backing {
    fn view(&self) -> &PartitionedLog<ReplBatch> {
        match self {
            Backing::Mem(log) => log,
            Backing::Durable(log) => log.mem(),
        }
    }
}

/// The single replication plane: every home merge appends here, every
/// replica region tails it with its own cursors.
pub struct ReplicationFabric {
    backing: Backing,
    regions: Vec<RegionState>,
    wake: Arc<Wake>,
    metrics: Option<Arc<MetricsRegistry>>,
    /// Per-partition log positions of the last recorded offline
    /// checkpoint (`None` until one is taken). Truncation never reclaims
    /// at/past this floor: entries newer than the checkpoint are absent
    /// from the persisted segments and are exactly what failover replays
    /// into a restored store.
    checkpoint_floor: Mutex<Option<Vec<u64>>>,
}

/// Bounded tail chunk: a region waiting out a long lag must not re-clone
/// its entire backlog on every pump.
const TAIL_CHUNK: usize = 256;

impl ReplicationFabric {
    /// Build a fabric with `partitions` log partitions (tables are
    /// hash-routed, so one table's batches stay ordered) over
    /// `(region, store, lag_secs)` replicas. `metrics`, when present,
    /// receives per-region `repl_lag_secs_*` / `repl_backlog_*` gauges
    /// on every pump.
    pub fn new(
        partitions: usize,
        replicas: Vec<(String, Arc<OnlineStore>, i64)>,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> Arc<ReplicationFabric> {
        let partitions = partitions.max(1);
        Self::build(Backing::Mem(PartitionedLog::new(partitions)), partitions, replicas, metrics)
    }

    /// Build a fabric over a recovered durable log: the log's replayed
    /// mirror is the fabric history, so acked pre-crash appends are
    /// immediately replayable. Callers restore per-region cursors and
    /// the checkpoint floor from the manifest afterwards
    /// ([`Self::set_cursors`], [`Self::set_checkpoint_floor`]).
    pub fn new_durable(
        log: Arc<DurableLog<ReplBatch>>,
        replicas: Vec<(String, Arc<OnlineStore>, i64)>,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> Arc<ReplicationFabric> {
        let partitions = log.partitions();
        Self::build(Backing::Durable(log), partitions, replicas, metrics)
    }

    fn build(
        backing: Backing,
        partitions: usize,
        replicas: Vec<(String, Arc<OnlineStore>, i64)>,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> Arc<ReplicationFabric> {
        let regions = replicas
            .into_iter()
            .map(|(name, store, lag_secs)| RegionState {
                name,
                store,
                lag_secs,
                cursors: Mutex::new(vec![0u64; partitions]),
            })
            .collect();
        Arc::new(ReplicationFabric {
            backing,
            regions,
            wake: Arc::new(Wake::default()),
            metrics,
            checkpoint_floor: Mutex::new(None),
        })
    }

    /// The read view of the fabric log (always RAM).
    fn log(&self) -> &PartitionedLog<ReplBatch> {
        self.backing.view()
    }

    pub fn partitions(&self) -> usize {
        self.log().partitions()
    }

    pub fn regions(&self) -> Vec<String> {
        let mut r: Vec<_> = self.regions.iter().map(|r| r.name.clone()).collect();
        r.sort();
        r
    }

    pub fn replica(&self, region: &str) -> Option<&Arc<OnlineStore>> {
        self.region(region).map(|r| &r.store)
    }

    /// The replica stores + lags (failover wiring).
    pub fn replica_set(&self) -> Vec<(String, Arc<OnlineStore>, i64)> {
        let mut out: Vec<_> = self
            .regions
            .iter()
            .map(|r| (r.name.clone(), r.store.clone(), r.lag_secs))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    fn region(&self, region: &str) -> Option<&RegionState> {
        self.regions.iter().find(|r| r.name == region)
    }

    /// The wake channel a [`ReplicationDriver`] parks on.
    pub(crate) fn wake(&self) -> Arc<Wake> {
        self.wake.clone()
    }

    /// The log partition a table's batches route to (stable hash, so a
    /// table's batches form one ordered sub-log).
    fn partition_of(&self, table: &str) -> usize {
        (crate::stream::log::hash_key(table) % self.log().partitions() as u64) as usize
    }

    /// Append one home-region merge to the fabric (copies the records
    /// into one shared `Arc`). Wakes the driver. Returns the session
    /// token covering this write. On a durable backing the batch is
    /// fsync-acked before this returns; an `Err` means the batch is
    /// **not** acked (transient errors are retryable — replica merges
    /// are idempotent, so a duplicate replay is harmless).
    pub fn append(
        &self,
        table: &str,
        records: &[FeatureRecord],
        now: Timestamp,
    ) -> Result<SessionToken> {
        if records.is_empty() {
            return Ok(SessionToken::default());
        }
        self.append_shared(table, records.into(), now)
    }

    /// Append an already-shared batch (the streaming dual-write hands
    /// the same allocation to both sinks and the fabric).
    pub fn append_shared(
        &self,
        table: &str,
        records: Arc<[FeatureRecord]>,
        now: Timestamp,
    ) -> Result<SessionToken> {
        if records.is_empty() {
            return Ok(SessionToken::default());
        }
        let mut token = SessionToken { offsets: vec![0; self.log().partitions()] };
        let p = self.partition_of(table);
        let batch = ReplBatch { table: table.to_string(), records, appended_at: now };
        let off = match &self.backing {
            Backing::Mem(log) => log.append(p, batch),
            Backing::Durable(log) => log.append(p, batch)?,
        };
        token.offsets[p] = off + 1;
        self.wake.ping();
        Ok(token)
    }

    /// A token covering **everything appended so far** (per-partition
    /// high-water marks) — what a session grabs after a batch of writes.
    pub fn token(&self) -> SessionToken {
        SessionToken {
            offsets: (0..self.log().partitions()).map(|p| self.log().high_water(p)).collect(),
        }
    }

    /// Does `region`'s applied state cover `token`? (Every partition
    /// cursor at/past the token's offset.)
    pub fn covers(&self, region: &str, token: &SessionToken) -> bool {
        let Some(r) = self.region(region) else { return false };
        let cursors = r.cursors.lock().unwrap();
        token
            .offsets
            .iter()
            .enumerate()
            .all(|(p, &off)| cursors.get(p).map_or(off == 0, |&c| c >= off))
    }

    /// `region`'s applied cursors (failover replay bound).
    pub fn cursors(&self, region: &str) -> Vec<u64> {
        match self.region(region) {
            Some(r) => r.cursors.lock().unwrap().clone(),
            None => vec![0; self.log().partitions()],
        }
    }

    /// Apply every batch visible to `region` by `now`, in log order,
    /// coalescing per table into one shard-grouped merge per chunk. Only
    /// `region`'s cursor lock is held — other regions pump in parallel.
    /// Returns records applied.
    pub fn pump_region(&self, region: &str, now: Timestamp) -> u64 {
        let Some(r) = self.region(region) else { return 0 };
        let mut cursors = r.cursors.lock().unwrap();
        let mut n = 0u64;
        for p in 0..self.log().partitions() {
            // A cursor below the truncated base resumes at the base:
            // those entries were applied by every region already.
            cursors[p] = cursors[p].max(self.log().base_offset(p));
            loop {
                let entries = self.log().read_from(p, cursors[p], TAIL_CHUNK);
                if entries.is_empty() {
                    break;
                }
                // Tail in log order, stopping at the first not-yet-visible
                // batch (visibility is monotone in append order).
                let mut hit_unripe = false;
                let mut visible: Vec<(&str, &[FeatureRecord])> = Vec::new();
                for (off, batch) in &entries {
                    if batch.appended_at + r.lag_secs > now {
                        hit_unripe = true;
                        break;
                    }
                    visible.push((batch.table.as_str(), &batch.records));
                    cursors[p] = off + 1;
                }
                let stats = r.store.merge_batches(&visible, now);
                n += stats.inserted + stats.skipped;
                if hit_unripe || entries.len() < TAIL_CHUNK {
                    break;
                }
            }
        }
        n
    }

    /// Pump every region sequentially and refresh the per-region
    /// lag/backlog gauges. Returns records applied per region. The
    /// fan-out variant is [`Self::pump_parallel`].
    pub fn pump(&self, now: Timestamp) -> HashMap<String, u64> {
        let mut applied = HashMap::new();
        for r in &self.regions {
            applied.insert(r.name.clone(), self.pump_region(&r.name, now));
        }
        self.set_region_gauges(now);
        applied
    }

    /// Pump every region **concurrently** (one pool task per region),
    /// so one slow region — long apply, big backlog, or a held cursor
    /// lock — no longer delays the others' convergence. Semantically
    /// identical to [`Self::pump`]: each task holds only its own
    /// region's cursor lock, and per-partition apply order is unchanged
    /// (order across *regions* never mattered — they share no state).
    /// Sets the `repl_apply_parallel` gauge to the fan-out used.
    pub fn pump_parallel(
        self: &Arc<Self>,
        now: Timestamp,
        pool: &crate::exec::ThreadPool,
    ) -> HashMap<String, u64> {
        let applied = if self.regions.len() <= 1 {
            // Nothing to overlap — skip the task hand-off.
            self.regions
                .iter()
                .map(|r| (r.name.clone(), self.pump_region(&r.name, now)))
                .collect()
        } else {
            let handles: Vec<_> = self
                .regions
                .iter()
                .map(|r| {
                    let fabric = self.clone();
                    let name = r.name.clone();
                    pool.submit(move || {
                        let n = fabric.pump_region(&name, now);
                        (name, n)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        };
        if let Some(m) = &self.metrics {
            m.set_gauge(
                MetricKind::System,
                names::REPL_APPLY_PARALLEL,
                self.regions.len().min(pool.worker_count()).max(1) as f64,
            );
        }
        self.set_region_gauges(now);
        applied
    }

    /// Refresh `repl_lag_secs_*` / `repl_backlog_*` after a pump.
    fn set_region_gauges(&self, now: Timestamp) {
        if let Some(m) = &self.metrics {
            for r in &self.regions {
                m.set_gauge(
                    MetricKind::System,
                    &names::repl_lag_secs(&r.name),
                    self.staleness_secs(&r.name, now) as f64,
                );
                m.set_gauge(
                    MetricKind::System,
                    &names::repl_backlog(&r.name),
                    self.backlog(&r.name) as f64,
                );
            }
        }
    }

    /// Record the current log high-water marks as the checkpoint floor.
    /// Called after an offline checkpoint persists: everything below the
    /// returned positions is durable in the checkpoint segments, so it
    /// is safe to reclaim once every region applied it; everything at or
    /// past them must stay replayable for failover. Re-recording after a
    /// newer checkpoint advances the floor.
    pub fn record_checkpoint(&self) -> Vec<u64> {
        let floor: Vec<u64> =
            (0..self.log().partitions()).map(|p| self.log().high_water(p)).collect();
        *self.checkpoint_floor.lock().unwrap() = Some(floor.clone());
        floor
    }

    /// The last recorded checkpoint floor, if any (test/metrics hook).
    pub fn checkpoint_floor(&self) -> Option<Vec<u64>> {
        self.checkpoint_floor.lock().unwrap().clone()
    }

    /// Install a checkpoint floor captured earlier (durable-checkpoint
    /// protocol: the floor is captured *before* the manifest commit but
    /// installed only after the commit succeeds, so a failed commit
    /// never licenses truncation; also the manifest-recovery restore
    /// path). Floors only advance — a stale restore cannot regress one.
    pub fn set_checkpoint_floor(&self, floor: Vec<u64>) {
        let mut guard = self.checkpoint_floor.lock().unwrap();
        match guard.as_mut() {
            Some(cur) => {
                for (c, f) in cur.iter_mut().zip(&floor) {
                    *c = (*c).max(*f);
                }
            }
            None => *guard = Some(floor),
        }
    }

    /// Restore `region`'s apply cursors (manifest recovery: replay
    /// resumes exactly above what the pre-crash store had applied).
    /// Cursors only advance, and never past the log high-water mark.
    pub fn set_cursors(&self, region: &str, cursors: &[u64]) {
        let Some(r) = self.region(region) else { return };
        let mut cur = r.cursors.lock().unwrap();
        for (p, c) in cur.iter_mut().enumerate() {
            if let Some(&want) = cursors.get(p) {
                *c = (*c).max(want.min(self.log().high_water(p)));
            }
        }
    }

    /// Truncate the log below the minimum applied cursor across all
    /// regions (every surviving entry is still needed by someone),
    /// additionally gated on the last recorded checkpoint floor: an
    /// entry applied everywhere but newer than the checkpoint is still
    /// the only durable copy failover can replay into a restored offline
    /// store, so it survives. With no checkpoint recorded the min-cursor
    /// rule stands alone — a store that never checkpointed has no
    /// restore target to protect. Returns entries reclaimed. With no
    /// replica regions nothing is reclaimed — the log is then purely the
    /// failover-replay history.
    pub fn truncate_applied(&self) -> u64 {
        if self.regions.is_empty() {
            return 0;
        }
        let per_region: Vec<Vec<u64>> =
            self.regions.iter().map(|r| r.cursors.lock().unwrap().clone()).collect();
        let floor = self.checkpoint_floor.lock().unwrap().clone();
        let mut reclaimed = 0;
        for p in 0..self.log().partitions() {
            let mut min = per_region.iter().map(|c| c[p]).min().unwrap_or(0);
            if let Some(fl) = &floor {
                min = min.min(fl[p]);
            }
            reclaimed += self.log().truncate_below(p, min);
        }
        reclaimed
    }

    /// Log entries `region` has not applied yet.
    pub fn backlog(&self, region: &str) -> usize {
        let Some(r) = self.region(region) else { return 0 };
        let cursors = r.cursors.lock().unwrap();
        (0..self.log().partitions())
            .map(|p| (self.log().high_water(p).saturating_sub(cursors[p])) as usize)
            .sum()
    }

    /// Worst-case staleness of `region` at `now`: age of its oldest
    /// unapplied batch (0 when fully caught up). This is the
    /// log-position staleness `BoundedStaleness` routing checks.
    pub fn staleness_secs(&self, region: &str, now: Timestamp) -> i64 {
        let Some(r) = self.region(region) else { return 0 };
        let cursors = r.cursors.lock().unwrap().clone();
        let mut worst = 0i64;
        for (p, &cur) in cursors.iter().enumerate() {
            if let Some((_, batch)) = self.log().read_from(p, cur, 1).into_iter().next() {
                worst = worst.max((now - batch.appended_at).max(0));
            }
        }
        worst
    }

    /// Read the retained log tail of one partition from `offset`
    /// (failover replay; bounded chunks are the caller's loop).
    pub fn read_tail(&self, partition: usize, offset: u64, max: usize) -> Vec<(u64, ReplBatch)> {
        self.log().read_from(partition, offset, max)
    }

    /// Retained log entries across all partitions.
    pub fn log_len(&self) -> usize {
        self.log().len()
    }

    /// Test hook: run `f` while holding `region`'s cursor lock. Pins the
    /// per-region locking contract — with a fabric-global cursor lock,
    /// pumping another region from inside `f` would deadlock.
    #[doc(hidden)]
    pub fn while_region_locked<R>(&self, region: &str, f: impl FnOnce() -> R) -> R {
        let r = self.region(region).expect("known region");
        let _held = r.cursors.lock().unwrap();
        f()
    }
}

/// Background delivery thread: parked on the fabric's wake channel
/// (pinged by every append), ticking at least every `period` so
/// lag-gated visibility advances with the clock. Each tick pumps every
/// region and truncates the log below the minimum applied cursor.
/// Dropping the driver stops the thread.
pub struct ReplicationDriver {
    stop: Arc<AtomicBool>,
    wake: Arc<Wake>,
    applied: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ReplicationDriver {
    /// Sequential-pump driver (no pool): regions apply one after
    /// another on the driver thread.
    pub fn spawn(fabric: Arc<ReplicationFabric>, clock: Clock, period: Duration) -> Self {
        Self::spawn_inner(fabric, clock, period, None, None)
    }

    /// Fan-out driver: each tick pumps all regions concurrently on
    /// `pool` ([`ReplicationFabric::pump_parallel`]), so a slow
    /// region's apply overlaps the others instead of delaying them.
    pub fn spawn_with_pool(
        fabric: Arc<ReplicationFabric>,
        clock: Clock,
        period: Duration,
        pool: Arc<crate::exec::ThreadPool>,
    ) -> Self {
        Self::spawn_inner(fabric, clock, period, Some(pool), None)
    }

    /// [`Self::spawn_with_pool`] plus request tracing: each tick that
    /// applied anything publishes a sampled trace with the per-region
    /// apply counts.
    pub fn spawn_observed(
        fabric: Arc<ReplicationFabric>,
        clock: Clock,
        period: Duration,
        pool: Arc<crate::exec::ThreadPool>,
        tracer: Option<Arc<Tracer>>,
    ) -> Self {
        Self::spawn_inner(fabric, clock, period, Some(pool), tracer)
    }

    fn spawn_inner(
        fabric: Arc<ReplicationFabric>,
        clock: Clock,
        period: Duration,
        pool: Option<Arc<crate::exec::ThreadPool>>,
        tracer: Option<Arc<Tracer>>,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let applied = Arc::new(AtomicU64::new(0));
        let wake = fabric.wake();
        let (stop2, applied2, wake2) = (stop.clone(), applied.clone(), wake.clone());
        let handle = std::thread::Builder::new()
            .name("geofs-replicator".into())
            .spawn(move || {
                let mut seen = 0u64;
                loop {
                    if stop2.load(Ordering::Acquire) {
                        return;
                    }
                    seen = wake2.wait(seen, period);
                    let now = clock.now();
                    let trace = tracer.as_ref().and_then(|t| t.maybe_trace("replication_pump"));
                    let per_region = {
                        let g = trace.as_ref().map(|t| t.span("pump"));
                        let per_region = match &pool {
                            Some(pool) => fabric.pump_parallel(now, pool),
                            None => fabric.pump(now),
                        };
                        if let Some(g) = &g {
                            let mut parts: Vec<String> = per_region
                                .iter()
                                .map(|(r, n)| format!("{r}={n}"))
                                .collect();
                            parts.sort();
                            g.note(format!("applied {}", parts.join(" ")));
                        }
                        per_region
                    };
                    let n: u64 = per_region.values().sum();
                    applied2.fetch_add(n, Ordering::Relaxed);
                    fabric.truncate_applied();
                    if let Some(t) = &trace {
                        t.finish();
                    }
                }
            })
            .expect("spawn replication driver");
        ReplicationDriver { stop, wake, applied, handle: Some(handle) }
    }

    /// Records applied since spawn (test/metrics hook).
    pub fn applied(&self) -> u64 {
        self.applied.load(Ordering::Relaxed)
    }
}

impl Drop for ReplicationDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.wake.ping();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn fabric(lag: i64) -> (Arc<ReplicationFabric>, Arc<OnlineStore>) {
        let store = Arc::new(OnlineStore::new(2));
        let f = ReplicationFabric::new(2, vec![("westeurope".into(), store.clone(), lag)], None);
        (f, store)
    }

    #[test]
    fn records_visible_after_lag() {
        let (f, store) = fabric(60);
        f.append("t", &[rec(1, 100, 150, 1.0)], 1_000).unwrap();
        f.pump(1_030);
        assert!(store.get("t", 1, 1_030).is_none(), "not visible before lag");
        assert_eq!(f.backlog("westeurope"), 1);
        f.pump(1_060);
        assert_eq!(store.get("t", 1, 1_060).unwrap().values[0], 1.0);
        assert_eq!(f.backlog("westeurope"), 0);
    }

    #[test]
    fn staleness_measures_oldest_pending() {
        let (f, _) = fabric(120);
        assert_eq!(f.staleness_secs("westeurope", 0), 0);
        f.append("t", &[rec(1, 1, 2, 1.0)], 1_000).unwrap();
        f.append("t", &[rec(2, 1, 2, 1.0)], 1_050).unwrap();
        assert_eq!(f.staleness_secs("westeurope", 1_080), 80);
        f.pump(1_120); // first batch applies
        assert_eq!(f.staleness_secs("westeurope", 1_130), 80); // second pending, appended 1050
        f.pump(1_200);
        assert_eq!(f.staleness_secs("westeurope", 1_300), 0);
    }

    #[test]
    fn replication_preserves_alg2_ordering() {
        // Batches applied in log order converge the replica to the home
        // state even when a late-arriving record was merged in between.
        let (f, store) = fabric(10);
        f.append("t", &[rec(1, 100, 110, 1.0)], 0).unwrap();
        f.append("t", &[rec(1, 100, 300, 2.0)], 5).unwrap(); // recompute
        f.append("t", &[rec(1, 90, 400, 0.5)], 6).unwrap(); // older event: no-op
        f.pump(1_000);
        let got = store.get("t", 1, 1_000).unwrap();
        assert_eq!(got.version(), (100, 300));
        assert_eq!(got.values[0], 2.0);
    }

    #[test]
    fn one_log_many_regions_independent_lag() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let f = ReplicationFabric::new(
            1,
            vec![("westeurope".into(), eu.clone(), 30), ("southeastasia".into(), asia.clone(), 90)],
            None,
        );
        f.append("t", &[rec(1, 100, 110, 1.0)], 1_000).unwrap();
        f.append("t", &[rec(1, 100, 300, 2.0)], 1_005).unwrap(); // recompute
        f.append("u", &[rec(2, 5, 6, 3.0)], 1_010).unwrap();
        // Before any lag elapses: nothing applied anywhere.
        let applied = f.pump(1_020);
        assert_eq!(applied["westeurope"], 0);
        assert_eq!(f.backlog("westeurope"), 3);
        // EU lag elapsed for all three, Asia still waiting.
        let applied = f.pump(1_040);
        assert_eq!(applied["westeurope"], 3);
        assert_eq!(applied["southeastasia"], 0);
        assert_eq!(eu.get("t", 1, 1_040).unwrap().version(), (100, 300));
        assert_eq!(eu.get("u", 2, 1_040).unwrap().values[0], 3.0);
        assert!(asia.get("t", 1, 1_040).is_none());
        // One history, two cursors: nothing reclaimable while Asia lags.
        assert_eq!(f.truncate_applied(), 0);
        f.pump(1_100);
        assert_eq!(asia.get("t", 1, 1_100).unwrap().version(), (100, 300));
        assert_eq!(f.backlog("southeastasia"), 0);
        // Everyone applied: the prefix is reclaimed.
        assert_eq!(f.truncate_applied(), 3);
        assert_eq!(f.log_len(), 0);
        // Replays are no-ops: the cursor moved past everything.
        assert_eq!(f.pump(2_000)["westeurope"], 0);
        assert_eq!(f.regions(), vec!["southeastasia", "westeurope"]);
    }

    #[test]
    fn pump_stops_at_first_unripe_entry() {
        // Apply order is log order: a visible entry behind an unripe one
        // must wait (prefix semantics, like a real log tail).
        let (f, store) = fabric(10);
        f.append("t", &[rec(1, 100, 110, 1.0)], 1_000).unwrap();
        f.append("t", &[rec(2, 100, 110, 2.0)], 5_000).unwrap();
        f.append("t", &[rec(3, 100, 110, 3.0)], 1_001).unwrap(); // appended_at regressed
        assert_eq!(f.pump(1_050)["westeurope"], 1);
        assert!(store.get("t", 3, 1_050).is_none(), "entry behind unripe prefix must wait");
        f.pump(5_010);
        assert!(store.get("t", 2, 5_010).is_some() && store.get("t", 3, 5_010).is_some());
        assert_eq!(f.backlog("westeurope"), 0);
        assert_eq!(f.backlog("nope"), 0);
    }

    #[test]
    fn tokens_cover_once_cursors_pass() {
        let (f, _) = fabric(0);
        let empty = f.token();
        assert!(f.covers("westeurope", &empty), "empty token is always covered");
        let tok = f.append("t", &[rec(1, 1, 2, 1.0)], 100).unwrap();
        assert!(!f.covers("westeurope", &tok));
        assert!(!f.covers("nowhere", &tok), "unknown region never covers");
        f.pump(100);
        assert!(f.covers("westeurope", &tok));
        // join folds positions per partition.
        let mut joined = tok.clone();
        let tok2 = f.append("t", &[rec(2, 1, 2, 1.0)], 101).unwrap();
        joined.join(&tok2);
        assert!(!f.covers("westeurope", &joined));
        f.pump(101);
        assert!(f.covers("westeurope", &joined));
        assert_eq!(joined, f.token());
    }

    #[test]
    fn driver_applies_in_background_and_truncates() {
        let eu = Arc::new(OnlineStore::new(2));
        let f = ReplicationFabric::new(2, vec![("eu".into(), eu.clone(), 30)], None);
        let clock = Clock::fixed(1_000);
        let driver = ReplicationDriver::spawn(f.clone(), clock.clone(), Duration::from_millis(2));
        f.append("t", &[rec(1, 10, 20, 7.0)], 1_000).unwrap();
        // Lag not elapsed: the driver must hold the batch back.
        std::thread::sleep(Duration::from_millis(20));
        assert!(eu.get("t", 1, 1_000).is_none());
        // Advance the clock past the lag: the periodic tick delivers
        // without any caller pump, then reclaims the applied prefix.
        clock.set(1_030);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while f.backlog("eu") > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(eu.get("t", 1, 1_030).unwrap().values[0], 7.0);
        assert!(driver.applied() >= 1);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while f.log_len() > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(f.log_len(), 0, "driver must truncate below the min applied cursor");
        drop(driver);
    }

    #[test]
    fn checkpoint_floor_gates_truncation() {
        let (f, _store) = fabric(0);
        f.append("t", &[rec(1, 1, 2, 1.0)], 100).unwrap();
        f.pump(100);
        // Checkpoint here: everything so far is durable offline.
        let floor = f.record_checkpoint();
        assert_eq!(f.checkpoint_floor(), Some(floor));
        // A post-checkpoint entry applies everywhere...
        f.append("t", &[rec(2, 1, 2, 2.0)], 101).unwrap();
        f.pump(101);
        assert_eq!(f.backlog("westeurope"), 0);
        // ...but only the pre-checkpoint prefix is reclaimable: the new
        // entry exists nowhere durable except this log.
        assert_eq!(f.truncate_applied(), 1);
        assert_eq!(f.log_len(), 1, "applied-everywhere entry newer than checkpoint survives");
        // A fresh checkpoint advances the floor and releases it.
        f.record_checkpoint();
        assert_eq!(f.truncate_applied(), 1);
        assert_eq!(f.log_len(), 0);
        // Nothing further to reclaim.
        assert_eq!(f.truncate_applied(), 0);
    }

    #[test]
    fn pump_parallel_matches_sequential_and_sets_gauge() {
        let metrics = Arc::new(MetricsRegistry::new());
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let f = ReplicationFabric::new(
            2,
            vec![("eu".into(), eu.clone(), 0), ("asia".into(), asia.clone(), 0)],
            Some(metrics.clone()),
        );
        let pool = crate::exec::ThreadPool::new(4);
        for e in 0..32u64 {
            f.append("t", &[rec(e, 1, 2, e as f32)], 100).unwrap();
        }
        let applied = f.pump_parallel(200, &pool);
        assert_eq!(applied["eu"], 32);
        assert_eq!(applied["asia"], 32);
        for e in 0..32u64 {
            assert_eq!(eu.get("t", e, 200).unwrap().values[0], e as f32);
            assert_eq!(asia.get("t", e, 200).unwrap().values[0], e as f32);
        }
        assert_eq!(metrics.gauge("repl_apply_parallel"), Some(2.0));
        assert_eq!(metrics.gauge("repl_backlog_eu"), Some(0.0));
        // Replays are no-ops, same as the sequential pump.
        assert_eq!(f.pump_parallel(300, &pool)["eu"], 0);
    }

    #[test]
    fn pump_sets_lag_and_backlog_gauges() {
        let metrics = Arc::new(MetricsRegistry::new());
        let eu = Arc::new(OnlineStore::new(2));
        let f = ReplicationFabric::new(
            1,
            vec![("eu".into(), eu, 60)],
            Some(metrics.clone()),
        );
        f.append("t", &[rec(1, 1, 2, 1.0)], 1_000).unwrap();
        f.pump(1_010);
        assert_eq!(metrics.gauge("repl_lag_secs_eu"), Some(10.0));
        assert_eq!(metrics.gauge("repl_backlog_eu"), Some(1.0));
        f.pump(1_060);
        assert_eq!(metrics.gauge("repl_lag_secs_eu"), Some(0.0));
        assert_eq!(metrics.gauge("repl_backlog_eu"), Some(0.0));
    }
}
