//! Asynchronous geo-replication of online-store data (§4.1.2's
//! geo-replication mechanism, on the paper's roadmap).
//!
//! The home region's merges are enqueued and become visible in each
//! replica after the replication lag (WAN transfer + apply).  Reads in a
//! replica region are local-latency but may be stale by up to the lag —
//! the trade experiment E6 measures against cross-region access.
//!
//! Two delivery mechanisms share the replica stores:
//!
//! * [`GeoReplicator`] — the batch path: each home merge is **pushed**
//!   into per-region queues (one shared `Arc` batch across regions).
//! * [`LogTailer`] — the streaming path: the engine appends every
//!   emitted batch to one shared [`PartitionedLog`], and each remote
//!   region **tails** it with its own cursor. One log entry serves any
//!   number of regions with O(1) state per region (a cursor instead of
//!   a queue), and a new region can join by starting its cursor at 0 —
//!   the ad-hoc per-region queues of the batch path become a single
//!   replayable history.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::online_store::OnlineStore;
use crate::stream::log::PartitionedLog;
use crate::types::{FeatureRecord, Timestamp};

/// One replicable unit in the streaming record log: the records a
/// materialization round emitted for a table, stamped with the
/// processing time it was appended (drives lag-based visibility).
#[derive(Debug, Clone)]
pub struct ReplBatch {
    pub table: String,
    /// Shared with the online write batcher — the log never copies
    /// record data.
    pub records: Arc<[FeatureRecord]>,
    pub appended_at: Timestamp,
}

/// Remote regions tailing the streaming record log. Apply order is log
/// order; a batch becomes visible to a region `lag` seconds after it
/// was appended.
pub struct LogTailer {
    log: Arc<PartitionedLog<ReplBatch>>,
    /// (region, store, lag_secs), fixed at construction.
    replicas: Vec<(String, Arc<OnlineStore>, i64)>,
    /// Per-replica, per-partition cursors — the only per-region state.
    cursors: Mutex<Vec<Vec<u64>>>,
}

impl LogTailer {
    pub fn new(log: Arc<PartitionedLog<ReplBatch>>, replicas: Vec<(String, Arc<OnlineStore>, i64)>) -> Self {
        let cursors = vec![vec![0u64; log.partitions()]; replicas.len()];
        LogTailer { log, replicas, cursors: Mutex::new(cursors) }
    }

    pub fn regions(&self) -> Vec<String> {
        let mut r: Vec<_> = self.replicas.iter().map(|(name, _, _)| name.clone()).collect();
        r.sort();
        r
    }

    /// Advance every region's cursor over all batches visible by `now`,
    /// coalescing per table into one shard-grouped merge (same idiom as
    /// [`GeoReplicator::pump`]). Returns records applied per region.
    pub fn pump(&self, now: Timestamp) -> HashMap<String, u64> {
        let mut applied = HashMap::new();
        let mut cursors = self.cursors.lock().unwrap();
        // Bounded tail chunk: a region waiting out a long lag must not
        // re-clone its entire backlog on every pump.
        const TAIL_CHUNK: usize = 256;
        for (ri, (region, store, lag)) in self.replicas.iter().enumerate() {
            let mut n = 0u64;
            for p in 0..self.log.partitions() {
                loop {
                    let entries = self.log.read_from(p, cursors[ri][p], TAIL_CHUNK);
                    if entries.is_empty() {
                        break;
                    }
                    // Tail in log order, stopping at the first
                    // not-yet-visible batch (visibility is monotone in
                    // append order).
                    let mut hit_unripe = false;
                    let mut visible: Vec<(&str, &[FeatureRecord])> = Vec::new();
                    for (off, batch) in &entries {
                        if batch.appended_at + lag > now {
                            hit_unripe = true;
                            break;
                        }
                        visible.push((batch.table.as_str(), &batch.records));
                        cursors[ri][p] = off + 1;
                    }
                    let stats = store.merge_batches(&visible, now);
                    n += stats.inserted + stats.skipped;
                    if hit_unripe || entries.len() < TAIL_CHUNK {
                        break;
                    }
                }
            }
            applied.insert(region.clone(), n);
        }
        applied
    }

    /// Log entries a region has not applied yet.
    pub fn backlog(&self, region: &str) -> usize {
        let cursors = self.cursors.lock().unwrap();
        self.replicas
            .iter()
            .position(|(name, _, _)| name.as_str() == region)
            .map(|ri| {
                (0..self.log.partitions())
                    .map(|p| (self.log.high_water(p) - cursors[ri][p]) as usize)
                    .sum()
            })
            .unwrap_or(0)
    }
}

struct Pending {
    table: String,
    /// One shared copy of the batch for *all* replica queues (the
    /// write-path symmetry follow-up: enqueue used to clone the record
    /// vector once per region).
    records: Arc<[FeatureRecord]>,
    visible_at: Timestamp,
}

/// Replicates online merges from a home store to replica stores.
pub struct GeoReplicator {
    replicas: HashMap<String, Arc<OnlineStore>>,
    /// Per-replica apply queue.
    queues: Mutex<HashMap<String, VecDeque<Pending>>>,
    /// Replication lag per replica region (seconds on the processing
    /// timeline).
    lag_secs: HashMap<String, i64>,
}

impl GeoReplicator {
    pub fn new(replicas: Vec<(String, Arc<OnlineStore>, i64)>) -> Self {
        let mut map = HashMap::new();
        let mut lag = HashMap::new();
        let mut queues = HashMap::new();
        for (region, store, lag_secs) in replicas {
            map.insert(region.clone(), store);
            lag.insert(region.clone(), lag_secs);
            queues.insert(region, VecDeque::new());
        }
        GeoReplicator { replicas: map, queues: Mutex::new(queues), lag_secs: lag }
    }

    pub fn replica(&self, region: &str) -> Option<&Arc<OnlineStore>> {
        self.replicas.get(region)
    }

    pub fn regions(&self) -> Vec<String> {
        let mut r: Vec<_> = self.replicas.keys().cloned().collect();
        r.sort();
        r
    }

    /// The replica stores + lags, for wiring a streaming [`LogTailer`]
    /// onto the same destination stores the batch path pushes to.
    pub fn replica_set(&self) -> Vec<(String, Arc<OnlineStore>, i64)> {
        let mut out: Vec<_> = self
            .replicas
            .iter()
            .map(|(region, store)| (region.clone(), store.clone(), self.lag_secs[region]))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Called after every home-region merge: enqueue for each replica.
    /// The batch is copied **once** into a shared `Arc` — every replica
    /// queue holds the same allocation, mirroring how the read path
    /// shares one routed batch across a region's key set.
    pub fn enqueue(&self, table: &str, records: &[FeatureRecord], now: Timestamp) {
        if records.is_empty() {
            return;
        }
        let shared: Arc<[FeatureRecord]> = records.into();
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            queue.push_back(Pending {
                table: table.to_string(),
                records: shared.clone(),
                visible_at: now + self.lag_secs[region],
            });
        }
    }

    /// Apply every queued batch that has become visible by `now`.
    /// Returns records applied per region.
    ///
    /// Visible batches are drained first and applied through
    /// [`OnlineStore::merge_batches`]: one shard-grouped merge per table
    /// instead of one per batch (the `merge`/`get_many` symmetry from
    /// the ROADMAP).
    pub fn pump(&self, now: Timestamp) -> HashMap<String, u64> {
        let mut applied = HashMap::new();
        let mut q = self.queues.lock().unwrap();
        for (region, queue) in q.iter_mut() {
            let store = &self.replicas[region];
            let mut visible: Vec<Pending> = Vec::new();
            while queue.front().map_or(false, |p| p.visible_at <= now) {
                visible.push(queue.pop_front().unwrap());
            }
            let batches: Vec<(&str, &[FeatureRecord])> =
                visible.iter().map(|p| (p.table.as_str(), &p.records[..])).collect();
            let stats = store.merge_batches(&batches, now);
            applied.insert(region.clone(), stats.inserted + stats.skipped);
        }
        applied
    }

    /// Worst-case staleness of a replica at `now`: age of its oldest
    /// unapplied batch (0 when fully caught up).
    pub fn staleness_secs(&self, region: &str, now: Timestamp) -> i64 {
        let q = self.queues.lock().unwrap();
        q.get(region)
            .and_then(|queue| queue.front())
            .map(|p| (now - (p.visible_at - self.lag_secs[region])).max(0))
            .unwrap_or(0)
    }

    pub fn backlog(&self, region: &str) -> usize {
        self.queues.lock().unwrap().get(region).map(|q| q.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn replicator(lag: i64) -> (GeoReplicator, Arc<OnlineStore>) {
        let store = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![("westeurope".into(), store.clone(), lag)]);
        (r, store)
    }

    #[test]
    fn records_visible_after_lag() {
        let (r, store) = replicator(60);
        r.enqueue("t", &[rec(1, 100, 150, 1.0)], 1_000);
        r.pump(1_030);
        assert!(store.get("t", 1, 1_030).is_none(), "not visible before lag");
        assert_eq!(r.backlog("westeurope"), 1);
        r.pump(1_060);
        assert_eq!(store.get("t", 1, 1_060).unwrap().values[0], 1.0);
        assert_eq!(r.backlog("westeurope"), 0);
    }

    #[test]
    fn staleness_measures_oldest_pending() {
        let (r, _) = replicator(120);
        assert_eq!(r.staleness_secs("westeurope", 0), 0);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 1_000);
        r.enqueue("t", &[rec(2, 1, 2, 1.0)], 1_050);
        assert_eq!(r.staleness_secs("westeurope", 1_080), 80);
        r.pump(1_120); // first batch applies
        assert_eq!(r.staleness_secs("westeurope", 1_130), 80); // second pending, enqueued 1050
        r.pump(1_200);
        assert_eq!(r.staleness_secs("westeurope", 1_300), 0);
    }

    #[test]
    fn replication_preserves_alg2_ordering() {
        // Batches applied in order converge replicas to the home state
        // even when a late-arriving record was merged in between.
        let (r, store) = replicator(10);
        r.enqueue("t", &[rec(1, 100, 110, 1.0)], 0);
        r.enqueue("t", &[rec(1, 100, 300, 2.0)], 5); // recompute
        r.enqueue("t", &[rec(1, 90, 400, 0.5)], 6); // older event: no-op
        r.pump(1_000);
        let got = store.get("t", 1, 1_000).unwrap();
        assert_eq!(got.version(), (100, 300));
        assert_eq!(got.values[0], 2.0);
    }

    #[test]
    fn pump_coalesces_batches_per_table_per_region() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![
            ("westeurope".into(), eu.clone(), 10),
            ("southeastasia".into(), asia.clone(), 10),
        ]);
        // Three batches for "a" (including a same-event recompute and a
        // stale event) and one for "b", all visible at once: one merge
        // per table per region must converge exactly as per-batch
        // application would.
        r.enqueue("a", &[rec(1, 100, 110, 1.0)], 0);
        r.enqueue("a", &[rec(1, 100, 300, 2.0), rec(2, 10, 20, 9.0)], 1);
        r.enqueue("b", &[rec(1, 5, 6, 3.0)], 2);
        r.enqueue("a", &[rec(1, 90, 400, 0.5)], 3); // older event: no-op
        let applied = r.pump(1_000);
        assert_eq!(applied["westeurope"], 5);
        assert_eq!(applied["southeastasia"], 5);
        for store in [&eu, &asia] {
            let got = store.get("a", 1, 1_000).unwrap();
            assert_eq!(got.version(), (100, 300));
            assert_eq!(got.values[0], 2.0);
            assert_eq!(store.get("a", 2, 1_000).unwrap().values[0], 9.0);
            assert_eq!(store.get("b", 1, 1_000).unwrap().values[0], 3.0);
        }
        assert_eq!(r.backlog("westeurope"), 0);
        assert_eq!(r.backlog("southeastasia"), 0);
    }

    #[test]
    fn multiple_replicas_independent_lag() {
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let r = GeoReplicator::new(vec![
            ("westeurope".into(), eu.clone(), 30),
            ("southeastasia".into(), asia.clone(), 90),
        ]);
        r.enqueue("t", &[rec(1, 1, 2, 1.0)], 100);
        r.pump(140);
        assert!(eu.get("t", 1, 140).is_some());
        assert!(asia.get("t", 1, 140).is_none());
        r.pump(190);
        assert!(asia.get("t", 1, 190).is_some());
        assert_eq!(r.regions(), vec!["southeastasia", "westeurope"]);
        let set = r.replica_set();
        assert_eq!(set.len(), 2);
        assert_eq!((set[0].0.as_str(), set[0].2), ("southeastasia", 90));
        assert_eq!((set[1].0.as_str(), set[1].2), ("westeurope", 30));
    }

    fn batch(table: &str, entity: u64, event: Timestamp, created: Timestamp, v: f32, at: Timestamp) -> ReplBatch {
        ReplBatch {
            table: table.into(),
            records: [rec(entity, event, created, v)].into(),
            appended_at: at,
        }
    }

    #[test]
    fn tailer_applies_after_lag_in_log_order() {
        let log = Arc::new(PartitionedLog::new(1));
        let eu = Arc::new(OnlineStore::new(2));
        let asia = Arc::new(OnlineStore::new(2));
        let t = LogTailer::new(
            log.clone(),
            vec![("westeurope".into(), eu.clone(), 30), ("southeastasia".into(), asia.clone(), 90)],
        );
        log.append(0, batch("t", 1, 100, 110, 1.0, 1_000));
        log.append(0, batch("t", 1, 100, 300, 2.0, 1_005)); // recompute
        log.append(0, batch("u", 2, 5, 6, 3.0, 1_010));
        // Before any lag elapses: nothing applied anywhere.
        let applied = t.pump(1_020);
        assert_eq!(applied["westeurope"], 0);
        assert_eq!(t.backlog("westeurope"), 3);
        // EU lag elapsed for all three, Asia still waiting.
        let applied = t.pump(1_040);
        assert_eq!(applied["westeurope"], 3);
        assert_eq!(applied["southeastasia"], 0);
        assert_eq!(eu.get("t", 1, 1_040).unwrap().version(), (100, 300));
        assert_eq!(eu.get("u", 2, 1_040).unwrap().values[0], 3.0);
        assert!(asia.get("t", 1, 1_040).is_none());
        assert_eq!(t.backlog("westeurope"), 0);
        assert_eq!(t.backlog("southeastasia"), 3);
        // Asia catches up from the same log entries (one history, two
        // cursors).
        t.pump(1_100);
        assert_eq!(asia.get("t", 1, 1_100).unwrap().version(), (100, 300));
        assert_eq!(t.backlog("southeastasia"), 0);
        // Replays are no-ops: the cursor moved past everything.
        assert_eq!(t.pump(2_000)["westeurope"], 0);
        assert_eq!(t.regions(), vec!["southeastasia", "westeurope"]);
    }

    #[test]
    fn tailer_stops_at_first_unripe_entry() {
        // Apply order is log order: a visible entry behind an unripe one
        // must wait (prefix semantics, like a real log tail).
        let log = Arc::new(PartitionedLog::new(1));
        let eu = Arc::new(OnlineStore::new(2));
        let t = LogTailer::new(log.clone(), vec![("eu".into(), eu.clone(), 10)]);
        log.append(0, batch("t", 1, 100, 110, 1.0, 1_000));
        log.append(0, batch("t", 2, 100, 110, 2.0, 5_000));
        log.append(0, batch("t", 3, 100, 110, 3.0, 1_001)); // appended_at regressed
        let applied = t.pump(1_050);
        assert_eq!(applied["eu"], 1);
        assert!(eu.get("t", 3, 1_050).is_none(), "entry behind unripe prefix must wait");
        t.pump(5_010);
        assert!(eu.get("t", 2, 5_010).is_some() && eu.get("t", 3, 5_010).is_some());
        assert_eq!(t.backlog("eu"), 0);
        assert_eq!(t.backlog("nope"), 0);
    }
}
