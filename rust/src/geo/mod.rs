//! Geo-distribution (§2.1 "Regional presence", §3.1.2–§3.1.3, §4.1.2).
//!
//! The substrate here replaces Azure's regions and WAN (DESIGN.md §5): a
//! simulated topology with a configurable inter-region latency matrix and
//! injectable outages.  On top of it, one **replication fabric** ties the
//! geo story together:
//!
//! * [`replication`] — the fabric: every home-region online merge
//!   (batch scheduler job, streaming dual-write, bootstrap) appends a
//!   `ReplBatch` to one shared durable record log; replica regions are
//!   just per-region cursors into it, advanced by a background
//!   `ReplicationDriver` (push-woken on append + periodic lag ticks),
//!   with the log truncated below the minimum applied cursor. Writes
//!   return `SessionToken`s (per-partition log positions).
//! * [`access`] — consistency-aware routed reads: `Strong` (home
//!   region, one WAN RTT), `BoundedStaleness(secs)` (replica only while
//!   its log-position staleness is within the bound, else cross-region
//!   fallback), and `ReadYourWrites(token)` (replica only once its
//!   cursors cover the session token). Geo-fenced stores never leave
//!   their home region (§4.1.2 "data compliance issues").
//! * [`failover`] — region-down handling: restore metadata + scheduler
//!   checkpoint in a standby region, promote the standby's replica
//!   store, replay the retained fabric log (no acked write lost), and
//!   come back as a first-class home with its own running drivers.
//!
//! `benches/geo_access.rs` (experiments E6 + E-GEO) quantifies the
//! latency ↔ staleness trade per consistency policy and the fabric's
//! apply throughput vs region count.

pub mod access;
pub mod failover;
pub mod replication;
pub mod topology;

pub use access::{AccessMechanism, CrossRegionAccess, ReadConsistency};
pub use failover::{FailoverManager, PromotedRegion, RegionCheckpoint};
pub use replication::{ReplBatch, ReplicationDriver, ReplicationFabric, SessionToken};
pub use topology::GeoTopology;
