//! Geo-distribution (§2.1 "Regional presence", §3.1.2–§3.1.3, §4.1.2).
//!
//! The substrate here replaces Azure's regions and WAN (DESIGN.md §5): a
//! simulated topology with a configurable inter-region latency matrix and
//! injectable outages.  On top of it:
//!
//! * [`access`] — cross-region asset access (data stays in its home
//!   region; consumers pay WAN latency) — the mechanism AzureML shipped.
//! * [`replication`] — geo-replication with asynchronous lag (the
//!   roadmap mechanism): local-latency reads, staleness > 0.
//! * [`failover`] — region-down handling: restore metadata + scheduler
//!   checkpoint in a standby region and resume without data loss.
//!
//! `benches/geo_access.rs` (experiment E6) quantifies the latency ↔
//! staleness trade between the two access mechanisms.

pub mod access;
pub mod failover;
pub mod replication;
pub mod topology;

pub use access::{AccessMechanism, CrossRegionAccess};
pub use failover::{FailoverManager, RegionCheckpoint};
pub use replication::GeoReplicator;
pub use topology::GeoTopology;
