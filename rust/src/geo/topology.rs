//! Region topology: names, pairwise latency, and health.

use std::collections::{HashMap, HashSet};
use std::sync::RwLock;

use crate::types::{FsError, Result};

/// Simulated multi-region topology.
///
/// Latencies are one-way microseconds; `rtt_us` doubles them. Defaults
/// are calibrated to public cloud inter-region numbers (same-region
/// ~0.5 ms RTT, cross-continent ~70–150 ms RTT).
#[derive(Debug)]
pub struct GeoTopology {
    regions: Vec<String>,
    one_way_us: HashMap<(String, String), u64>,
    down: RwLock<HashSet<String>>,
    /// Local (in-region) lookup one-way latency.
    local_us: u64,
}

impl GeoTopology {
    /// Build a topology from `(from, to, one_way_us)` entries; latency is
    /// symmetrized.
    pub fn new(regions: &[&str], links: &[(&str, &str, u64)], local_us: u64) -> Self {
        let mut one_way = HashMap::new();
        for (a, b, us) in links {
            one_way.insert((a.to_string(), b.to_string()), *us);
            one_way.insert((b.to_string(), a.to_string()), *us);
        }
        GeoTopology {
            regions: regions.iter().map(|s| s.to_string()).collect(),
            one_way_us: one_way,
            down: RwLock::new(HashSet::new()),
            local_us,
        }
    }

    /// The 4-region default used by examples and benches: two US regions,
    /// one EU, one APAC (public-cloud-like numbers).
    pub fn default_four_region() -> Self {
        Self::new(
            &["eastus", "westus", "westeurope", "southeastasia"],
            &[
                ("eastus", "westus", 30_000),
                ("eastus", "westeurope", 40_000),
                ("eastus", "southeastasia", 110_000),
                ("westus", "westeurope", 70_000),
                ("westus", "southeastasia", 85_000),
                ("westeurope", "southeastasia", 90_000),
            ],
            250,
        )
    }

    pub fn regions(&self) -> &[String] {
        &self.regions
    }

    pub fn has_region(&self, r: &str) -> bool {
        self.regions.iter().any(|x| x == r)
    }

    pub fn is_up(&self, r: &str) -> bool {
        !self.down.read().unwrap().contains(r)
    }

    /// Inject an outage (§3.1.2 "when one region is down").
    pub fn set_down(&self, r: &str, down: bool) {
        let mut g = self.down.write().unwrap();
        if down {
            g.insert(r.to_string());
        } else {
            g.remove(r);
        }
    }

    fn check_up(&self, r: &str) -> Result<()> {
        if !self.has_region(r) {
            return Err(FsError::NotFound(format!("region '{r}'")));
        }
        if !self.is_up(r) {
            return Err(FsError::RegionDown(r.to_string()));
        }
        Ok(())
    }

    /// One-way latency in µs between two (up) regions.
    pub fn one_way_us(&self, from: &str, to: &str) -> Result<u64> {
        self.check_up(from)?;
        self.check_up(to)?;
        if from == to {
            return Ok(self.local_us);
        }
        self.one_way_us
            .get(&(from.to_string(), to.to_string()))
            .copied()
            .ok_or_else(|| FsError::Other(format!("no link {from} → {to}")))
    }

    /// Round-trip latency in µs.
    pub fn rtt_us(&self, from: &str, to: &str) -> Result<u64> {
        Ok(self.one_way_us(from, to)? * 2)
    }

    /// Nearest *up* region to `from`, excluding `from` itself — the
    /// failover target choice.
    pub fn nearest_standby(&self, from: &str) -> Option<String> {
        self.regions
            .iter()
            .filter(|r| *r != from && self.is_up(r))
            .min_by_key(|r| {
                self.one_way_us
                    .get(&(from.to_string(), r.to_string()))
                    .copied()
                    .unwrap_or(u64::MAX)
            })
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_symmetric_and_local() {
        let t = GeoTopology::default_four_region();
        assert_eq!(t.one_way_us("eastus", "westus").unwrap(), 30_000);
        assert_eq!(t.one_way_us("westus", "eastus").unwrap(), 30_000);
        assert_eq!(t.one_way_us("eastus", "eastus").unwrap(), 250);
        assert_eq!(t.rtt_us("eastus", "westeurope").unwrap(), 80_000);
    }

    #[test]
    fn outage_errors_and_recovers() {
        let t = GeoTopology::default_four_region();
        t.set_down("westus", true);
        assert!(matches!(
            t.one_way_us("eastus", "westus"),
            Err(FsError::RegionDown(_))
        ));
        assert!(!t.is_up("westus"));
        t.set_down("westus", false);
        assert!(t.one_way_us("eastus", "westus").is_ok());
    }

    #[test]
    fn unknown_region_not_found() {
        let t = GeoTopology::default_four_region();
        assert!(matches!(t.one_way_us("eastus", "mars"), Err(FsError::NotFound(_))));
    }

    #[test]
    fn nearest_standby_picks_lowest_latency_up_region() {
        let t = GeoTopology::default_four_region();
        assert_eq!(t.nearest_standby("eastus").unwrap(), "westus");
        t.set_down("westus", true);
        assert_eq!(t.nearest_standby("eastus").unwrap(), "westeurope");
        t.set_down("westeurope", true);
        assert_eq!(t.nearest_standby("eastus").unwrap(), "southeastasia");
    }
}
