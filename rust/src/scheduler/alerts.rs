//! Alerting for non-recoverable failures (§3.1.3).

use std::sync::Mutex;

use crate::types::Timestamp;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Critical,
}

#[derive(Debug, Clone)]
pub struct Alert {
    pub at: Timestamp,
    pub severity: Severity,
    pub subsystem: &'static str,
    pub message: String,
}

/// Thread-safe alert collector. Production would fan out to paging /
/// metrics; tests assert on the collected alerts.
#[derive(Debug, Default)]
pub struct AlertSink {
    alerts: Mutex<Vec<Alert>>,
}

impl AlertSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn raise(&self, at: Timestamp, severity: Severity, subsystem: &'static str, message: impl Into<String>) {
        let a = Alert { at, severity, subsystem, message: message.into() };
        if severity >= Severity::Warning {
            log::warn!("[alert:{subsystem}] {}", a.message);
        }
        self.alerts.lock().unwrap().push(a);
    }

    pub fn all(&self) -> Vec<Alert> {
        self.alerts.lock().unwrap().clone()
    }

    pub fn count_at_least(&self, severity: Severity) -> usize {
        self.alerts.lock().unwrap().iter().filter(|a| a.severity >= severity).count()
    }

    pub fn clear(&self) {
        self.alerts.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_and_filters() {
        let s = AlertSink::new();
        s.raise(1, Severity::Info, "scheduler", "tick");
        s.raise(2, Severity::Critical, "materialize", "job failed permanently");
        assert_eq!(s.all().len(), 2);
        assert_eq!(s.count_at_least(Severity::Warning), 1);
        assert_eq!(s.count_at_least(Severity::Info), 2);
        s.clear();
        assert!(s.all().is_empty());
    }

    #[test]
    fn severity_ordering() {
        assert!(Severity::Critical > Severity::Warning);
        assert!(Severity::Warning > Severity::Info);
    }
}
