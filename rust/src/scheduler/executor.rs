//! The scheduler executor: drives incremental + backfill materialization
//! jobs with retry, suspension, and alerting (§3.1.1–§3.1.3, §4.3).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::alerts::{AlertSink, Severity};
use super::policy::SchedulePolicy;
use super::tracker::WindowTracker;
use crate::exec::retry::{retry_with, RetryPolicy};
use crate::exec::ThreadPool;
use crate::types::{FeatureWindow, FsError, Result};
use crate::util::Clock;

/// A materialization job body: computes + merges one window, returning
/// the number of records merged. Provided by the materialization engine;
/// the scheduler is agnostic to how features are computed.
pub type JobFn = Arc<dyn Fn(FeatureWindow, u32) -> Result<u64> + Send + Sync>;

/// Result of running one job window.
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    pub window: FeatureWindow,
    pub records: u64,
    pub attempts: u32,
    pub backfill: bool,
}

#[derive(Debug, Default)]
struct TableState {
    tracker: WindowTracker,
    /// Scheduled materialization suspended while a backfill runs (§3.1.1).
    suspended: bool,
    /// Windows that became due while suspended; run on resume.
    deferred: Vec<FeatureWindow>,
}

/// The scheduling subsystem. One instance per region; tables are keyed
/// by feature-set reference.
pub struct Scheduler {
    tables: Mutex<HashMap<String, TableState>>,
    pool: Arc<ThreadPool>,
    retry: RetryPolicy,
    pub alerts: Arc<AlertSink>,
    pub clock: Clock,
}

impl Scheduler {
    pub fn new(pool: Arc<ThreadPool>, clock: Clock, retry: RetryPolicy) -> Self {
        Scheduler {
            tables: Mutex::new(HashMap::new()),
            pool,
            retry,
            alerts: Arc::new(AlertSink::new()),
            clock,
        }
    }

    fn with_table<T>(&self, table: &str, f: impl FnOnce(&mut TableState) -> T) -> T {
        let mut g = self.tables.lock().unwrap();
        f(g.entry(table.to_string()).or_default())
    }

    /// Run one scheduled tick for a table: claim + execute every due
    /// window. Windows due while the table is suspended are deferred.
    pub fn tick(&self, table: &str, policy: &SchedulePolicy, origin: i64, job: JobFn) -> Vec<JobOutcome> {
        let now = self.clock.now();
        let due = self.with_table(table, |t| {
            let hw = t.tracker.high_water(origin);
            let due = policy.due_windows(hw, now);
            if t.suspended {
                for w in &due {
                    if !t.deferred.contains(w) {
                        t.deferred.push(*w);
                    }
                }
                Vec::new()
            } else {
                due
            }
        });
        self.run_windows(table, &due, job, false)
    }

    /// One-time backfill (§4.3): suspends scheduled materialization,
    /// partitions the requested window, runs the pieces in parallel,
    /// resumes scheduled work (running anything deferred meanwhile).
    pub fn backfill(
        &self,
        table: &str,
        policy: &SchedulePolicy,
        window: FeatureWindow,
        job: JobFn,
    ) -> Vec<JobOutcome> {
        self.with_table(table, |t| t.suspended = true);
        let parts = policy.partition_backfill(window);
        let mut outcomes = self.run_windows(table, &parts, job.clone(), true);

        // Resume: release suspension and run deferred scheduled windows.
        let deferred = self.with_table(table, |t| {
            t.suspended = false;
            std::mem::take(&mut t.deferred)
        });
        if !deferred.is_empty() {
            log::info!("scheduler: resuming {} deferred window(s) for '{table}'", deferred.len());
            outcomes.extend(self.run_windows(table, &deferred, job, false));
        }
        outcomes
    }

    /// Claim + execute a set of windows on the worker pool.
    fn run_windows(
        &self,
        table: &str,
        windows: &[FeatureWindow],
        job: JobFn,
        backfill: bool,
    ) -> Vec<JobOutcome> {
        let mut handles = Vec::new();
        for &w in windows {
            // Skip already-materialized backfill pieces (idempotent
            // backfill over partially-covered ranges).
            let claim = self.with_table(table, |t| {
                if backfill && t.tracker.is_materialized(&w) {
                    Ok(None)
                } else {
                    t.tracker.try_claim(w).map(Some)
                }
            });
            let job_id = match claim {
                Ok(None) => continue,
                Ok(Some(id)) => id,
                Err(FsError::WindowConflict { got, active }) => {
                    self.alerts.raise(
                        self.clock.now(),
                        Severity::Warning,
                        "scheduler",
                        format!("window conflict on '{table}': {got} vs active {active}"),
                    );
                    continue;
                }
                Err(e) => {
                    self.alerts.raise(self.clock.now(), Severity::Warning, "scheduler", e.to_string());
                    continue;
                }
            };
            let job = job.clone();
            let retry = self.retry.clone();
            let clock = self.clock.clone();
            handles.push((
                job_id,
                w,
                self.pool.submit(move || {
                    retry_with(&retry, &clock, |attempt| job(w, attempt))
                }),
            ));
        }

        let mut outcomes = Vec::new();
        for (job_id, w, h) in handles {
            match h.join() {
                Ok(out) => {
                    self.with_table(table, |t| t.tracker.complete(job_id)).expect("complete");
                    outcomes.push(JobOutcome {
                        window: w,
                        records: out.value,
                        attempts: out.attempts,
                        backfill,
                    });
                }
                Err(e) => {
                    self.with_table(table, |t| t.tracker.fail(job_id)).expect("fail");
                    self.alerts.raise(
                        self.clock.now(),
                        Severity::Critical,
                        "scheduler",
                        format!("job on '{table}' {w} failed permanently: {e}"),
                    );
                }
            }
        }
        outcomes
    }

    /// Data-state inspection (§4.3): fully materialized?
    pub fn is_materialized(&self, table: &str, window: &FeatureWindow) -> bool {
        self.with_table(table, |t| t.tracker.is_materialized(window))
    }

    /// Unmaterialized gaps of `window`.
    pub fn gaps(&self, table: &str, window: FeatureWindow) -> Vec<FeatureWindow> {
        self.with_table(table, |t| t.tracker.gaps(window))
    }

    pub fn coverage(&self, table: &str) -> Vec<FeatureWindow> {
        self.with_table(table, |t| t.tracker.coverage().to_vec())
    }

    pub fn is_suspended(&self, table: &str) -> bool {
        self.with_table(table, |t| t.suspended)
    }

    /// Snapshot of per-table coverage for failover checkpointing
    /// (§3.1.2 "safely resume from where it left off").
    pub fn checkpoint(&self) -> Vec<(String, Vec<FeatureWindow>)> {
        let g = self.tables.lock().unwrap();
        g.iter().map(|(k, t)| (k.clone(), t.tracker.coverage().to_vec())).collect()
    }

    /// Restore coverage from a checkpoint (new region taking over).
    pub fn restore(&self, checkpoint: &[(String, Vec<FeatureWindow>)]) {
        for (table, windows) in checkpoint {
            self.with_table(table, |t| {
                for &w in windows {
                    let id = t.tracker.try_claim(w).expect("restore claim");
                    t.tracker.complete(id).expect("restore complete");
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::{Granularity, DAY, HOUR};
    use std::sync::atomic::{AtomicU32, Ordering};

    fn sched() -> Scheduler {
        Scheduler::new(Arc::new(ThreadPool::new(4)), Clock::fixed(0), RetryPolicy::default())
    }

    fn policy() -> SchedulePolicy {
        SchedulePolicy {
            granularity: Granularity(HOUR),
            interval_secs: DAY,
            source_delay_secs: 0,
            max_bins_per_job: 24,
        }
    }

    fn ok_job() -> JobFn {
        Arc::new(|w, _| Ok(w.len() as u64))
    }

    #[test]
    fn tick_runs_due_windows_and_advances() {
        let s = sched();
        s.clock.set(2 * DAY);
        let out = s.tick("t", &policy(), 0, ok_job());
        assert_eq!(out.len(), 2);
        assert!(s.is_materialized("t", &FeatureWindow::new(0, 2 * DAY)));
        // Second tick at same time: nothing due.
        assert!(s.tick("t", &policy(), 0, ok_job()).is_empty());
        // Advance a day: one more.
        s.clock.set(3 * DAY);
        assert_eq!(s.tick("t", &policy(), 0, ok_job()).len(), 1);
    }

    #[test]
    fn retry_then_success() {
        let s = sched();
        s.clock.set(DAY);
        let tries = Arc::new(AtomicU32::new(0));
        let t2 = tries.clone();
        let job: JobFn = Arc::new(move |w, attempt| {
            t2.fetch_add(1, Ordering::SeqCst);
            if attempt < 2 {
                Err(FsError::InjectedFault("flaky".into()))
            } else {
                Ok(w.len() as u64)
            }
        });
        let out = s.tick("t", &policy(), 0, job);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].attempts, 3);
        assert_eq!(tries.load(Ordering::SeqCst), 3);
        assert_eq!(s.alerts.count_at_least(Severity::Critical), 0);
    }

    #[test]
    fn permanent_failure_raises_alert_and_releases_claim() {
        let s = sched();
        s.clock.set(DAY);
        let job: JobFn = Arc::new(|_, _| Err(FsError::InjectedFault("always".into())));
        let out = s.tick("t", &policy(), 0, job);
        assert!(out.is_empty());
        assert_eq!(s.alerts.count_at_least(Severity::Critical), 1);
        assert!(!s.is_materialized("t", &FeatureWindow::new(0, DAY)));
        // Window can be retried by a later tick.
        let out = s.tick("t", &policy(), 0, ok_job());
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn backfill_suspends_and_resumes_scheduled() {
        let s = sched();
        let p = policy();
        s.clock.set(DAY);
        s.tick("t", &p, 0, ok_job()); // day 0 materialized

        // Backfill an old range on another thread; its first job blocks
        // until this thread has observed the suspension with a tick.
        s.clock.set(3 * DAY);
        let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let go_rx = std::sync::Mutex::new(go_rx);
        let started_tx = std::sync::Mutex::new(started_tx);
        let out = std::thread::scope(|scope| {
            let h = scope.spawn(|| {
                s.backfill(
                    "t",
                    &p,
                    FeatureWindow::new(-2 * DAY, 0),
                    Arc::new(move |w, _| {
                        let _ = started_tx.lock().unwrap().send(());
                        let _ = go_rx.lock().unwrap().recv_timeout(
                            std::time::Duration::from_secs(5),
                        );
                        Ok(w.len() as u64)
                    }),
                )
            });
            started_rx.recv().unwrap(); // a backfill piece is running
            assert!(s.is_suspended("t"));
            // Scheduled tick during backfill must defer, not run.
            let during = s.tick("t", &p, 0, ok_job());
            assert!(during.is_empty(), "tick during backfill must defer");
            drop(go_tx); // release all blocked pieces
            h.join().unwrap()
        });
        // Backfill pieces (2 days) + deferred scheduled windows (days 1,2).
        let backfills = out.iter().filter(|o| o.backfill).count();
        let scheduled = out.iter().filter(|o| !o.backfill).count();
        assert_eq!(backfills, 2);
        assert_eq!(scheduled, 2);
        assert!(!s.is_suspended("t"));
        assert!(s.is_materialized("t", &FeatureWindow::new(-2 * DAY, 3 * DAY)));
    }

    #[test]
    fn backfill_skips_already_materialized_pieces() {
        let s = sched();
        let p = policy();
        s.clock.set(2 * DAY);
        s.tick("t", &p, 0, ok_job()); // days 0-1 done
        let out = s.backfill("t", &p, FeatureWindow::new(0, 2 * DAY), ok_job());
        assert!(out.is_empty(), "fully-covered backfill is a no-op: {out:?}");
    }

    #[test]
    fn gaps_surface_unmaterialized_ranges() {
        let s = sched();
        let p = policy();
        s.clock.set(DAY);
        s.tick("t", &p, 0, ok_job());
        let gaps = s.gaps("t", FeatureWindow::new(-DAY, 2 * DAY));
        assert_eq!(gaps, vec![FeatureWindow::new(-DAY, 0), FeatureWindow::new(DAY, 2 * DAY)]);
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let s = sched();
        let p = policy();
        s.clock.set(2 * DAY);
        s.tick("t", &p, 0, ok_job());
        let cp = s.checkpoint();

        let s2 = sched();
        s2.restore(&cp);
        assert!(s2.is_materialized("t", &FeatureWindow::new(0, 2 * DAY)));
        // Resumed region continues from the high-water mark, no re-work.
        s2.clock.set(3 * DAY);
        let out = s2.tick("t", &p, 0, ok_job());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].window, FeatureWindow::new(2 * DAY, 3 * DAY));
    }
}
