//! Context-aware scheduling subsystem (§3.1.1, §4.3).
//!
//! Tracks two kinds of state per feature-set table:
//!
//! * **Data state** — which feature windows are materialized vs not, on
//!   the event timeline.
//! * **Job state** — active (queued/running) jobs and the window each
//!   covers.
//!
//! Invariants enforced here (exercised by `tests/scheduler_invariants.rs`):
//! concurrent jobs never claim overlapping windows; backfill suspends
//! scheduled materialization and resumes it after (§3.1.1); retrying a
//! failed job cannot double-claim; "not materialized" is always
//! distinguishable from "no data in the window" (§4.3).

pub mod alerts;
pub mod executor;
pub mod policy;
pub mod tracker;

pub use alerts::{Alert, AlertSink, Severity};
pub use executor::{JobOutcome, Scheduler};
pub use policy::SchedulePolicy;
pub use tracker::{JobId, WindowTracker};
