//! Data-state + job-state tracking (§4.3).

use std::collections::HashMap;

use crate::types::{FeatureWindow, FsError, Result};

pub type JobId = u64;

/// Per-table window tracker.
///
/// `materialized` is kept as a sorted, coalesced list of disjoint
/// windows; `active` maps in-flight jobs to their claimed windows.
#[derive(Debug, Default)]
pub struct WindowTracker {
    materialized: Vec<FeatureWindow>,
    active: HashMap<JobId, FeatureWindow>,
    next_job: JobId,
}

impl WindowTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Claim `window` for a new job. Fails with `WindowConflict` if any
    /// active job's window overlaps (§4.3: "Concurrent jobs do not have
    /// overlapping feature windows").
    pub fn try_claim(&mut self, window: FeatureWindow) -> Result<JobId> {
        if window.is_empty() {
            return Err(FsError::InvalidArg("cannot claim an empty window".into()));
        }
        if let Some(conflict) = self.active.values().find(|w| w.overlaps(&window)) {
            return Err(FsError::WindowConflict { got: window, active: *conflict });
        }
        let id = self.next_job;
        self.next_job += 1;
        self.active.insert(id, window);
        Ok(id)
    }

    /// Job finished successfully: release the claim and mark its window
    /// materialized.
    pub fn complete(&mut self, job: JobId) -> Result<()> {
        let w = self
            .active
            .remove(&job)
            .ok_or_else(|| FsError::NotFound(format!("job {job}")))?;
        self.insert_materialized(w);
        Ok(())
    }

    /// Job failed: release the claim without marking data state.
    pub fn fail(&mut self, job: JobId) -> Result<()> {
        self.active
            .remove(&job)
            .map(|_| ())
            .ok_or_else(|| FsError::NotFound(format!("job {job}")))
    }

    fn insert_materialized(&mut self, w: FeatureWindow) {
        // Sorted-splice insert with local coalescing: O(log n) search +
        // one splice, instead of a full re-sort per completion (the
        // common case — appending at the high-water mark — is O(1)
        // amortized; see EXPERIMENTS.md §Perf L3).
        let i = self.materialized.partition_point(|m| m.start < w.start);
        let mut new = w;
        let mut start_idx = i;
        if i > 0 && self.materialized[i - 1].end >= w.start {
            start_idx = i - 1;
            new = FeatureWindow::new(
                self.materialized[i - 1].start,
                self.materialized[i - 1].end.max(w.end),
            );
        }
        let mut end_idx = start_idx;
        while end_idx < self.materialized.len() && self.materialized[end_idx].start <= new.end {
            new = FeatureWindow::new(new.start, new.end.max(self.materialized[end_idx].end));
            end_idx += 1;
        }
        self.materialized.splice(start_idx..end_idx, [new]);
    }

    /// Is the *entire* window materialized?
    pub fn is_materialized(&self, window: &FeatureWindow) -> bool {
        if window.is_empty() {
            return true;
        }
        self.materialized
            .iter()
            .any(|m| m.start <= window.start && m.end >= window.end)
    }

    /// Unmaterialized sub-windows of `window` — drives backfill planning
    /// and the "no result because not materialized" distinction (§4.3).
    pub fn gaps(&self, window: FeatureWindow) -> Vec<FeatureWindow> {
        let mut gaps = Vec::new();
        let mut cursor = window.start;
        for m in &self.materialized {
            if m.end <= cursor {
                continue;
            }
            if m.start >= window.end {
                break;
            }
            if m.start > cursor {
                gaps.push(FeatureWindow::new(cursor, m.start.min(window.end)));
            }
            cursor = cursor.max(m.end);
            if cursor >= window.end {
                break;
            }
        }
        if cursor < window.end {
            gaps.push(FeatureWindow::new(cursor, window.end));
        }
        gaps
    }

    /// Materialized coverage (sorted, disjoint).
    pub fn coverage(&self) -> &[FeatureWindow] {
        &self.materialized
    }

    /// Windows of currently active jobs.
    pub fn active_windows(&self) -> Vec<FeatureWindow> {
        self.active.values().copied().collect()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// End of contiguous materialized coverage starting at or before
    /// `origin` — the high-water mark scheduled materialization extends.
    pub fn high_water(&self, origin: i64) -> i64 {
        let mut hw = origin;
        for m in &self.materialized {
            if m.start <= hw && m.end > hw {
                hw = m.end;
            }
        }
        hw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(a: i64, b: i64) -> FeatureWindow {
        FeatureWindow::new(a, b)
    }

    #[test]
    fn claim_conflict_detection() {
        let mut t = WindowTracker::new();
        let j1 = t.try_claim(w(0, 10)).unwrap();
        assert!(matches!(t.try_claim(w(5, 15)), Err(FsError::WindowConflict { .. })));
        // Adjacent is fine (half-open).
        let j2 = t.try_claim(w(10, 20)).unwrap();
        assert_ne!(j1, j2);
        assert_eq!(t.active_count(), 2);
    }

    #[test]
    fn complete_materializes_and_releases() {
        let mut t = WindowTracker::new();
        let j = t.try_claim(w(0, 10)).unwrap();
        assert!(!t.is_materialized(&w(0, 10)));
        t.complete(j).unwrap();
        assert!(t.is_materialized(&w(0, 10)));
        assert!(t.is_materialized(&w(2, 8)));
        assert!(!t.is_materialized(&w(0, 11)));
        assert_eq!(t.active_count(), 0);
        // window can be re-claimed (recompute/late data)
        assert!(t.try_claim(w(0, 10)).is_ok());
    }

    #[test]
    fn fail_releases_without_materializing() {
        let mut t = WindowTracker::new();
        let j = t.try_claim(w(0, 10)).unwrap();
        t.fail(j).unwrap();
        assert!(!t.is_materialized(&w(0, 10)));
        assert!(t.try_claim(w(0, 10)).is_ok());
        assert!(t.fail(999).is_err());
    }

    #[test]
    fn coalescing() {
        let mut t = WindowTracker::new();
        for win in [w(0, 10), w(20, 30), w(10, 20)] {
            let j = t.try_claim(win).unwrap();
            t.complete(j).unwrap();
        }
        assert_eq!(t.coverage(), &[w(0, 30)]);
        assert!(t.is_materialized(&w(0, 30)));
    }

    #[test]
    fn gaps_reported_exactly() {
        let mut t = WindowTracker::new();
        for win in [w(10, 20), w(30, 40)] {
            let j = t.try_claim(win).unwrap();
            t.complete(j).unwrap();
        }
        assert_eq!(t.gaps(w(0, 50)), vec![w(0, 10), w(20, 30), w(40, 50)]);
        assert_eq!(t.gaps(w(12, 18)), vec![]);
        assert_eq!(t.gaps(w(15, 35)), vec![w(20, 30)]);
        assert_eq!(t.gaps(w(40, 45)), vec![w(40, 45)]);
    }

    #[test]
    fn high_water_mark() {
        let mut t = WindowTracker::new();
        assert_eq!(t.high_water(0), 0);
        for win in [w(0, 10), w(10, 25), w(40, 50)] {
            let j = t.try_claim(win).unwrap();
            t.complete(j).unwrap();
        }
        assert_eq!(t.high_water(0), 25); // stops at the gap
        assert_eq!(t.high_water(40), 50);
    }

    #[test]
    fn empty_window_rejected() {
        let mut t = WindowTracker::new();
        assert!(t.try_claim(w(5, 5)).is_err());
        assert!(t.is_materialized(&w(5, 5))); // vacuously
    }
}
