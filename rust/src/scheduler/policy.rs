//! Scheduling policy: when is the next incremental window due, and how
//! are large windows partitioned into job-sized units (§3.1.1's
//! "context aware partitioning scheme").

use crate::metadata::assets::FeatureSetSpec;
use crate::types::time::Granularity;
use crate::types::{FeatureWindow, Timestamp};

/// Derives job windows from a feature-set spec and the clock.
#[derive(Debug, Clone)]
pub struct SchedulePolicy {
    pub granularity: Granularity,
    /// Event-time length of each scheduled increment.
    pub interval_secs: i64,
    /// Events may land this late (§4.4): a window is only *ripe* for
    /// materialization once `now >= window.end + source_delay`.
    pub source_delay_secs: i64,
    /// Context-aware partitioning: max bins per job unit.
    pub max_bins_per_job: i64,
}

impl SchedulePolicy {
    pub fn from_spec(spec: &FeatureSetSpec) -> Self {
        SchedulePolicy {
            granularity: spec.granularity,
            interval_secs: spec.materialization.schedule_interval_secs,
            source_delay_secs: spec.source.source_delay_secs,
            max_bins_per_job: spec.materialization.max_bins_per_job,
        }
    }

    /// Scheduled incremental windows due at `now`, given materialization
    /// has already covered event time up to `high_water`.  Each returned
    /// window is one job; windows are aligned, non-overlapping, and only
    /// include event time that is ripe.
    ///
    /// Context-aware partitioning (§3.1.1) works in both directions: the
    /// due span (whole intervals only) is re-chunked into
    /// `max_bins_per_job` units — *splitting* large catch-up spans into
    /// parallel jobs, and *coalescing* many small due intervals into one
    /// job when the unit is larger than the interval.
    pub fn due_windows(&self, high_water: Timestamp, now: Timestamp) -> Vec<FeatureWindow> {
        let ripe_end = self.granularity.floor(now - self.source_delay_secs);
        let start = self.granularity.floor(high_water);
        if ripe_end <= start {
            return Vec::new();
        }
        // Whole intervals only: the partial trailing interval ships with
        // the next tick.
        let whole_intervals = (ripe_end - start) / self.interval_secs;
        if whole_intervals == 0 {
            return Vec::new();
        }
        let span = FeatureWindow::new(start, start + whole_intervals * self.interval_secs);
        span.split(self.granularity, self.max_bins_per_job)
    }

    /// Partition a backfill request into job units (§4.3 "one-time
    /// backfill ... covers one feature window defined by user").
    pub fn partition_backfill(&self, window: FeatureWindow) -> Vec<FeatureWindow> {
        window.align(self.granularity).split(self.granularity, self.max_bins_per_job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::{DAY, HOUR};

    fn policy() -> SchedulePolicy {
        SchedulePolicy {
            granularity: Granularity(HOUR),
            interval_secs: DAY,
            source_delay_secs: 0,
            max_bins_per_job: 24,
        }
    }

    #[test]
    fn nothing_due_before_interval_elapses() {
        let p = policy();
        assert!(p.due_windows(0, DAY - 1).is_empty());
        assert_eq!(p.due_windows(0, DAY), vec![FeatureWindow::new(0, DAY)]);
    }

    #[test]
    fn catches_up_multiple_intervals() {
        let p = policy();
        let due = p.due_windows(0, 3 * DAY + HOUR);
        assert_eq!(due.len(), 3);
        assert_eq!(due[0], FeatureWindow::new(0, DAY));
        assert_eq!(due[2], FeatureWindow::new(2 * DAY, 3 * DAY));
        // contiguous
        for pair in due.windows(2) {
            assert_eq!(pair[0].end, pair[1].start);
        }
    }

    #[test]
    fn source_delay_defers_ripeness() {
        let mut p = policy();
        p.source_delay_secs = 2 * HOUR;
        // At now = DAY the last 2h aren't ripe → no full interval yet.
        assert!(p.due_windows(0, DAY).is_empty());
        assert_eq!(p.due_windows(0, DAY + 2 * HOUR), vec![FeatureWindow::new(0, DAY)]);
    }

    #[test]
    fn partitioning_respects_max_bins() {
        let mut p = policy();
        p.max_bins_per_job = 6;
        let due = p.due_windows(0, DAY);
        assert_eq!(due.len(), 4); // 24h / 6h-chunks
        assert!(due.iter().all(|w| w.bins(p.granularity) <= 6));
    }

    #[test]
    fn coalesces_small_intervals_into_one_job() {
        // §3.1.1 "coalescing": a large job unit absorbs many due
        // intervals into a single window.
        let mut p = policy();
        p.max_bins_per_job = 24 * 30;
        let due = p.due_windows(0, 10 * DAY);
        assert_eq!(due, vec![FeatureWindow::new(0, 10 * DAY)]);
    }

    #[test]
    fn backfill_partition_aligns_and_chunks() {
        let p = policy();
        let parts = p.partition_backfill(FeatureWindow::new(100, 3 * DAY - 100));
        assert!(parts.len() == 3);
        assert_eq!(parts[0].start, 0); // aligned down
        assert_eq!(parts.last().unwrap().end, 3 * DAY); // aligned up
    }

    #[test]
    fn high_water_respected() {
        let p = policy();
        let due = p.due_windows(2 * DAY, 4 * DAY);
        assert_eq!(due.len(), 2);
        assert_eq!(due[0].start, 2 * DAY);
    }

    #[test]
    fn from_spec_pulls_policy_fields() {
        use crate::metadata::assets::SourceSpec;
        let mut spec = FeatureSetSpec::rolling(
            "f",
            1,
            "e",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        );
        spec.source.source_delay_secs = 3 * HOUR;
        spec.materialization.max_bins_per_job = 7;
        let p = SchedulePolicy::from_spec(&spec);
        assert_eq!(p.source_delay_secs, 3 * HOUR);
        assert_eq!(p.max_bins_per_job, 7);
        assert_eq!(p.interval_secs, spec.materialization.schedule_interval_secs);
    }
}
