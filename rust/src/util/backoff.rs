//! Bounded retry with exponential backoff for transient I/O errors.
//!
//! The durable storage layer runs over real disks (and, in tests, a
//! fault-injecting filesystem), so drivers must treat a transient error
//! — `FsError::is_transient()` — as retryable rather than fatal: a GC
//! pass that hits one flaky unlink should not kill the driver thread,
//! and a fragment-roll manifest commit should ride out a momentary I/O
//! hiccup instead of leaving an oversized active fragment forever.
//!
//! This is deliberately distinct from `exec::retry`'s virtual-clock
//! scheduler retries: storage retries happen on real driver threads
//! against a real filesystem, so they sleep real wall-clock time.
//! Non-transient errors (corruption, invalid argument, overload) are
//! returned immediately — retrying them re-reads the same bad state.

use std::time::Duration;

use crate::types::Result;

/// Retry policy: at most `max_attempts` tries, sleeping `base`
/// (doubling up to `max`) between them.
#[derive(Debug, Clone)]
pub struct Backoff {
    pub max_attempts: u32,
    pub base: Duration,
    pub max: Duration,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff {
            max_attempts: 5,
            base: Duration::from_millis(10),
            max: Duration::from_millis(500),
        }
    }
}

impl Backoff {
    /// A policy that never sleeps (unit tests: deterministic, fast).
    pub fn immediate(max_attempts: u32) -> Backoff {
        Backoff { max_attempts, base: Duration::ZERO, max: Duration::ZERO }
    }
}

/// Run `op`, retrying transient failures per `policy`. Returns the
/// first success, the first non-transient error, or the last transient
/// error once attempts are exhausted.
pub fn retry<T>(policy: &Backoff, mut op: impl FnMut() -> Result<T>) -> Result<T> {
    let mut delay = policy.base;
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient() && attempt < policy.max_attempts.max(1) => {
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                delay = delay.saturating_mul(2).min(policy.max);
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::FsError;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn succeeds_after_transient_failures() {
        let calls = AtomicU32::new(0);
        let out = retry(&Backoff::immediate(5), || {
            if calls.fetch_add(1, Ordering::Relaxed) < 3 {
                Err(FsError::InjectedFault("flaky".into()))
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(calls.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn exhausts_attempts_on_persistent_transient_error() {
        let calls = AtomicU32::new(0);
        let out: Result<()> = retry(&Backoff::immediate(3), || {
            calls.fetch_add(1, Ordering::Relaxed);
            Err(FsError::InjectedFault("down".into()))
        });
        assert!(matches!(out, Err(FsError::InjectedFault(_))));
        assert_eq!(calls.load(Ordering::Relaxed), 3, "bounded, not infinite");
    }

    #[test]
    fn non_transient_errors_return_immediately() {
        let calls = AtomicU32::new(0);
        let out: Result<()> = retry(&Backoff::immediate(5), || {
            calls.fetch_add(1, Ordering::Relaxed);
            Err(FsError::Corrupt("bad magic".into()))
        });
        assert!(matches!(out, Err(FsError::Corrupt(_))));
        assert_eq!(calls.load(Ordering::Relaxed), 1, "corruption is not retried");
    }

    #[test]
    fn first_success_short_circuits() {
        let out = retry(&Backoff::immediate(5), || Ok(7));
        assert_eq!(out.unwrap(), 7);
    }
}
