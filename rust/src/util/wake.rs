//! Shared wake channel for background driver threads.
//!
//! One producer-side `ping` + one consumer-side timed `wait`, built on
//! a counter + condvar. Used by the serving batchers' `FlushDriver`
//! (`serving::batcher`), the offline store's `CompactionDriver`
//! (`offline_store::compact`) and the geo fabric's `ReplicationDriver`
//! (`geo::replication`) — one implementation, so any fix to the
//! wakeup semantics (lost-wakeup ordering, spurious-wake handling)
//! lands everywhere at once.
//!
//! The ping counter (not a boolean) is what makes the channel lossless:
//! a ping that lands while the driver is mid-tick bumps the counter, so
//! the driver's next `wait(seen, …)` returns immediately instead of
//! sleeping a full period on work that arrived just too early.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Wake channel between producers and one parked driver thread.
#[derive(Debug, Default)]
pub(crate) struct Wake {
    pings: Mutex<u64>,
    cv: Condvar,
}

impl Wake {
    /// Signal the driver (cheap; callable from any thread).
    pub(crate) fn ping(&self) {
        *self.pings.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    /// Wait until pinged past `seen` or `timeout` elapses; returns the
    /// latest ping counter (pass it back as the next `seen`).
    pub(crate) fn wait(&self, seen: u64, timeout: Duration) -> u64 {
        let mut g = self.pings.lock().unwrap();
        if *g == seen {
            let (g2, _) = self.cv.wait_timeout(g, timeout).unwrap();
            g = g2;
        }
        *g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ping_wakes_a_parked_waiter_and_counter_is_lossless() {
        let w = Arc::new(Wake::default());
        // A ping delivered before the wait is observed immediately (no
        // lost wakeup): the counter moved past `seen`.
        w.ping();
        assert_eq!(w.wait(0, Duration::from_millis(1)), 1);
        // Parked waiter is woken by a concurrent ping.
        let w2 = w.clone();
        let h = std::thread::spawn(move || w2.wait(1, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(5));
        w.ping();
        assert_eq!(h.join().unwrap(), 2);
        // Timeout path returns the unchanged counter.
        assert_eq!(w.wait(2, Duration::from_millis(1)), 2);
    }
}
