//! Deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! All randomness in the system — synthetic sources, failure injection,
//! property tests, workload generators — flows through seeded instances of
//! this generator so every test and benchmark is reproducible.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to fill the state; avoids all-zero state.
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Rejection-free (Lemire's method is overkill for
    /// our non-cryptographic uses; modulo bias at n << 2^64 is negligible,
    /// but we still use the widening-multiply trick for uniformity).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "range({lo},{hi})");
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson-distributed count (Knuth for small lambda, normal approx
    /// beyond) — used by the synthetic event source for per-bin arrivals.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Exponential inter-arrival time with the given rate.
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf-distributed index sampler over `[0, n)` with exponent `s`
/// (P(k) ∝ 1/(k+1)^s) — the skewed key-popularity model used by the load
/// harness: a handful of hot entities absorb most online lookups while the
/// long tail stays cold. `s = 0` degenerates to uniform; `s ≈ 1` is the
/// classic web/serving skew.
///
/// The CDF is precomputed once (O(n)); each sample is a binary search, so
/// the sampler is cheap enough to sit on the benchmark hot path. Sampling
/// takes `&self` — one `Zipf` can be shared across worker threads, each
/// drawing from its own forked [`Rng`].
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf over empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        Zipf { cdf }
    }

    /// Draw an index in `[0, len)`; index 0 is the hottest key.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(5);
        for &lam in &[0.5, 3.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() / lam.max(1.0) < 0.06, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(13);
        let mut a = base.fork();
        let mut b = base.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zipf_deterministic_and_in_range() {
        let z = Zipf::new(100, 1.1);
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        for _ in 0..1000 {
            let x = z.sample(&mut a);
            assert!(x < 100);
            assert_eq!(x, z.sample(&mut b));
        }
    }

    #[test]
    fn zipf_zero_exponent_is_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut r = Rng::new(4);
        let mut counts = [0u32; 10];
        let n = 50_000;
        for _ in 0..n {
            counts[z.sample(&mut r)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "i={i} frac={frac}");
        }
    }

    #[test]
    fn zipf_head_dominates_at_unit_exponent() {
        let z = Zipf::new(1000, 1.0);
        let mut r = Rng::new(17);
        let n = 50_000;
        let mut head = 0u32; // draws landing in the top 1% of keys
        let mut first = 0u32;
        for _ in 0..n {
            let x = z.sample(&mut r);
            if x < 10 {
                head += 1;
            }
            if x == 0 {
                first += 1;
            }
        }
        // For n=1000, s=1: P(top 10) = H(10)/H(1000) ≈ 2.93/7.49 ≈ 0.39,
        // vs 1% under uniform. P(0) ≈ 0.134.
        assert!(head as f64 / n as f64 > 0.30, "head={head}");
        assert!(first as f64 / n as f64 > 0.10, "first={first}");
    }
}
