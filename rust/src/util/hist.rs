//! Log-bucketed latency histogram (HdrHistogram-style, fixed memory).
//!
//! Used by the serving metrics (§2.1 "Enterprise grade SLAs") and the
//! bench harness for percentile reporting.  Buckets are
//! log2-major/linear-minor: 64 sub-buckets per power of two gives ≤ ~1.6%
//! relative quantile error over the full u64 nanosecond range.

const SUB_BITS: u32 = 6; // 64 linear sub-buckets per octave
const SUB: usize = 1 << SUB_BITS;

/// Total bucket count — exposed so the lock-free metrics core can keep
/// per-thread-striped `AtomicU64` bucket arrays that mirror this layout
/// and fold them back into a `Histogram` for reads.
pub(crate) const BUCKETS: usize = 64 * SUB;

#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 64 octaves × SUB sub-buckets covers all of u64.
        Histogram { counts: vec![0; 64 * SUB], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> (msb - SUB_BITS)) as usize & (SUB - 1);
        // octave 0 handled above; shift so the table is contiguous.
        octave * SUB + sub
    }

    /// Lower edge of bucket `i` (the value we report for quantiles —
    /// a ≤ 1/64 under-estimate, consistent with HdrHistogram's convention).
    fn bucket_value(i: usize) -> u64 {
        let octave = i / SUB;
        let sub = i % SUB;
        if octave == 0 {
            return sub as u64;
        }
        let msb = octave as u32 + SUB_BITS - 1;
        (1u64 << msb) | ((sub as u64) << (msb - SUB_BITS))
    }

    /// Bucket index for value `v` — the same mapping `record` uses,
    /// exposed for the atomic mirror in `monitor/metrics.rs`.
    #[inline]
    pub(crate) fn index_of(v: u64) -> usize {
        Self::index(v)
    }

    /// Rebuild a histogram from raw bucket counts (the fold step of the
    /// striped atomic histograms). `counts` must use the `index_of`
    /// layout and have exactly [`BUCKETS`] entries.
    pub(crate) fn from_parts(counts: Vec<u64>, sum: u128, min: u64, max: u64) -> Histogram {
        debug_assert_eq!(counts.len(), BUCKETS);
        let total: u64 = counts.iter().sum();
        Histogram {
            counts,
            total,
            sum,
            min: if total == 0 { u64::MAX } else { min },
            max,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Exact sum of all recorded values (for Prometheus `_sum` export).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in [0,1]; returns the lower edge of the containing bucket
    /// (clamped to observed min/max so p0/p100 are exact).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// "p50=.. p95=.. p99=.. max=.." one-liner for logs/benches, in the
    /// given unit divisor (e.g. 1_000 for ns→µs).
    pub fn summary(&self, div: f64, unit: &str) -> String {
        format!(
            "n={} mean={:.1}{u} p50={:.1}{u} p95={:.1}{u} p99={:.1}{u} max={:.1}{u}",
            self.total,
            self.mean() / div,
            self.quantile(0.50) as f64 / div,
            self.quantile(0.95) as f64 / div,
            self.quantile(0.99) as f64 / div,
            self.max as f64 / div,
            u = unit,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_small_values() {
        let mut h = Histogram::new();
        for v in 0..SUB as u64 {
            h.record(v);
        }
        // Values below SUB are exact buckets.
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), SUB as u64 - 1);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB as u64 - 1);
    }

    #[test]
    fn quantile_relative_error_bounded() {
        let mut h = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(1);
        let mut vals: Vec<u64> = (0..100_000).map(|_| rng.below(10_000_000) + 1).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort();
        for &q in &[0.5, 0.9, 0.99, 0.999] {
            let want = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)] as f64;
            let got = h.quantile(q) as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.04, "q={q} want={want} got={got} rel={rel}");
        }
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut rng = crate::util::rng::Rng::new(2);
        for i in 0..10_000 {
            let v = rng.below(1 << 40);
            c.record(v);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.99), c.quantile(0.99));
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn huge_values_do_not_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) >= h.quantile(0.0));
    }
}
