//! Minimal JSON parser + writer.
//!
//! `serde`/`serde_json` are not available in this build environment (only
//! the `xla` crate's offline closure is vendored), so the feature store
//! carries its own small, well-tested JSON implementation.  It is used for
//! the artifact manifest written by `python/compile/aot.py`, for config
//! files, and for metadata snapshots — none of which are on the request
//! hot path.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (snapshot files diff cleanly).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access; returns `Json::Null` for missing keys or
    /// non-objects, which composes with the other accessors.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(o) => {
                f.write_str("{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our files;
                            // map unpaired surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":"v"},"n":-3}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn roundtrips_escaped_strings() {
        let v = Json::Str("line\nwith \"quotes\" \\ and \u{0001}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": 1, "dtype": "f32",
          "artifacts": [{"name": "small_dsl", "entities": 16,
                         "time_bins": 32, "window": 4,
                         "outputs": ["sum","cnt","mean","min","max"]}]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").as_i64(), Some(1));
        let a = &v.get("artifacts").as_arr().unwrap()[0];
        assert_eq!(a.get("entities").as_usize(), Some(16));
        assert_eq!(a.get("outputs").as_arr().unwrap().len(), 5);
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(Json::Num(1.0).get("x"), &Json::Null);
        assert_eq!(Json::Null.get("x").as_str(), None);
    }
}
