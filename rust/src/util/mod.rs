//! Hand-built substrates: JSON, PRNG, histograms, logging, clock.
//!
//! Nothing beyond the `xla` crate's dependency closure is available in
//! this build environment, so the usual ecosystem crates (serde, rand,
//! hdrhistogram, env_logger) are replaced by these small in-tree
//! implementations (see DESIGN.md §5).

pub mod backoff;
pub mod hist;
pub mod json;
pub mod rng;
pub(crate) mod wake;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Simple stderr logger wired into the `log` facade.
struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }
    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5} {}] {}", record.level(), record.target(), record.args());
        }
    }
    fn flush(&self) {}
}

/// Install the stderr logger once. Level from `GEOFS_LOG`
/// (error|warn|info|debug|trace), default `info`.
pub fn init_logging() {
    static LOGGER: StderrLogger = StderrLogger;
    let level = match std::env::var("GEOFS_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

/// A logical clock shared across the system.
///
/// The feature store reasons about two timelines (paper §4.5.1): the
/// *event* timeline (timestamps in the data) and the *processing*
/// timeline (creation timestamps, schedules, TTLs).  Tests and the geo
/// simulator need to drive the processing timeline deterministically, so
/// every subsystem takes a `Clock` instead of calling the OS.
#[derive(Debug, Clone)]
pub struct Clock(Arc<AtomicU64>);

impl Clock {
    /// A clock starting at the given epoch-seconds value; advanced manually.
    pub fn fixed(start: i64) -> Clock {
        Clock(Arc::new(AtomicU64::new(start as u64)))
    }

    /// Current time, epoch seconds.
    pub fn now(&self) -> i64 {
        self.0.load(Ordering::SeqCst) as i64
    }

    /// Advance by `secs` and return the new now.
    pub fn advance(&self, secs: i64) -> i64 {
        (self.0.fetch_add(secs as u64, Ordering::SeqCst) as i64) + secs
    }

    /// Set an absolute time (monotonicity is the caller's concern).
    pub fn set(&self, t: i64) {
        self.0.store(t as u64, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let c = Clock::fixed(1_000);
        assert_eq!(c.now(), 1_000);
        assert_eq!(c.advance(60), 1_060);
        assert_eq!(c.now(), 1_060);
        c.set(5);
        assert_eq!(c.now(), 5);
    }

    #[test]
    fn clock_is_shared() {
        let a = Clock::fixed(0);
        let b = a.clone();
        a.advance(10);
        assert_eq!(b.now(), 10);
    }
}
