//! Query subsystem: point-in-time correct feature retrieval (§4.4).
//!
//! * [`pit`] — the leakage-prevention rule: for an observation at time
//!   `ts₀`, return feature values strictly from the past of `ts₀`,
//!   nearest-past first, honoring the expected source/feature delay.
//!   Hosts the linear-scan [`pit::pit_lookup`] oracle and the
//!   [`pit::PitIndex`] baseline retained for differential tests.
//! * [`offline`] — the offline (training) engine: a streaming
//!   merge-join of the entity-sorted spine against the offline store's
//!   sorted columnar segments, fanned out per table / per entity chunk
//!   over the shared thread pool, assembling a columnar
//!   [`offline::TrainingFrame`]. No per-query index build, no
//!   full-table record clones.
//! * [`spec`] — feature retrieval specs (`featureset:version:feature`).

pub mod offline;
pub mod pit;
pub mod spec;

pub use offline::OfflineQueryEngine;
pub use pit::{pit_lookup, Observation, PitConfig};
pub use spec::FeatureRef;
