//! Query subsystem: point-in-time correct feature retrieval (§4.4).
//!
//! * [`pit`] — the leakage-prevention join: for an observation at time
//!   `ts₀`, return feature values strictly from the past of `ts₀`,
//!   nearest-past first, honoring the expected source/feature delay.
//! * [`offline`] — offline (training) retrieval over the offline store,
//!   including on-the-fly calculation for unmaterialized feature sets.
//! * [`spec`] — feature retrieval specs (`featureset:version:feature`).

pub mod offline;
pub mod pit;
pub mod spec;

pub use offline::OfflineQueryEngine;
pub use pit::{pit_lookup, Observation, PitConfig};
pub use spec::FeatureRef;
