//! Point-in-time (PIT) correct lookup — the data-leakage guard (§4.4).
//!
//! Given an observation event at `ts₀`, the query subsystem must:
//!
//! 1. only look at feature values from the **past** of `ts₀` — with the
//!    end-of-bin `event_ts` convention (§4.5.1) a record with
//!    `event_ts == ts₀` aggregates strictly-past data and is admissible
//!    (excluding it would *create* train/serve skew, since the online
//!    store serves exactly that record at `ts₀`), and
//! 2. pick the value from the **nearest past**, while considering the
//!    expected delay of source and feature data.
//!
//! "Considering the expected delay" means: a feature record only counts
//! as *available* at `ts₀` if it had already been materialized by then —
//! `creation_ts ≤ ts₀ − availability_slack`.  Without this, training
//! would use values that online inference could not have seen yet
//! (training/serving skew), even though they are "from the past" on the
//! event timeline.

use std::collections::HashMap;

use crate::types::{EntityId, FeatureRecord, Timestamp};

/// One observation row of the spine dataframe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub entity: EntityId,
    pub ts: Timestamp,
}

/// PIT join configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct PitConfig {
    /// Extra slack on record availability: a record is usable at `ts₀`
    /// only if `creation_ts + availability_slack ≤ ts₀`. Models serving
    /// pipeline propagation delay.
    pub availability_slack: i64,
    /// Maximum lookback: a feature older than `ts₀ − max_staleness` is
    /// not returned (0 = unlimited). Mirrors online TTL so training
    /// matches what serving would produce.
    pub max_staleness: i64,
}

/// Index of feature records by entity, sorted by event timestamp, for
/// repeated PIT lookups over the same table scan.
#[derive(Debug, Default)]
pub struct PitIndex {
    by_entity: HashMap<EntityId, Vec<FeatureRecord>>,
}

impl PitIndex {
    /// Build from a record scan. Records are sorted per entity by
    /// `(event_ts, creation_ts)`.
    pub fn build(records: impl IntoIterator<Item = FeatureRecord>) -> Self {
        let mut by_entity: HashMap<EntityId, Vec<FeatureRecord>> = HashMap::new();
        for r in records {
            by_entity.entry(r.entity).or_default().push(r);
        }
        for v in by_entity.values_mut() {
            v.sort_by_key(|r| (r.event_ts, r.creation_ts));
        }
        PitIndex { by_entity }
    }

    pub fn len(&self) -> usize {
        self.by_entity.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.by_entity.is_empty()
    }

    /// The PIT lookup for one observation.
    pub fn lookup(&self, obs: Observation, cfg: PitConfig) -> Option<&FeatureRecord> {
        let rows = self.by_entity.get(&obs.entity)?;
        pit_walk(rows, |r| (r.event_ts, r.creation_ts), obs.ts, cfg).map(|i| &rows[i])
    }
}

/// The core §4.4 walk over one entity's rows sorted by
/// `(event_ts, creation_ts)`: binary-search the first event past `ts`
/// (inclusive-end semantics), then walk event timestamps leftward,
/// preferring the *latest available* creation version within each event
/// and stopping at the staleness horizon. Returns the winning row index.
///
/// Shared by [`PitIndex::lookup`] and the offline engine's merge-join
/// candidate resolution, so the leakage-guard rule has exactly one
/// implementation. The merge-join feeds it `(event_ts, creation_ts)`
/// tuples lifted out of compressed segments by lazy cursors — the walk
/// itself never touches storage, which is what keeps the rule reusable
/// across the raw-record oracle and the compressed engine.
pub(crate) fn pit_walk<K>(
    rows: &[K],
    key: impl Fn(&K) -> (Timestamp, Timestamp),
    ts: Timestamp,
    cfg: PitConfig,
) -> Option<usize> {
    let mut idx = rows.partition_point(|r| key(r).0 <= ts);
    while idx > 0 {
        idx -= 1;
        let candidate_event = key(&rows[idx]).0;
        if cfg.max_staleness > 0 && candidate_event < ts - cfg.max_staleness {
            return None; // everything further left is older still
        }
        // Scan the run of rows sharing this event_ts (sorted by
        // creation_ts ascending) from newest creation down.
        let run_start = rows[..idx + 1].partition_point(|r| key(r).0 < candidate_event);
        let mut j = idx;
        loop {
            if key(&rows[j]).1 + cfg.availability_slack <= ts {
                return Some(j);
            }
            if j == run_start {
                break;
            }
            j -= 1;
        }
        // No version of this event_ts was available at ts; try the
        // previous event_ts.
        idx = run_start;
    }
    None
}

/// Convenience: single lookup without a prebuilt index.
pub fn pit_lookup<'a>(
    records: &'a [FeatureRecord],
    obs: Observation,
    cfg: PitConfig,
) -> Option<FeatureRecord> {
    // Linear scan variant (used by tests as an oracle and by one-off
    // queries): latest (event_ts, creation_ts) among available records
    // strictly in the past.
    records
        .iter()
        .filter(|r| r.entity == obs.entity)
        .filter(|r| r.event_ts <= obs.ts)
        .filter(|r| r.creation_ts + cfg.availability_slack <= obs.ts)
        .filter(|r| cfg.max_staleness == 0 || r.event_ts >= obs.ts - cfg.max_staleness)
        .max_by_key(|r| (r.event_ts, r.creation_ts))
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn obs(entity: u64, ts: Timestamp) -> Observation {
        Observation { entity, ts }
    }

    #[test]
    fn never_reads_future() {
        let idx = PitIndex::build([rec(1, 100, 110, 1.0), rec(1, 200, 210, 2.0)]);
        let cfg = PitConfig::default();
        // Observation between the two events sees only the first.
        assert_eq!(idx.lookup(obs(1, 150), cfg).unwrap().values[0], 1.0);
        // Exactly at an event_ts the record is admissible (it aggregates
        // strictly-past data) — but this one was only created at 210, so
        // the availability guard still hides it.
        assert_eq!(idx.lookup(obs(1, 200), cfg).unwrap().values[0], 1.0);
        assert_eq!(idx.lookup(obs(1, 205), cfg).unwrap().values[0], 1.0);
        // Once created, the event-200 record serves from ts >= 210.
        assert_eq!(idx.lookup(obs(1, 210), cfg).unwrap().values[0], 2.0);
        // Before everything: no value (event 100 exists but its creation
        // at 110 is after the observation).
        assert!(idx.lookup(obs(1, 100), cfg).is_none());
        // Strictly before the first event: nothing to see.
        assert!(idx.lookup(obs(1, 99), cfg).is_none());
    }

    #[test]
    fn respects_creation_availability() {
        // Event at 100 materialized late (creation 180): an observation at
        // 150 must NOT see it — inference at 150 couldn't have.
        let idx = PitIndex::build([rec(1, 100, 180, 1.0)]);
        let cfg = PitConfig::default();
        assert!(idx.lookup(obs(1, 150), cfg).is_none());
        assert_eq!(idx.lookup(obs(1, 180), cfg).unwrap().values[0], 1.0);
    }

    #[test]
    fn prefers_latest_available_version_of_same_event() {
        // Two versions of event 100: original (created 110) and a late
        // recompute (created 300).
        let idx = PitIndex::build([rec(1, 100, 110, 1.0), rec(1, 100, 300, 2.0)]);
        let cfg = PitConfig::default();
        // At 200 only the original is available.
        assert_eq!(idx.lookup(obs(1, 200), cfg).unwrap().values[0], 1.0);
        // At 400 the recompute is preferred (nearest past = same event,
        // newest available version).
        assert_eq!(idx.lookup(obs(1, 400), cfg).unwrap().values[0], 2.0);
    }

    #[test]
    fn falls_back_to_older_event_when_newer_unavailable() {
        let idx = PitIndex::build([rec(1, 100, 110, 1.0), rec(1, 200, 500, 2.0)]);
        let cfg = PitConfig::default();
        // At 300 the event-200 record isn't materialized yet → use event 100.
        assert_eq!(idx.lookup(obs(1, 300), cfg).unwrap().values[0], 1.0);
        assert_eq!(idx.lookup(obs(1, 500), cfg).unwrap().values[0], 2.0);
    }

    #[test]
    fn availability_slack_models_serving_delay() {
        let idx = PitIndex::build([rec(1, 100, 110, 1.0)]);
        let cfg = PitConfig { availability_slack: 50, ..Default::default() };
        assert!(idx.lookup(obs(1, 150), cfg).is_none()); // 110+50 > 150
        assert_eq!(idx.lookup(obs(1, 160), cfg).unwrap().values[0], 1.0);
    }

    #[test]
    fn max_staleness_mirrors_ttl() {
        let idx = PitIndex::build([rec(1, 100, 110, 1.0)]);
        let cfg = PitConfig { max_staleness: 200, ..Default::default() };
        assert!(idx.lookup(obs(1, 250), cfg).is_some());
        assert!(idx.lookup(obs(1, 301), cfg).is_none()); // 100 < 301-200
    }

    #[test]
    fn entities_isolated() {
        let idx = PitIndex::build([rec(1, 100, 110, 1.0)]);
        assert!(idx.lookup(obs(2, 999), PitConfig::default()).is_none());
    }

    #[test]
    fn index_agrees_with_linear_oracle() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut records = Vec::new();
        for _ in 0..400 {
            let e = rng.below(5);
            let event = rng.range(0, 1_000);
            let created = event + rng.range(1, 200);
            records.push(rec(e, event, created, rng.f32()));
        }
        let idx = PitIndex::build(records.clone());
        for trial in 0..500 {
            let o = obs(rng.below(6), rng.range(0, 1_400));
            for cfg in [
                PitConfig::default(),
                PitConfig { availability_slack: 37, max_staleness: 0 },
                PitConfig { availability_slack: 0, max_staleness: 150 },
                PitConfig { availability_slack: 20, max_staleness: 300 },
            ] {
                let fast = idx.lookup(o, cfg).cloned();
                let slow = pit_lookup(&records, o, cfg);
                assert_eq!(fast, slow, "trial {trial} obs {o:?} cfg {cfg:?}");
            }
        }
    }
}
