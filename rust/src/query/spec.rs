//! Feature references: `featureset:version:feature` strings used by
//! retrieval specs and model lineage (the paper's "features used in a
//! model" tracking).

use crate::metadata::assets::FeatureSetSpec;
use crate::types::{FsError, Result};

/// A fully-qualified reference to one feature column.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FeatureRef {
    pub feature_set: String,
    pub version: u32,
    pub feature: String,
}

impl FeatureRef {
    pub fn parse(s: &str) -> Result<FeatureRef> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 || parts.iter().any(|p| p.is_empty()) {
            return Err(FsError::InvalidArg(format!(
                "bad feature ref '{s}' (want featureset:version:feature)"
            )));
        }
        let version: u32 = parts[1]
            .parse()
            .map_err(|_| FsError::InvalidArg(format!("bad version in feature ref '{s}'")))?;
        Ok(FeatureRef {
            feature_set: parts[0].to_string(),
            version,
            feature: parts[2].to_string(),
        })
    }

    /// The table key under which this feature set materializes.
    pub fn table(&self) -> String {
        format!("{}:{}", self.feature_set, self.version)
    }

    /// Index of the feature column within the feature-set schema.
    pub fn column_index(&self, spec: &FeatureSetSpec) -> Result<usize> {
        spec.feature_names
            .iter()
            .position(|f| *f == self.feature)
            .ok_or_else(|| {
                FsError::NotFound(format!(
                    "feature '{}' in feature set '{}' (has: {})",
                    self.feature,
                    spec.reference(),
                    spec.feature_names.join(", ")
                ))
            })
    }
}

impl std::fmt::Display for FeatureRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}", self.feature_set, self.version, self.feature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::SourceSpec;
    use crate::types::time::Granularity;

    #[test]
    fn parse_roundtrip() {
        let r = FeatureRef::parse("txn_30d:2:720h_sum").unwrap();
        assert_eq!(r.feature_set, "txn_30d");
        assert_eq!(r.version, 2);
        assert_eq!(r.feature, "720h_sum");
        assert_eq!(r.to_string(), "txn_30d:2:720h_sum");
        assert_eq!(r.table(), "txn_30d:2");
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "a:b", "a:1:b:c", "a::b", "a:x:b", ":1:b"] {
            assert!(FeatureRef::parse(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn column_index_resolves() {
        let spec = FeatureSetSpec::rolling(
            "txn_30d",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        );
        let r = FeatureRef::parse("txn_30d:1:720h_mean").unwrap();
        assert_eq!(r.column_index(&spec).unwrap(), 2);
        let missing = FeatureRef::parse("txn_30d:1:nope").unwrap();
        assert!(missing.column_index(&spec).is_err());
    }
}
