//! Offline (training) retrieval: PIT-join a spine of observations
//! against one or more feature sets from the offline store (§2.1
//! "Offline feature retrieval to support point-in-time joins with high
//! data throughput").

use std::collections::HashMap;
use std::sync::Arc;

use super::pit::{Observation, PitConfig, PitIndex};
use super::spec::FeatureRef;
use crate::metadata::assets::FeatureSetSpec;
use crate::offline_store::OfflineStore;
use crate::types::{FeatureWindow, FsError, Result, Timestamp};

/// A training dataframe: one row per observation, one column per
/// requested feature (None = no PIT-valid value).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingFrame {
    pub columns: Vec<String>,
    pub rows: Vec<TrainingRow>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TrainingRow {
    pub observation: Observation,
    pub features: Vec<Option<f32>>,
}

impl TrainingFrame {
    /// Fraction of cells that resolved to a value.
    pub fn fill_rate(&self) -> f64 {
        let total = self.rows.len() * self.columns.len();
        if total == 0 {
            return 0.0;
        }
        let filled: usize =
            self.rows.iter().map(|r| r.features.iter().filter(|f| f.is_some()).count()).sum();
        filled as f64 / total as f64
    }
}

/// Offline query engine bound to an offline store.
pub struct OfflineQueryEngine {
    store: Arc<OfflineStore>,
}

impl OfflineQueryEngine {
    pub fn new(store: Arc<OfflineStore>) -> Self {
        OfflineQueryEngine { store }
    }

    /// PIT-join `observations` against `features`. Each feature ref must
    /// resolve in `specs` (keyed by feature-set name). The scan window is
    /// derived from the observation span plus each set's max staleness.
    pub fn get_training_frame(
        &self,
        observations: &[Observation],
        features: &[FeatureRef],
        specs: &HashMap<String, FeatureSetSpec>,
        cfg: PitConfig,
    ) -> Result<TrainingFrame> {
        if observations.is_empty() {
            return Ok(TrainingFrame {
                columns: features.iter().map(|f| f.to_string()).collect(),
                rows: Vec::new(),
            });
        }
        let obs_min = observations.iter().map(|o| o.ts).min().unwrap();
        let obs_max = observations.iter().map(|o| o.ts).max().unwrap();

        // Group feature refs per feature-set table so each table is
        // scanned + indexed once (high-throughput path).
        let mut per_table: HashMap<String, Vec<(usize, FeatureRef)>> = HashMap::new();
        for (col, f) in features.iter().enumerate() {
            per_table.entry(f.table()).or_default().push((col, f.clone()));
        }

        let mut rows: Vec<TrainingRow> = observations
            .iter()
            .map(|&observation| TrainingRow {
                observation,
                features: vec![None; features.len()],
            })
            .collect();

        for (table, refs) in per_table {
            let spec = specs.get(&refs[0].1.feature_set).ok_or_else(|| {
                FsError::NotFound(format!("feature set spec '{}'", refs[0].1.feature_set))
            })?;
            // Column indices resolved against the schema once per table.
            let cols: Vec<(usize, usize)> = refs
                .iter()
                .map(|(col, f)| f.column_index(spec).map(|ci| (*col, ci)))
                .collect::<Result<_>>()?;

            // Scan window: far enough back that any record usable by the
            // earliest observation is included.
            let lookback = if cfg.max_staleness > 0 {
                cfg.max_staleness
            } else {
                // Unlimited staleness: scan from the table's own start.
                let table_start = self
                    .store
                    .event_range(&table)
                    .map(|(lo, _)| obs_min - lo)
                    .unwrap_or(0)
                    .max(0);
                table_start + spec.granularity.secs()
            };
            let window = FeatureWindow::new(obs_min - lookback, obs_max + 1);
            // Index only entities the spine actually references — for a
            // small spine over a large table this skips most of the scan
            // (EXPERIMENTS.md §Perf L3).
            let wanted: std::collections::HashSet<_> =
                observations.iter().map(|o| o.entity).collect();
            let index = PitIndex::build(
                self.store
                    .scan(&table, window)
                    .into_iter()
                    .filter(|r| wanted.contains(&r.entity)),
            );

            for row in rows.iter_mut() {
                if let Some(rec) = index.lookup(row.observation, cfg) {
                    for &(col, ci) in &cols {
                        row.features[col] = rec.values.get(ci).copied();
                    }
                }
            }
        }

        Ok(TrainingFrame {
            columns: features.iter().map(|f| f.to_string()).collect(),
            rows,
        })
    }

    /// Was the window fully materialized when read? The caller combines
    /// this with the scheduler's data-state to distinguish "no data" from
    /// "not materialized" (§4.3).
    pub fn store(&self) -> &Arc<OfflineStore> {
        &self.store
    }
}

/// Naive full-scan join baseline (per-observation linear scan) — the
/// comparator for `benches/pit_join.rs` (experiment E4).
pub fn naive_training_frame(
    store: &OfflineStore,
    observations: &[Observation],
    features: &[FeatureRef],
    specs: &HashMap<String, FeatureSetSpec>,
    cfg: PitConfig,
) -> Result<TrainingFrame> {
    let mut rows = Vec::with_capacity(observations.len());
    for &observation in observations {
        let mut feats = vec![None; features.len()];
        for (col, f) in features.iter().enumerate() {
            let spec = specs
                .get(&f.feature_set)
                .ok_or_else(|| FsError::NotFound(format!("spec '{}'", f.feature_set)))?;
            let ci = f.column_index(spec)?;
            let all = store.scan(&f.table(), scan_all_window(store, &f.table(), observation.ts));
            if let Some(rec) = super::pit::pit_lookup(&all, observation, cfg) {
                feats[col] = rec.values.get(ci).copied();
            }
        }
        rows.push(TrainingRow { observation, features: feats });
    }
    Ok(TrainingFrame { columns: features.iter().map(|f| f.to_string()).collect(), rows })
}

fn scan_all_window(store: &OfflineStore, table: &str, until: Timestamp) -> FeatureWindow {
    let lo = store.event_range(table).map(|(lo, _)| lo).unwrap_or(0).min(until - 1);
    FeatureWindow::new(lo, until)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::SourceSpec;
    use crate::types::time::{Granularity, DAY};
    use crate::types::FeatureRecord;

    fn setup() -> (OfflineQueryEngine, HashMap<String, FeatureSetSpec>) {
        let store = Arc::new(OfflineStore::new());
        let spec = FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        );
        // Two entities, two days of records; entity 1 gets a late
        // recompute for day 1.
        store.merge(
            "txn:1",
            &[
                FeatureRecord::new(1, DAY, DAY + 100, vec![10.0, 1.0, 10.0, 10.0, 10.0]),
                FeatureRecord::new(1, 2 * DAY, 2 * DAY + 100, vec![20.0, 2.0, 10.0, 5.0, 15.0]),
                FeatureRecord::new(1, DAY, 3 * DAY, vec![11.0, 1.0, 11.0, 11.0, 11.0]),
                FeatureRecord::new(2, DAY, DAY + 100, vec![7.0, 1.0, 7.0, 7.0, 7.0]),
            ],
        );
        let mut specs = HashMap::new();
        specs.insert("txn".to_string(), spec);
        (OfflineQueryEngine::new(store), specs)
    }

    fn refs(names: &[&str]) -> Vec<FeatureRef> {
        names.iter().map(|n| FeatureRef::parse(&format!("txn:1:{n}")).unwrap()).collect()
    }

    #[test]
    fn joins_pit_correct_values() {
        let (q, specs) = setup();
        let obs = vec![
            Observation { entity: 1, ts: DAY + 200 },     // sees day-1 original
            Observation { entity: 1, ts: 2 * DAY + 200 }, // sees day-2
            Observation { entity: 2, ts: DAY + 50 },      // created later → none
            Observation { entity: 3, ts: 5 * DAY },       // unknown entity
        ];
        let frame = q
            .get_training_frame(&obs, &refs(&["720h_sum", "720h_cnt"]), &specs, PitConfig::default())
            .unwrap();
        assert_eq!(frame.columns.len(), 2);
        assert_eq!(frame.rows[0].features[0], Some(10.0));
        assert_eq!(frame.rows[1].features[0], Some(20.0));
        assert_eq!(frame.rows[1].features[1], Some(2.0));
        assert_eq!(frame.rows[2].features[0], None); // availability guard
        assert_eq!(frame.rows[3].features[0], None);
        assert!((frame.fill_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn matches_naive_baseline() {
        let (q, specs) = setup();
        let features = refs(&["720h_sum", "720h_max"]);
        let obs: Vec<Observation> = (0..40)
            .map(|i| Observation { entity: 1 + (i % 3), ts: DAY / 2 + i as i64 * 6_000 })
            .collect();
        for cfg in [
            PitConfig::default(),
            PitConfig { availability_slack: 500, max_staleness: 0 },
            PitConfig { availability_slack: 0, max_staleness: 2 * DAY },
        ] {
            let fast = q.get_training_frame(&obs, &features, &specs, cfg).unwrap();
            let slow = naive_training_frame(q.store(), &obs, &features, &specs, cfg).unwrap();
            assert_eq!(fast, slow, "cfg {cfg:?}");
        }
    }

    #[test]
    fn empty_observations_ok() {
        let (q, specs) = setup();
        let frame = q
            .get_training_frame(&[], &refs(&["720h_sum"]), &specs, PitConfig::default())
            .unwrap();
        assert!(frame.rows.is_empty());
        assert_eq!(frame.fill_rate(), 0.0);
    }

    #[test]
    fn missing_spec_or_feature_errors() {
        let (q, specs) = setup();
        let obs = vec![Observation { entity: 1, ts: DAY }];
        let bad_set = vec![FeatureRef::parse("other:1:x").unwrap()];
        assert!(q.get_training_frame(&obs, &bad_set, &specs, PitConfig::default()).is_err());
        let bad_feature = vec![FeatureRef::parse("txn:1:missing").unwrap()];
        assert!(q
            .get_training_frame(&obs, &bad_feature, &specs, PitConfig::default())
            .is_err());
    }
}
