//! Offline (training) retrieval: PIT-join a spine of observations
//! against one or more feature sets from the offline store (§2.1
//! "Offline feature retrieval to support point-in-time joins with high
//! data throughput").
//!
//! # The streaming merge-join (PR 2 rebuild)
//!
//! The engine no longer scans the table into a `Vec<FeatureRecord>` and
//! builds a hash-of-sorted-vectors index per query. Instead:
//!
//! 1. The spine is sorted once by `(entity, ts)` — the same order the
//!    offline store's columnar segments are sorted in.
//! 2. Each table contributes an [`OfflineStore::snapshot`]: `Arc`-shared
//!    sorted **compressed** segments, read through per-segment
//!    [`SegmentCursor`]s (PR 4): the entity-run binary search goes
//!    through each segment's block directory and decodes exactly the
//!    blocks a run touches — full key planes are never materialized.
//!    For each spine entity, the engine binary-searches each segment's
//!    **entity run** (advancing a per-segment position, since spine
//!    entities ascend) and k-way-merges the runs into one
//!    `(event_ts, creation_ts)`-sorted candidate list — a merge of
//!    presorted runs, not a sort, touching only spine entities inside
//!    the scan window.
//! 3. Each observation resolves against that candidate list with the
//!    §4.4 PIT rule (nearest past, latest available version, staleness
//!    and availability-slack guards). Only the winning row's requested
//!    value columns are copied into the frame — value planes are read
//!    in place.
//! 4. Per-table (and, for large spines, per-entity-chunk) joins fan out
//!    over the shared [`ThreadPool`]; results scatter into a columnar
//!    [`TrainingFrame`].
//!
//! The naive per-observation full-scan join ([`naive_training_frame`])
//! is retained verbatim as the differential-test oracle and the bench
//! baseline (experiment E4).

use std::collections::HashMap;
use std::sync::Arc;

use super::pit::{Observation, PitConfig};
use super::spec::FeatureRef;
use crate::exec::ThreadPool;
use crate::metadata::assets::FeatureSetSpec;
use crate::monitor::trace::TraceContext;
use crate::offline_store::{OfflineStore, Segment, SegmentCursor};
use crate::types::{EntityId, FeatureWindow, FsError, Result, Timestamp};

/// A training dataframe in columnar layout: one entry per observation
/// per requested feature (`None` = no PIT-valid value). Cells live in
/// one column-major buffer — `data[col * len() + row]` — matching the
/// columnar store the frame is assembled from.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingFrame {
    pub columns: Vec<String>,
    pub observations: Vec<Observation>,
    /// Column-major cells: `data[col * observations.len() + row]`.
    pub data: Vec<Option<f32>>,
}

/// One materialized row (a gather over the columnar buffer) — kept for
/// row-oriented consumers (model trainers, examples).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingRow {
    pub observation: Observation,
    pub features: Vec<Option<f32>>,
}

impl TrainingFrame {
    /// Number of observation rows.
    pub fn len(&self) -> usize {
        self.observations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.observations.is_empty()
    }

    /// One cell.
    pub fn value(&self, row: usize, col: usize) -> Option<f32> {
        self.data[col * self.len() + row]
    }

    /// One whole feature column, contiguous.
    pub fn column(&self, col: usize) -> &[Option<f32>] {
        &self.data[col * self.len()..(col + 1) * self.len()]
    }

    /// Row-oriented iteration (gathers across columns per row).
    pub fn rows(&self) -> impl Iterator<Item = TrainingRow> + '_ {
        (0..self.len()).map(move |i| TrainingRow {
            observation: self.observations[i],
            features: (0..self.columns.len()).map(|c| self.value(i, c)).collect(),
        })
    }

    /// Fraction of cells that resolved to a value.
    pub fn fill_rate(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let filled = self.data.iter().filter(|c| c.is_some()).count();
        filled as f64 / self.data.len() as f64
    }
}

/// One merge-join candidate: `(event_ts, creation_ts, segment, row)`.
/// Rows never leave the segment — the tuple is the only per-candidate
/// allocation, and values are read in place on resolution.
type Candidate = (Timestamp, Timestamp, u32, u32);

/// The §4.4 PIT rule over an `(event_ts, creation_ts)`-sorted candidate
/// list — delegates to the single shared [`super::pit::pit_walk`]
/// implementation also used by `PitIndex::lookup` (the differential
/// tests in `tests/offline_stress.rs` pin the equivalence against the
/// linear `pit_lookup` oracle).
fn pit_pick(rows: &[Candidate], ts: Timestamp, cfg: PitConfig) -> Option<usize> {
    super::pit::pit_walk(rows, |r| (r.0, r.1), ts, cfg)
}

/// Per-task pruning tallies for the sampled `join_task` trace event:
/// how many per-entity segment probes each pruning stage cut off before
/// any block was decoded, and how many candidate rows survived into the
/// k-way merge.
#[derive(Default)]
struct JoinStats {
    /// Probes rejected by the segment's entity bloom filter.
    bloom_pruned: u64,
    /// Probes rejected by the segment's event-window zone bounds.
    window_pruned: u64,
    /// Candidate rows k-way-merged across all entities of the span.
    rows_merged: u64,
}

/// Gather `entity`'s rows (within `window`) from every segment and
/// k-way-merge the presorted runs into `out`, sorted by
/// `(event_ts, creation_ts)`. `positions` are per-segment forward-only
/// run positions (valid because callers probe entities in ascending
/// order); `readers` are the per-segment lazy-decode cursors — each
/// holds one decoded block, so an ascending probe sequence streams
/// block to block instead of materializing key planes.
fn collect_candidates(
    segs: &[Arc<Segment>],
    readers: &mut [SegmentCursor<'_>],
    positions: &mut [usize],
    entity: EntityId,
    window: FeatureWindow,
    heads: &mut Vec<(usize, usize, usize)>,
    out: &mut Vec<Candidate>,
    stats: &mut JoinStats,
) {
    out.clear();
    // (segment, next row, run end) per segment holding in-window rows;
    // caller-owned scratch so the per-entity loop never allocates.
    heads.clear();
    for (si, seg) in segs.iter().enumerate() {
        if !seg.may_contain_entity(entity) {
            stats.bloom_pruned += 1;
            continue;
        }
        if !seg.overlaps_event_window(window) {
            stats.window_pruned += 1;
            continue;
        }
        let (lo, hi) = readers[si].entity_run(entity, positions[si]);
        positions[si] = hi;
        let (wlo, whi) = readers[si].run_event_window(lo, hi, window);
        if wlo < whi {
            heads.push((si, wlo, whi));
        }
    }
    if let &[(si, lo, hi)] = &heads[..] {
        for i in lo..hi {
            let (_, ev, cr) = readers[si].key(i);
            out.push((ev, cr, si as u32, i as u32));
        }
        stats.rows_merged += out.len() as u64;
        return;
    }
    while !heads.is_empty() {
        let mut b = 0;
        let mut bkey = {
            let (si, i, _) = heads[0];
            let (_, ev, cr) = readers[si].key(i);
            (ev, cr)
        };
        for k in 1..heads.len() {
            let (si, i, _) = heads[k];
            let (_, ev, cr) = readers[si].key(i);
            if (ev, cr) < bkey {
                b = k;
                bkey = (ev, cr);
            }
        }
        let (si, i, hi) = heads[b];
        out.push((bkey.0, bkey.1, si as u32, i as u32));
        if i + 1 < hi {
            heads[b].1 = i + 1;
        } else {
            heads.swap_remove(b);
        }
    }
    stats.rows_merged += out.len() as u64;
}

/// One unit of fanned-out join work: a contiguous span of the sorted
/// spine joined against one table's segment snapshot.
struct JoinTask {
    segs: Arc<Vec<Arc<Segment>>>,
    obs: Arc<Vec<Observation>>,
    /// Spine permutation, sorted by `(entity, ts)`.
    order: Arc<Vec<u32>>,
    /// Span `[lo, hi)` of `order` this task owns (entity-aligned).
    lo: usize,
    hi: usize,
    /// Schema column indices to extract for this table.
    cols: Arc<Vec<usize>>,
    window: FeatureWindow,
    cfg: PitConfig,
    /// Table this task joins against (trace labels only).
    table: Arc<String>,
    /// Sampled request trace this query runs under: each task reports
    /// its segment/pruning/merge tallies as one `join_task` event.
    trace: Option<Arc<TraceContext>>,
}

impl JoinTask {
    /// Returns `span_len * cols.len()` cells, row-major within the span.
    fn run(&self) -> Vec<Option<f32>> {
        let n_cols = self.cols.len();
        let span = &self.order[self.lo..self.hi];
        let mut out = vec![None; span.len() * n_cols];
        // Per-segment decode cursors + forward-only run positions: the
        // task streams each compressed segment's blocks exactly once as
        // spine entities ascend.
        let mut readers: Vec<SegmentCursor<'_>> = self.segs.iter().map(|s| s.cursor()).collect();
        let mut positions = vec![0usize; self.segs.len()];
        let mut heads: Vec<(usize, usize, usize)> = Vec::new();
        let mut cand: Vec<Candidate> = Vec::new();
        let mut stats = JoinStats::default();
        let mut pos = 0;
        while pos < span.len() {
            let entity = self.obs[span[pos] as usize].entity;
            let mut end = pos + 1;
            while end < span.len() && self.obs[span[end] as usize].entity == entity {
                end += 1;
            }
            collect_candidates(
                &self.segs,
                &mut readers,
                &mut positions,
                entity,
                self.window,
                &mut heads,
                &mut cand,
                &mut stats,
            );
            if !cand.is_empty() {
                for k in pos..end {
                    let o = self.obs[span[k] as usize];
                    if let Some(win) = pit_pick(&cand, o.ts, self.cfg) {
                        let (_, _, si, ri) = cand[win];
                        let vals = self.segs[si as usize].values_of(ri as usize);
                        for (j, &col) in self.cols.iter().enumerate() {
                            out[k * n_cols + j] = vals.get(col).copied();
                        }
                    }
                }
            }
            pos = end;
        }
        if let Some(t) = &self.trace {
            t.event(
                "join_task",
                format!(
                    "table={} span={} segments={} bloom_pruned={} window_pruned={} \
                     rows_merged={}",
                    self.table,
                    span.len(),
                    self.segs.len(),
                    stats.bloom_pruned,
                    stats.window_pruned,
                    stats.rows_merged,
                ),
            );
        }
        out
    }
}

/// Split the sorted spine into entity-aligned spans of at least
/// `target` observations (one span when parallelism is off).
fn chunk_spine(obs: &[Observation], order: &[u32], workers: usize) -> Vec<(usize, usize)> {
    let n = order.len();
    if workers <= 1 || n == 0 {
        return vec![(0, n)];
    }
    let target = (n / (workers * 3)).max(256);
    let mut chunks = Vec::new();
    let mut start = 0;
    while start < n {
        let mut i = (start + target).min(n);
        if i < n {
            // Extend to the end of the entity straddling the boundary so
            // no entity's candidate merge is done twice.
            let e = obs[order[i - 1] as usize].entity;
            while i < n && obs[order[i] as usize].entity == e {
                i += 1;
            }
        }
        chunks.push((start, i));
        start = i;
    }
    chunks
}

/// Offline query engine bound to an offline store, optionally fanning
/// work out over a shared thread pool.
pub struct OfflineQueryEngine {
    store: Arc<OfflineStore>,
    pool: Option<Arc<ThreadPool>>,
    trace: Option<Arc<TraceContext>>,
}

impl OfflineQueryEngine {
    pub fn new(store: Arc<OfflineStore>) -> Self {
        OfflineQueryEngine { store, pool: None, trace: None }
    }

    /// Engine that runs per-table / per-entity-chunk joins on `pool`.
    /// Must not be invoked *from* a task already running on that pool
    /// (the blocking joins could starve the queue).
    pub fn with_pool(store: Arc<OfflineStore>, pool: Arc<ThreadPool>) -> Self {
        OfflineQueryEngine { store, pool: Some(pool), trace: None }
    }

    /// Attach a sampled request trace: every fanned-out join task will
    /// report its segment/pruning/merge tallies into it (one `join_task`
    /// event per table × entity-chunk), so a slow training-frame trace
    /// shows *where* the scan work went.
    pub fn with_trace(mut self, trace: Arc<TraceContext>) -> Self {
        self.trace = Some(trace);
        self
    }

    /// PIT-join `observations` against `features`. Each feature ref must
    /// resolve in `specs` (keyed by feature-set name). The scan window is
    /// derived from the observation span plus each set's max staleness.
    pub fn get_training_frame(
        &self,
        observations: &[Observation],
        features: &[FeatureRef],
        specs: &HashMap<String, FeatureSetSpec>,
        cfg: PitConfig,
    ) -> Result<TrainingFrame> {
        let columns: Vec<String> = features.iter().map(|f| f.to_string()).collect();
        let n = observations.len();
        if n == 0 {
            return Ok(TrainingFrame { columns, observations: Vec::new(), data: Vec::new() });
        }
        let obs_min = observations.iter().map(|o| o.ts).min().unwrap();
        let obs_max = observations.iter().map(|o| o.ts).max().unwrap();

        // Group feature refs per feature-set table, resolving schemas up
        // front so errors surface before any work is scheduled.
        // (table, granularity secs, [(frame col, schema col)])
        let mut per_table: Vec<(String, i64, Vec<(usize, usize)>)> = Vec::new();
        for (col, f) in features.iter().enumerate() {
            let spec = specs
                .get(&f.feature_set)
                .ok_or_else(|| FsError::NotFound(format!("feature set spec '{}'", f.feature_set)))?;
            let ci = f.column_index(spec)?;
            let table = f.table();
            match per_table.iter_mut().find(|(t, _, _)| *t == table) {
                Some((_, _, cols)) => cols.push((col, ci)),
                None => per_table.push((table, spec.granularity.secs(), vec![(col, ci)])),
            }
        }

        // The spine permutation, sorted by (entity, ts) — the merge-join
        // driving order, computed once for every table.
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by_key(|&i| {
            let o = observations[i as usize];
            (o.entity, o.ts)
        });
        let obs_arc = Arc::new(observations.to_vec());
        let order_arc = Arc::new(order);
        let workers = self.pool.as_ref().map(|p| p.worker_count()).unwrap_or(1);
        let chunks = chunk_spine(&obs_arc, &order_arc, workers);

        let mut data: Vec<Option<f32>> = vec![None; features.len() * n];
        let mut tasks: Vec<JoinTask> = Vec::new();
        let mut metas: Vec<(usize, usize, Vec<usize>)> = Vec::new();

        for (table, gran_secs, cols) in &per_table {
            let segs = self.store.snapshot(table);
            if segs.is_empty() {
                continue; // unknown/empty table: whole columns stay None
            }
            // Scan window: far enough back that any record usable by the
            // earliest observation is included.
            let lookback = if cfg.max_staleness > 0 {
                cfg.max_staleness
            } else {
                // Unlimited staleness: reach back to the table's own start.
                let table_start = self
                    .store
                    .event_range(table)
                    .map(|(lo, _)| obs_min - lo)
                    .unwrap_or(0)
                    .max(0);
                table_start + gran_secs
            };
            let window = FeatureWindow::new(obs_min - lookback, obs_max + 1);
            let segs = Arc::new(segs);
            let schema_cols = Arc::new(cols.iter().map(|&(_, ci)| ci).collect::<Vec<_>>());
            let frame_cols: Vec<usize> = cols.iter().map(|&(c, _)| c).collect();
            if let Some(t) = &self.trace {
                t.event(
                    "table_scan",
                    format!(
                        "table={table} segments={} window=[{},{})",
                        segs.len(),
                        window.start,
                        window.end
                    ),
                );
            }
            let table_arc = Arc::new(table.clone());
            for &(lo, hi) in &chunks {
                tasks.push(JoinTask {
                    segs: segs.clone(),
                    obs: obs_arc.clone(),
                    order: order_arc.clone(),
                    lo,
                    hi,
                    cols: schema_cols.clone(),
                    window,
                    cfg,
                    table: table_arc.clone(),
                    trace: self.trace.clone(),
                });
                metas.push((lo, hi, frame_cols.clone()));
            }
        }

        let results: Vec<Vec<Option<f32>>> = match &self.pool {
            Some(pool) if tasks.len() > 1 => pool.map(tasks, |t: JoinTask| t.run()),
            // Consume the tasks either way so every Arc ref drops before
            // the frame reclaims the spine below.
            _ => tasks.into_iter().map(|t| t.run()).collect(),
        };

        // Scatter span-local cells into the columnar frame.
        for ((lo, hi, frame_cols), cells) in metas.into_iter().zip(results) {
            let n_cols = frame_cols.len();
            for local in 0..(hi - lo) {
                let row = order_arc[lo + local] as usize;
                for (j, &col) in frame_cols.iter().enumerate() {
                    data[col * n + row] = cells[local * n_cols + j];
                }
            }
        }

        // All tasks have dropped their Arc refs; reclaim the spine copy
        // instead of cloning it a second time for the frame.
        let observations = Arc::try_unwrap(obs_arc).unwrap_or_else(|a| a.as_ref().clone());
        Ok(TrainingFrame { columns, observations, data })
    }

    /// Was the window fully materialized when read? The caller combines
    /// this with the scheduler's data-state to distinguish "no data" from
    /// "not materialized" (§4.3).
    pub fn store(&self) -> &Arc<OfflineStore> {
        &self.store
    }
}

/// Naive full-scan join baseline (per-observation linear scan) — the
/// differential-test oracle and the comparator for `benches/pit_join.rs`
/// (experiment E4).
pub fn naive_training_frame(
    store: &OfflineStore,
    observations: &[Observation],
    features: &[FeatureRef],
    specs: &HashMap<String, FeatureSetSpec>,
    cfg: PitConfig,
) -> Result<TrainingFrame> {
    let columns: Vec<String> = features.iter().map(|f| f.to_string()).collect();
    let n = observations.len();
    let mut data: Vec<Option<f32>> = vec![None; features.len() * n];
    for (row, &observation) in observations.iter().enumerate() {
        for (col, f) in features.iter().enumerate() {
            let spec = specs
                .get(&f.feature_set)
                .ok_or_else(|| FsError::NotFound(format!("spec '{}'", f.feature_set)))?;
            let ci = f.column_index(spec)?;
            let all = store.scan(&f.table(), scan_all_window(store, &f.table(), observation.ts));
            if let Some(rec) = super::pit::pit_lookup(&all, observation, cfg) {
                data[col * n + row] = rec.values.get(ci).copied();
            }
        }
    }
    Ok(TrainingFrame { columns, observations: observations.to_vec(), data })
}

fn scan_all_window(store: &OfflineStore, table: &str, until: Timestamp) -> FeatureWindow {
    // Inclusive end: with the end-of-bin convention (§4.5.1) a record
    // with `event_ts == until` is admissible, exactly as `pit_lookup`
    // admits it — the oracle's scan window must not hide such records.
    let lo = store.event_range(table).map(|(lo, _)| lo).unwrap_or(0).min(until);
    FeatureWindow::new(lo, until + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::SourceSpec;
    use crate::types::time::{Granularity, DAY};
    use crate::types::FeatureRecord;

    fn setup() -> (OfflineQueryEngine, HashMap<String, FeatureSetSpec>) {
        let store = Arc::new(OfflineStore::new());
        let spec = FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        );
        // Two entities, two days of records; entity 1 gets a late
        // recompute for day 1.
        store.merge(
            "txn:1",
            &[
                FeatureRecord::new(1, DAY, DAY + 100, vec![10.0, 1.0, 10.0, 10.0, 10.0]),
                FeatureRecord::new(1, 2 * DAY, 2 * DAY + 100, vec![20.0, 2.0, 10.0, 5.0, 15.0]),
                FeatureRecord::new(1, DAY, 3 * DAY, vec![11.0, 1.0, 11.0, 11.0, 11.0]),
                FeatureRecord::new(2, DAY, DAY + 100, vec![7.0, 1.0, 7.0, 7.0, 7.0]),
            ],
        );
        let mut specs = HashMap::new();
        specs.insert("txn".to_string(), spec);
        (OfflineQueryEngine::new(store), specs)
    }

    fn refs(names: &[&str]) -> Vec<FeatureRef> {
        names.iter().map(|n| FeatureRef::parse(&format!("txn:1:{n}")).unwrap()).collect()
    }

    #[test]
    fn joins_pit_correct_values() {
        let (q, specs) = setup();
        let obs = vec![
            Observation { entity: 1, ts: DAY + 200 },     // sees day-1 original
            Observation { entity: 1, ts: 2 * DAY + 200 }, // sees day-2
            Observation { entity: 2, ts: DAY + 50 },      // created later → none
            Observation { entity: 3, ts: 5 * DAY },       // unknown entity
        ];
        let frame = q
            .get_training_frame(&obs, &refs(&["720h_sum", "720h_cnt"]), &specs, PitConfig::default())
            .unwrap();
        assert_eq!(frame.columns.len(), 2);
        assert_eq!(frame.len(), 4);
        assert_eq!(frame.value(0, 0), Some(10.0));
        assert_eq!(frame.value(1, 0), Some(20.0));
        assert_eq!(frame.value(1, 1), Some(2.0));
        assert_eq!(frame.value(2, 0), None); // availability guard
        assert_eq!(frame.value(3, 0), None);
        assert!((frame.fill_rate() - 0.5).abs() < 1e-9);
        // Row gather matches the columnar cells.
        let rows: Vec<TrainingRow> = frame.rows().collect();
        assert_eq!(rows[1].observation, obs[1]);
        assert_eq!(rows[1].features, vec![Some(20.0), Some(2.0)]);
        // Whole-column access is contiguous.
        assert_eq!(frame.column(0), &[Some(10.0), Some(20.0), None, None]);
    }

    #[test]
    fn matches_naive_baseline() {
        let (q, specs) = setup();
        let features = refs(&["720h_sum", "720h_max"]);
        let mut obs: Vec<Observation> = (0..40)
            .map(|i| Observation { entity: 1 + (i % 3), ts: DAY / 2 + i as i64 * 6_000 })
            .collect();
        // Exercise the inclusive-end boundary: observation exactly at an
        // event timestamp.
        obs.push(Observation { entity: 1, ts: DAY });
        obs.push(Observation { entity: 1, ts: 2 * DAY });
        for cfg in [
            PitConfig::default(),
            PitConfig { availability_slack: 500, max_staleness: 0 },
            PitConfig { availability_slack: 0, max_staleness: 2 * DAY },
        ] {
            let fast = q.get_training_frame(&obs, &features, &specs, cfg).unwrap();
            let slow = naive_training_frame(q.store(), &obs, &features, &specs, cfg).unwrap();
            assert_eq!(fast, slow, "cfg {cfg:?}");
        }
    }

    #[test]
    fn pooled_engine_matches_sequential() {
        // Two tables and a spine large enough to split into several
        // entity chunks: the pool path (per-table × per-chunk tasks) must
        // scatter back to exactly the sequential result.
        let (q, mut specs) = setup();
        specs.insert(
            "click".to_string(),
            FeatureSetSpec::rolling(
                "click",
                1,
                "customer",
                SourceSpec::synthetic(0),
                Granularity::daily(),
                30,
            ),
        );
        for e in 0..5u64 {
            for d in 1..4i64 {
                q.store().merge(
                    "click:1",
                    &[FeatureRecord::new(
                        e,
                        d * DAY,
                        d * DAY + 50,
                        vec![e as f32 + d as f32, 1.0, 0.0, 0.0, 0.0],
                    )],
                );
            }
        }
        let pooled =
            OfflineQueryEngine::with_pool(q.store().clone(), Arc::new(ThreadPool::new(3)));
        let mut features = refs(&["720h_sum", "720h_cnt", "720h_max"]);
        features.push(FeatureRef::parse("click:1:720h_sum").unwrap());
        let obs: Vec<Observation> = (0..1_000)
            .map(|i| Observation { entity: i % 5, ts: DAY / 3 + i as i64 * 300 })
            .collect();
        let cfg = PitConfig { availability_slack: 100, max_staleness: 3 * DAY };
        let seq = q.get_training_frame(&obs, &features, &specs, cfg).unwrap();
        let par = pooled.get_training_frame(&obs, &features, &specs, cfg).unwrap();
        assert_eq!(seq, par);
        assert!(par.fill_rate() > 0.0);
    }

    #[test]
    fn exact_event_ts_is_admissible_when_available() {
        // End-of-bin convention: a record whose event_ts equals the
        // observation time is served as long as it was created by then —
        // on both the engine and the oracle path.
        let store = Arc::new(OfflineStore::new());
        store.merge("txn:1", &[FeatureRecord::new(1, 100, 100, vec![5.0, 1.0, 5.0, 5.0, 5.0])]);
        let spec = FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        );
        let mut specs = HashMap::new();
        specs.insert("txn".to_string(), spec);
        let q = OfflineQueryEngine::new(store);
        let obs = vec![Observation { entity: 1, ts: 100 }];
        let features = refs(&["720h_sum"]);
        let fast = q.get_training_frame(&obs, &features, &specs, PitConfig::default()).unwrap();
        let slow =
            naive_training_frame(q.store(), &obs, &features, &specs, PitConfig::default()).unwrap();
        assert_eq!(fast.value(0, 0), Some(5.0));
        assert_eq!(fast, slow);
    }

    #[test]
    fn empty_observations_ok() {
        let (q, specs) = setup();
        let frame = q
            .get_training_frame(&[], &refs(&["720h_sum"]), &specs, PitConfig::default())
            .unwrap();
        assert!(frame.is_empty());
        assert_eq!(frame.fill_rate(), 0.0);
    }

    #[test]
    fn missing_spec_or_feature_errors() {
        let (q, specs) = setup();
        let obs = vec![Observation { entity: 1, ts: DAY }];
        let bad_set = vec![FeatureRef::parse("other:1:x").unwrap()];
        assert!(q.get_training_frame(&obs, &bad_set, &specs, PitConfig::default()).is_err());
        let bad_feature = vec![FeatureRef::parse("txn:1:missing").unwrap()];
        assert!(q
            .get_training_frame(&obs, &bad_feature, &specs, PitConfig::default())
            .is_err());
    }

    #[test]
    fn chunking_is_entity_aligned_and_covering() {
        let obs: Vec<Observation> =
            (0..1_000).map(|i| Observation { entity: (i / 10) as u64, ts: i as i64 }).collect();
        let mut order: Vec<u32> = (0..obs.len() as u32).collect();
        order.sort_unstable_by_key(|&i| (obs[i as usize].entity, obs[i as usize].ts));
        let chunks = chunk_spine(&obs, &order, 4);
        assert!(chunks.len() > 1);
        // Covering and contiguous.
        assert_eq!(chunks[0].0, 0);
        assert_eq!(chunks.last().unwrap().1, obs.len());
        for pair in chunks.windows(2) {
            assert_eq!(pair[0].1, pair[1].0);
            // Entity-aligned: an entity never straddles a boundary.
            let left = obs[order[pair[0].1 - 1] as usize].entity;
            let right = obs[order[pair[1].0] as usize].entity;
            assert_ne!(left, right);
        }
    }
}
