//! File source connector: CSV or JSONL event files.
//!
//! CSV layout: header `key,ts,value` (any column order); JSONL: one
//! object per line with fields `key`, `ts`, `value`.  Used by the
//! examples to feed real (on-disk) datasets through the same path the
//! synthetic source uses.

use std::path::{Path, PathBuf};

use super::{Event, SourceConnector};
use crate::types::{FeatureWindow, FsError, Result, Timestamp};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct FileSource {
    pub path: PathBuf,
    pub delay_secs: i64,
}

impl FileSource {
    pub fn new(path: impl AsRef<Path>) -> Self {
        FileSource { path: path.as_ref().to_path_buf(), delay_secs: 0 }
    }

    pub fn with_delay(mut self, delay_secs: i64) -> Self {
        self.delay_secs = delay_secs;
        self
    }

    fn parse_csv(&self, text: &str) -> Result<Vec<Event>> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines
            .next()
            .ok_or_else(|| FsError::Schema("empty csv".into()))?;
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        let find = |name: &str| -> Result<usize> {
            cols.iter()
                .position(|c| *c == name)
                .ok_or_else(|| FsError::Schema(format!("csv missing column '{name}'")))
        };
        let (ki, ti, vi) = (find("key")?, find("ts")?, find("value")?);
        let mut out = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            if fields.len() != cols.len() {
                return Err(FsError::Schema(format!("csv line {}: arity mismatch", lineno + 2)));
            }
            out.push(Event {
                key: fields[ki].to_string(),
                ts: fields[ti]
                    .parse()
                    .map_err(|_| FsError::Schema(format!("csv line {}: bad ts", lineno + 2)))?,
                value: fields[vi]
                    .parse()
                    .map_err(|_| FsError::Schema(format!("csv line {}: bad value", lineno + 2)))?,
            });
        }
        Ok(out)
    }

    fn parse_jsonl(&self, text: &str) -> Result<Vec<Event>> {
        let mut out = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line)
                .map_err(|e| FsError::Schema(format!("jsonl line {}: {e}", lineno + 1)))?;
            let key = v
                .get("key")
                .as_str()
                .ok_or_else(|| FsError::Schema(format!("jsonl line {}: missing key", lineno + 1)))?
                .to_string();
            let ts = v
                .get("ts")
                .as_i64()
                .ok_or_else(|| FsError::Schema(format!("jsonl line {}: missing ts", lineno + 1)))?;
            let value = v.get("value").as_f64().ok_or_else(|| {
                FsError::Schema(format!("jsonl line {}: missing value", lineno + 1))
            })? as f32;
            out.push(Event { key, ts, value });
        }
        Ok(out)
    }
}

impl SourceConnector for FileSource {
    fn read(&self, window: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>> {
        let text = std::fs::read_to_string(&self.path)?;
        let all = match self.path.extension().and_then(|e| e.to_str()) {
            Some("csv") => self.parse_csv(&text)?,
            Some("jsonl") | Some("json") => self.parse_jsonl(&text)?,
            other => {
                return Err(FsError::InvalidArg(format!(
                    "unsupported source file extension {other:?} (want .csv or .jsonl)"
                )))
            }
        };
        let mut out: Vec<Event> = all
            .into_iter()
            .filter(|e| window.contains(e.ts) && e.ts + self.delay_secs <= as_of)
            .collect();
        out.sort_by(|a, b| (a.ts, &a.key).cmp(&(b.ts, &b.key)));
        Ok(out)
    }

    fn delay_secs(&self) -> i64 {
        self.delay_secs
    }

    fn describe(&self) -> String {
        format!("file({})", self.path.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, content: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("geofs-src-{}-{name}", std::process::id()));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmp("a.csv", "key,ts,value\nc1,100,2.5\nc2,200,3.5\n");
        let s = FileSource::new(&p);
        let got = s.read(FeatureWindow::new(0, 1_000), i64::MAX).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], Event { key: "c1".into(), ts: 100, value: 2.5 });
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn csv_column_order_free() {
        let p = tmp("b.csv", "value,key,ts\n7.5,c9,42\n");
        let got = FileSource::new(&p).read(FeatureWindow::new(0, 100), i64::MAX).unwrap();
        assert_eq!(got[0].key, "c9");
        assert_eq!(got[0].value, 7.5);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn jsonl_roundtrip() {
        let p = tmp(
            "c.jsonl",
            "{\"key\":\"c1\",\"ts\":100,\"value\":2.5}\n{\"key\":\"c2\",\"ts\":900,\"value\":1.0}\n",
        );
        let got = FileSource::new(&p).read(FeatureWindow::new(0, 500), i64::MAX).unwrap();
        assert_eq!(got.len(), 1); // window filter applies
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn delay_applies() {
        let p = tmp("d.csv", "key,ts,value\nc1,100,1.0\n");
        let s = FileSource::new(&p).with_delay(50);
        assert!(s.read(FeatureWindow::new(0, 200), 149).unwrap().is_empty());
        assert_eq!(s.read(FeatureWindow::new(0, 200), 150).unwrap().len(), 1);
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn schema_errors() {
        let p = tmp("e.csv", "a,b\n1,2\n");
        assert!(FileSource::new(&p).read(FeatureWindow::new(0, 10), 0).is_err());
        std::fs::remove_file(&p).unwrap();

        let p = tmp("f.jsonl", "{\"key\":\"x\"}\n");
        assert!(FileSource::new(&p).read(FeatureWindow::new(0, 10), 0).is_err());
        std::fs::remove_file(&p).unwrap();

        let p = tmp("g.txt", "whatever");
        assert!(matches!(
            FileSource::new(&p).read(FeatureWindow::new(0, 10), 0),
            Err(FsError::InvalidArg(_))
        ));
        std::fs::remove_file(&p).unwrap();
    }
}
