//! Seeded synthetic event source.
//!
//! Substitute for the paper's production data sources (DESIGN.md §5):
//! per-entity Poisson arrivals with lognormal-ish values, deterministic
//! given (seed, window) — the same window always re-reads identical
//! events, which the idempotent-merge and eventual-consistency tests
//! rely on. Arrival delay models late-landing data (§4.4).

use super::{Event, SourceConnector};
use crate::types::time::Granularity;
use crate::types::{FeatureWindow, Result, Timestamp};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SyntheticSource {
    pub seed: u64,
    /// Entity keys this source emits (e.g. customer ids).
    pub entities: Vec<String>,
    /// Mean events per entity per hour.
    pub rate_per_hour: f64,
    /// Source delay: event at `ts` becomes visible at `ts + delay_secs`.
    pub delay_secs: i64,
    /// Value distribution: value = base * exp(normal * sigma).
    pub value_base: f64,
    pub value_sigma: f64,
}

impl SyntheticSource {
    pub fn new(seed: u64, n_entities: usize) -> Self {
        SyntheticSource {
            seed,
            entities: (0..n_entities).map(|i| format!("cust_{i:05}")).collect(),
            rate_per_hour: 0.8,
            delay_secs: 0,
            value_base: 25.0,
            value_sigma: 0.8,
        }
    }

    pub fn with_delay(mut self, delay_secs: i64) -> Self {
        self.delay_secs = delay_secs;
        self
    }

    pub fn with_rate(mut self, rate_per_hour: f64) -> Self {
        self.rate_per_hour = rate_per_hour;
        self
    }

    /// Deterministic per (entity, hour-bucket) stream so *any* window
    /// read reproduces the same events.
    fn events_for_bucket(&self, entity_idx: usize, bucket: i64) -> Vec<Event> {
        let g = Granularity::hourly();
        let mut rng = Rng::new(
            self.seed
                ^ (entity_idx as u64).wrapping_mul(0x9e3779b97f4a7c15)
                ^ (bucket as u64).wrapping_mul(0xc2b2ae3d27d4eb4f),
        );
        let n = rng.poisson(self.rate_per_hour);
        let start = bucket * g.secs();
        (0..n)
            .map(|_| {
                let ts = start + rng.below(g.secs() as u64) as i64;
                let value = (self.value_base * (rng.normal() * self.value_sigma).exp()) as f32;
                Event { key: self.entities[entity_idx].clone(), ts, value }
            })
            .collect()
    }
}

impl SourceConnector for SyntheticSource {
    fn read(&self, window: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>> {
        let g = Granularity::hourly();
        let b0 = window.start.div_euclid(g.secs());
        let b1 = (window.end - 1).div_euclid(g.secs());
        let mut out = Vec::new();
        for e in 0..self.entities.len() {
            for b in b0..=b1 {
                for ev in self.events_for_bucket(e, b) {
                    if window.contains(ev.ts) && ev.ts + self.delay_secs <= as_of {
                        out.push(ev);
                    }
                }
            }
        }
        // Stable order (ts, key) for reproducibility.
        out.sort_by(|a, b| (a.ts, &a.key).cmp(&(b.ts, &b.key)));
        Ok(out)
    }

    fn delay_secs(&self) -> i64 {
        self.delay_secs
    }

    fn describe(&self) -> String {
        format!("synthetic(seed={}, entities={}, rate={}/h)", self.seed, self.entities.len(), self.rate_per_hour)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::{DAY, HOUR};

    #[test]
    fn deterministic_reads() {
        let s = SyntheticSource::new(42, 10);
        let w = FeatureWindow::new(0, DAY);
        let a = s.read(w, i64::MAX).unwrap();
        let b = s.read(w, i64::MAX).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn subwindow_reads_are_consistent() {
        // Reading [0,2d) must equal [0,1d) ∪ [1d,2d) — window-invariant
        // generation is what makes re-materialization idempotent.
        let s = SyntheticSource::new(7, 5);
        let full = s.read(FeatureWindow::new(0, 2 * DAY), i64::MAX).unwrap();
        let mut halves = s.read(FeatureWindow::new(0, DAY), i64::MAX).unwrap();
        halves.extend(s.read(FeatureWindow::new(DAY, 2 * DAY), i64::MAX).unwrap());
        halves.sort_by(|a, b| (a.ts, &a.key).cmp(&(b.ts, &b.key)));
        assert_eq!(full, halves);
    }

    #[test]
    fn events_inside_window() {
        let s = SyntheticSource::new(1, 5);
        let w = FeatureWindow::new(3 * HOUR, 9 * HOUR);
        for e in s.read(w, i64::MAX).unwrap() {
            assert!(w.contains(e.ts));
        }
    }

    #[test]
    fn delay_hides_recent_events() {
        let s = SyntheticSource::new(3, 20).with_delay(2 * HOUR);
        let w = FeatureWindow::new(0, DAY);
        let complete = s.read(w, i64::MAX).unwrap();
        let as_of_end = s.read(w, DAY).unwrap();
        // Events in the last 2h of the window are not yet visible.
        assert!(as_of_end.len() < complete.len());
        for e in &as_of_end {
            assert!(e.ts + 2 * HOUR <= DAY);
        }
        // Reading later reveals everything.
        let later = s.read(w, DAY + 2 * HOUR).unwrap();
        assert_eq!(later, complete);
    }

    #[test]
    fn rate_scales_event_count() {
        let lo = SyntheticSource::new(5, 50).with_rate(0.2);
        let hi = SyntheticSource::new(5, 50).with_rate(2.0);
        let w = FeatureWindow::new(0, 2 * DAY);
        let n_lo = lo.read(w, i64::MAX).unwrap().len();
        let n_hi = hi.read(w, i64::MAX).unwrap().len();
        assert!(n_hi > n_lo * 5, "lo={n_lo} hi={n_hi}");
    }

    #[test]
    fn different_seeds_differ() {
        let w = FeatureWindow::new(0, DAY);
        let a = SyntheticSource::new(1, 10).read(w, i64::MAX).unwrap();
        let b = SyntheticSource::new(2, 10).read(w, i64::MAX).unwrap();
        assert_ne!(a, b);
    }
}
