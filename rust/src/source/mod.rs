//! Source systems (§2.2): connectors that yield raw events, plus the
//! binning stage that turns events into the dense per-bin partial
//! aggregates the compute layer consumes.
//!
//! An event is `(entity_key, ts, value)` — the minimal shape the paper's
//! churn example needs (`30day_transactions_sum` over transaction
//! amounts).  Connectors model *source delay* (§4.4): an event with
//! timestamp `t` only becomes readable at `t + delay` on the processing
//! timeline, which is what makes leakage prevention non-trivial.

pub mod binning;
pub mod file;
pub mod synthetic;

pub use binning::bin_events;
pub use file::FileSource;
pub use synthetic::SyntheticSource;

use crate::types::{FeatureWindow, Result, Timestamp};

/// One raw source event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Canonical entity key (index columns joined; see `EntityInterner`).
    pub key: String,
    /// Event timestamp on the event timeline.
    pub ts: Timestamp,
    /// Value column the transformation aggregates.
    pub value: f32,
}

/// A source connector (§3.2's "source" artifact).
pub trait SourceConnector: Send + Sync {
    /// Events with `ts` in `window`, *as visible at* `as_of` on the
    /// processing timeline: events with `ts + delay > as_of` are not yet
    /// readable (late data). Pass `as_of = i64::MAX` for a complete read.
    fn read(&self, window: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>>;

    /// The connector's expected source delay in seconds (§4.4).
    fn delay_secs(&self) -> i64 {
        0
    }

    /// Human-readable identity for lineage/monitoring.
    fn describe(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_shape() {
        let e = Event { key: "c1".into(), ts: 100, value: 2.5 };
        assert_eq!(e.key, "c1");
        assert_eq!(e.ts, 100);
    }
}
