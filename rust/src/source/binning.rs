//! Binning: raw events → dense per-bin partial aggregates.
//!
//! This is the bridge between the row-oriented source world and the
//! dense tensor world of the AOT compute graph: events in the source
//! window (feature window + lookback halo, per Algorithm 1) are grouped
//! by (entity, bin) and reduced to per-bin `sum/cnt/min/max` planes of
//! shape `[E, lookback_bins + window_bins]`.

use std::collections::HashMap;

use super::Event;
use crate::runtime::BinPlanes;
use crate::types::time::Granularity;
use crate::types::{EntityInterner, FeatureWindow};

/// Result of binning: the planes plus the entity row mapping.
#[derive(Debug)]
pub struct BinnedWindow {
    pub planes: BinPlanes,
    /// Entity id for each row of the planes.
    pub row_entities: Vec<u64>,
    /// The *feature* window these planes cover (excluding the halo).
    pub feature_window: FeatureWindow,
    /// Halo bins on the left (window_bins - 1 for rolling transforms).
    pub halo_bins: usize,
}

/// Bin `events` (which must already cover `feature_window.source_window
/// (halo)`) into planes. Entities are interned through `interner`;
/// rows appear in first-seen order.
///
/// Events outside the source window are ignored (defensive — connectors
/// already filter).
pub fn bin_events(
    events: &[Event],
    interner: &EntityInterner,
    feature_window: FeatureWindow,
    granularity: Granularity,
    halo_bins: usize,
) -> BinnedWindow {
    debug_assert!(granularity.aligned(feature_window.start));
    debug_assert!(granularity.aligned(feature_window.end));
    let source_start = feature_window.start - halo_bins as i64 * granularity.secs();
    let total_bins = halo_bins + feature_window.bins(granularity) as usize;

    // First pass: discover entities (stable order), memoizing the
    // interned id per event so the fill pass never touches the interner
    // lock again.
    let mut row_of: HashMap<u64, usize> = HashMap::new();
    let mut row_entities: Vec<u64> = Vec::new();
    let mut event_rows: Vec<usize> = Vec::with_capacity(events.len());
    for e in events {
        let id = interner.intern(&e.key);
        let row = *row_of.entry(id).or_insert_with(|| {
            row_entities.push(id);
            row_entities.len() - 1
        });
        event_rows.push(row);
    }

    let mut planes = BinPlanes::empty(row_entities.len().max(1), total_bins.max(1));
    for (e, &row) in events.iter().zip(&event_rows) {
        if e.ts < source_start || e.ts >= feature_window.end {
            continue;
        }
        let bin = granularity.bin_index(granularity.floor(source_start), e.ts);
        // source_start is aligned because feature_window.start is.
        planes.add_event(row, bin as usize, e.value);
    }
    BinnedWindow { planes, row_entities, feature_window, halo_bins }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::HOUR;

    fn ev(key: &str, ts: i64, value: f32) -> Event {
        Event { key: key.into(), ts, value }
    }

    #[test]
    fn bins_by_entity_and_time() {
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(2 * HOUR, 4 * HOUR); // 2 output bins
        let events = vec![
            ev("a", 0, 1.0),             // halo bin 0
            ev("a", HOUR + 10, 2.0),     // halo bin 1
            ev("a", 2 * HOUR + 5, 4.0),  // feature bin 0 (index 2)
            ev("b", 3 * HOUR + 5, 8.0),  // feature bin 1 (index 3)
            ev("a", 3 * HOUR + 6, 16.0), // feature bin 1
        ];
        let out = bin_events(&events, &interner, w, g, 2);
        assert_eq!(out.planes.bins(), 4); // 2 halo + 2 feature
        assert_eq!(out.row_entities.len(), 2);
        let (ra, rb) = (0usize, 1usize); // first-seen order: a then b
        assert_eq!(out.planes.sum.get(ra, 0), 1.0);
        assert_eq!(out.planes.sum.get(ra, 1), 2.0);
        assert_eq!(out.planes.sum.get(ra, 2), 4.0);
        assert_eq!(out.planes.sum.get(ra, 3), 16.0);
        assert_eq!(out.planes.sum.get(rb, 3), 8.0);
        assert_eq!(out.planes.cnt.get(ra, 3), 1.0);
        assert_eq!(out.planes.min.get(rb, 3), 8.0);
    }

    #[test]
    fn multiple_events_same_bin_aggregate() {
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(0, HOUR);
        let events = vec![ev("a", 10, 3.0), ev("a", 20, 5.0), ev("a", 30, 1.0)];
        let out = bin_events(&events, &interner, w, g, 0);
        assert_eq!(out.planes.sum.get(0, 0), 9.0);
        assert_eq!(out.planes.cnt.get(0, 0), 3.0);
        assert_eq!(out.planes.min.get(0, 0), 1.0);
        assert_eq!(out.planes.max.get(0, 0), 5.0);
    }

    #[test]
    fn empty_events_yield_identity_planes() {
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let out = bin_events(&[], &interner, FeatureWindow::new(0, 2 * HOUR), g, 1);
        assert!(out.row_entities.is_empty());
        assert_eq!(out.planes.sum.get(0, 0), 0.0); // placeholder row
        assert_eq!(out.planes.min.get(0, 0), f32::INFINITY);
    }

    #[test]
    fn out_of_window_events_ignored() {
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(HOUR, 2 * HOUR);
        // before halo and after end
        let events = vec![ev("a", -HOUR, 100.0), ev("a", 2 * HOUR, 100.0), ev("a", HOUR, 1.0)];
        let out = bin_events(&events, &interner, w, g, 1);
        let total: f32 = out.planes.sum.data.iter().sum();
        assert_eq!(total, 1.0);
    }

    #[test]
    fn interner_is_shared_across_windows() {
        // Entity rows differ per window but ids are stable globally.
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let w1 = FeatureWindow::new(0, HOUR);
        let w2 = FeatureWindow::new(HOUR, 2 * HOUR);
        let o1 = bin_events(&[ev("x", 5, 1.0), ev("y", 6, 1.0)], &interner, w1, g, 0);
        let o2 = bin_events(&[ev("y", HOUR + 5, 1.0)], &interner, w2, g, 0);
        assert_eq!(o1.row_entities[1], o2.row_entities[0]); // same id for "y"
    }

    #[test]
    fn negative_event_times() {
        let interner = EntityInterner::new();
        let g = Granularity(HOUR);
        let w = FeatureWindow::new(-2 * HOUR, 0);
        let out = bin_events(&[ev("a", -HOUR - 1, 2.0)], &interner, w, g, 0);
        assert_eq!(out.planes.sum.get(0, 0), 2.0); // bin [-2h,-1h)
    }
}
