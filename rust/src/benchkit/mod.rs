//! Bench harness (criterion is not available offline).
//!
//! Each `rust/benches/*.rs` is a `harness = false` binary that uses this
//! module: warmup, timed iterations, percentile/throughput reporting as
//! aligned tables — one table per paper figure/claim (DESIGN.md §3).
//!
//! `cargo bench` runs all of them; `GEOFS_BENCH_FAST=1` shrinks budgets
//! for smoke runs.

use std::time::{Duration, Instant};

use crate::util::hist::Histogram;

/// Runs a closure repeatedly and collects per-iteration latency.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: u64,
    max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        let fast = std::env::var("GEOFS_BENCH_FAST").is_ok();
        if fast {
            Bencher {
                warmup: Duration::from_millis(50),
                budget: Duration::from_millis(200),
                min_iters: 3,
                max_iters: 10_000,
            }
        } else {
            Bencher {
                warmup: Duration::from_millis(300),
                budget: Duration::from_secs(2),
                min_iters: 10,
                max_iters: 1_000_000,
            }
        }
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub hist: Histogram, // per-iteration wall time, ns
    /// Work units per iteration (rows, lookups...) for throughput columns.
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        self.hist.mean()
    }
    pub fn p50_ns(&self) -> u64 {
        self.hist.quantile(0.5)
    }
    pub fn p99_ns(&self) -> u64 {
        self.hist.quantile(0.99)
    }
    pub fn p999_ns(&self) -> u64 {
        self.hist.quantile(0.999)
    }
    /// Units per second at mean latency.
    pub fn throughput(&self) -> f64 {
        if self.mean_ns() == 0.0 {
            0.0
        } else {
            self.units_per_iter * 1e9 / self.mean_ns()
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under the budget. `units` scales throughput reporting.
    pub fn run<T>(&self, name: &str, units: f64, mut f: impl FnMut() -> T) -> Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut hist = Histogram::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (start.elapsed() < self.budget || iters < self.min_iters) && iters < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            hist.record(t0.elapsed().as_nanos() as u64);
            iters += 1;
        }
        Measurement { name: name.to_string(), iters, hist, units_per_iter: units }
    }
}

/// Format ns as an adaptive human unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Format a unit-per-second rate.
pub fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.1}k/s", r / 1e3)
    } else {
        format!("{r:.1}/s")
    }
}

/// Paper-style results table printed to stdout.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: a standard latency row from a measurement.
    pub fn latency_row(&mut self, m: &Measurement) {
        self.row(&[
            m.name.clone(),
            m.iters.to_string(),
            fmt_ns(m.mean_ns()),
            fmt_ns(m.p50_ns() as f64),
            fmt_ns(m.hist.quantile(0.95) as f64),
            fmt_ns(m.p99_ns() as f64),
            fmt_rate(m.throughput()),
        ]);
    }

    pub const LATENCY_HEADERS: &'static [&'static str] =
        &["case", "iters", "mean", "p50", "p95", "p99", "throughput"];

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures() {
        std::env::set_var("GEOFS_BENCH_FAST", "1");
        let b = Bencher::new();
        let m = b.run("noop", 1.0, || 1 + 1);
        assert!(m.iters >= 3);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn fmtters() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1_500.0), "1.5µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert!(fmt_rate(2_000_000.0).contains("M/s"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["x".into(), "y".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
