//! Production load harness (experiment E-LOAD): composable workloads ×
//! datasets over a fully-wired store, with overload behavior measured,
//! not assumed.
//!
//! The paper's managed store exists to serve low-latency online
//! inferencing while batch/stream materialization runs behind it. This
//! module answers "what does the store do under a diurnal serving load?"
//! with a reproducible instrument instead of an anecdote:
//!
//! * **Dataset axis** — the [`crate::sim::workload::ChurnWorkload`]
//!   fixture: a batch-materialized daily table plus a live streamed
//!   hourly table on one store, opened with geo-replication so the real
//!   [`crate::geo::replication::ReplicationDriver`] and
//!   [`crate::offline_store::compaction::CompactionDriver`] run
//!   concurrently with the measured traffic.
//! * **Workload axis** — [`PhaseSpec`]s blending Zipf-skewed
//!   `get_online_many_mixed` reads, streaming `ingest`, and PIT
//!   `get_training_frame` queries under per-phase mix weights and think
//!   times. Key popularity comes from [`crate::util::rng::Zipf`]; every
//!   worker's op sequence derives from the harness seed, so two runs
//!   issue identical traffic (timings — and therefore token-bucket shed
//!   counts — still reflect the machine they ran on).
//! * **Admission** — the store opens with a finite
//!   [`crate::serving::AdmissionConfig`] sized from the phase plan:
//!   the steady phases fit inside the token budget by construction,
//!   while the overload phase offers several multiples of it, so the
//!   run demonstrates typed `Overloaded` shedding at ≥2× saturation
//!   with the served-read p99 staying bounded.
//! * **Output** — a [`LoadReport`]: per-phase, per-op-class
//!   p50/p99/p999 latency, throughput, and shed rate, printable as
//!   benchkit tables and serializable to `BENCH_load.json` so the perf
//!   trajectory is diffable across PRs (`benches/load_harness.rs` +
//!   the CI artifact upload).

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::benchkit::{fmt_ns, fmt_rate, Table};
use crate::config::Config;
use crate::coordinator::{FeatureStore, OpenOptions};
use crate::monitor::metrics::MetricsSnapshot;
use crate::monitor::trace::TraceConfig;
use crate::query::pit::PitConfig;
use crate::query::spec::FeatureRef;
use crate::serving::AdmissionConfig;
use crate::sim::workload::{ChurnWorkload, ChurnWorkloadConfig};
use crate::stream::{StreamConfig, StreamEvent};
use crate::types::time::DAY;
use crate::types::{FsError, Result, Timestamp};
use crate::util::hist::Histogram;
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};

/// Op-class blend weights for one phase (relative, not percentages).
#[derive(Debug, Clone, Copy)]
pub struct MixWeights {
    /// Batched `get_online_many_mixed` lookups.
    pub read: u32,
    /// Streaming `stream_ingest` batches.
    pub ingest: u32,
    /// Offline PIT `get_training_frame` queries.
    pub pit: u32,
}

impl MixWeights {
    fn pick(&self, rng: &mut Rng) -> OpClass {
        let total = (self.read + self.ingest + self.pit) as u64;
        assert!(total > 0, "phase mix has no weight");
        let roll = rng.below(total) as u32;
        if roll < self.read {
            OpClass::Read
        } else if roll < self.read + self.ingest {
            OpClass::Ingest
        } else {
            OpClass::Pit
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpClass {
    Read,
    Ingest,
    Pit,
}

const CLASSES: [(&str, OpClass); 3] =
    [("read", OpClass::Read), ("ingest", OpClass::Ingest), ("pit", OpClass::Pit)];

/// One workload phase: every worker issues `ops_per_worker` operations
/// drawn from `mix`, pausing `think_us` between ops (0 = closed loop).
#[derive(Debug, Clone)]
pub struct PhaseSpec {
    pub name: String,
    pub ops_per_worker: usize,
    pub mix: MixWeights,
    pub think_us: u64,
}

/// Full harness configuration. [`LoadConfig::standard`] builds the
/// canonical three-phase plan (steady → write-heavy → read-overload)
/// with an admission budget derived from the phase volumes.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub seed: u64,
    /// Zipf exponent for key popularity (0 = uniform, ~1 = web skew).
    pub zipf_s: f64,
    /// Keys per batched read.
    pub read_batch: usize,
    /// Events per ingest batch.
    pub ingest_batch: usize,
    /// Observations per PIT query.
    pub pit_rows: usize,
    /// Concurrent load-generator threads.
    pub workers: usize,
    /// Event-time seconds each ingested event advances the stream.
    pub event_step_secs: i64,
    /// Admission bound on the streamed table's unconsumed backlog.
    pub max_backlog_events: usize,
    pub admission: AdmissionConfig,
    /// Request-tracing policy for the run (the standard plan samples
    /// 1-in-64 so slow ops carry span trees without perturbing the
    /// measured latencies).
    pub trace: TraceConfig,
    pub phases: Vec<PhaseSpec>,
    pub dataset: ChurnWorkloadConfig,
}

impl LoadConfig {
    /// The canonical plan. Sizing contract (what the bench asserts):
    ///
    /// * the pre-overload phases' total read-key demand fits inside
    ///   `tenant_burst` alone, so they shed **zero** regardless of
    ///   wall-clock timing;
    /// * the final read-overload phase offers ~5× the burst in a closed
    ///   loop while the refill rate is a trickle (`burst/50` per
    ///   second), so it sheds typed `Overloaded` on every run.
    pub fn standard(fast: bool) -> LoadConfig {
        let scale = if fast { 1 } else { 8 };
        let workers = 4;
        let read_batch = 16;
        let phases = vec![
            PhaseSpec {
                name: "steady".into(),
                ops_per_worker: 60 * scale,
                mix: MixWeights { read: 8, ingest: 2, pit: 1 },
                think_us: 200,
            },
            PhaseSpec {
                name: "write-heavy".into(),
                ops_per_worker: 40 * scale,
                mix: MixWeights { read: 2, ingest: 8, pit: 0 },
                think_us: 100,
            },
            PhaseSpec {
                name: "read-overload".into(),
                ops_per_worker: 300 * scale,
                mix: MixWeights { read: 1, ingest: 0, pit: 0 },
                think_us: 0,
            },
        ];
        // Key demand of every phase before the overload phase.
        let pre_overload_keys: f64 = phases[..phases.len() - 1]
            .iter()
            .map(|p| {
                let total = (p.mix.read + p.mix.ingest + p.mix.pit) as f64;
                (workers * p.ops_per_worker * read_batch) as f64 * p.mix.read as f64 / total
            })
            .sum();
        let tenant_burst = (pre_overload_keys * 1.2) + read_batch as f64;
        let admission = AdmissionConfig {
            tenant_rate: tenant_burst / 50.0,
            tenant_burst,
            // Per-table budgets stay open: the demonstration bounds the
            // tenant; table buckets are exercised by the property tests.
            max_inflight: 256,
            ..Default::default()
        };
        LoadConfig {
            seed: 42,
            zipf_s: 1.1,
            read_batch,
            ingest_batch: 32,
            pit_rows: 8,
            workers,
            event_step_secs: 5,
            max_backlog_events: 100_000,
            admission,
            trace: TraceConfig { sample_every: 64, ..Default::default() },
            phases,
            dataset: ChurnWorkloadConfig::default(),
        }
    }
}

/// Per-op-class accumulation for one phase.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub issued: u64,
    pub served: u64,
    pub shed: u64,
    /// Latency of served ops, ns.
    pub hist: Histogram,
}

impl Default for ClassReport {
    fn default() -> Self {
        ClassReport { issued: 0, served: 0, shed: 0, hist: Histogram::new() }
    }
}

impl ClassReport {
    pub fn shed_rate(&self) -> f64 {
        if self.issued == 0 {
            0.0
        } else {
            self.shed as f64 / self.issued as f64
        }
    }

    fn merge(&mut self, other: &ClassReport) {
        self.issued += other.issued;
        self.served += other.served;
        self.shed += other.shed;
        self.hist.merge(&other.hist);
    }

    fn to_json(&self, wall_secs: f64) -> Json {
        let q = |p: f64| self.hist.quantile(p) as f64 / 1e3; // ns → µs
        Json::obj(vec![
            ("issued", Json::num(self.issued as f64)),
            ("served", Json::num(self.served as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("mean_us", Json::num(self.hist.mean() / 1e3)),
            ("p50_us", Json::num(q(0.50))),
            ("p99_us", Json::num(q(0.99))),
            ("p999_us", Json::num(q(0.999))),
            ("throughput_per_s", Json::num(self.served as f64 / wall_secs.max(1e-9))),
        ])
    }
}

/// One phase's outcome.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    pub name: String,
    pub wall_secs: f64,
    /// `(class name, stats)` in [`CLASSES`] order.
    pub classes: Vec<(String, ClassReport)>,
    /// What the store's metrics did *during this phase*: the registry
    /// snapshot after minus the snapshot before (counters and latency
    /// counts subtract; gauges keep their end-of-phase value).
    pub metrics_delta: MetricsSnapshot,
}

impl PhaseReport {
    pub fn class(&self, name: &str) -> &ClassReport {
        &self.classes.iter().find(|(n, _)| n == name).expect("known op class").1
    }
}

/// The machine-readable run outcome (`BENCH_load.json`).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub seed: u64,
    pub fast: bool,
    pub phases: Vec<PhaseReport>,
    /// Rendered span trees of every sampled request that crossed the
    /// slow-op threshold during the run (drained from the store after
    /// the final phase; oldest first, ring-bounded).
    pub slow_ops: Vec<String>,
}

impl LoadReport {
    pub fn phase(&self, name: &str) -> &PhaseReport {
        self.phases.iter().find(|p| p.name == name).expect("known phase")
    }

    pub fn to_json(&self) -> Json {
        let phases = self
            .phases
            .iter()
            .map(|p| {
                let classes = p
                    .classes
                    .iter()
                    .filter(|(_, c)| c.issued > 0)
                    .map(|(n, c)| (n.as_str(), c.to_json(p.wall_secs)))
                    .collect();
                Json::obj(vec![
                    ("name", Json::str(p.name.clone())),
                    ("wall_ms", Json::num(p.wall_secs * 1e3)),
                    ("classes", Json::obj(classes)),
                    ("metrics", p.metrics_delta.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("load_harness")),
            ("seed", Json::num(self.seed as f64)),
            ("fast", Json::Bool(self.fast)),
            ("phases", Json::Arr(phases)),
            (
                "slow_ops",
                Json::Arr(self.slow_ops.iter().map(|s| Json::str(s.clone())).collect()),
            ),
        ])
    }

    /// Just the per-phase metrics deltas (the CI artifact uploaded next
    /// to `BENCH_load.json`): `{phase name: snapshot delta}`.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("load_harness_metrics")),
            (
                "phases",
                Json::Obj(
                    self.phases
                        .iter()
                        .map(|p| (p.name.clone(), p.metrics_delta.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Write the per-phase metrics-delta artifact.
    pub fn write_metrics_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.metrics_json()))?;
        Ok(())
    }

    /// Write `BENCH_load.json` (or wherever `path` points).
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    /// Benchkit-style tables, one per phase.
    pub fn print(&self) {
        for p in &self.phases {
            let mut t = Table::new(
                &format!("E-LOAD phase '{}' ({:.2}s)", p.name, p.wall_secs),
                &["class", "issued", "served", "shed", "shed%", "p50", "p99", "p999", "served/s"],
            );
            for (name, c) in &p.classes {
                if c.issued == 0 {
                    continue;
                }
                t.row(&[
                    name.clone(),
                    c.issued.to_string(),
                    c.served.to_string(),
                    c.shed.to_string(),
                    format!("{:.1}%", c.shed_rate() * 100.0),
                    fmt_ns(c.hist.quantile(0.50) as f64),
                    fmt_ns(c.hist.quantile(0.99) as f64),
                    fmt_ns(c.hist.quantile(0.999) as f64),
                    fmt_rate(c.served as f64 / p.wall_secs.max(1e-9)),
                ]);
            }
            t.print();
        }
        if !self.slow_ops.is_empty() {
            println!("E-LOAD slow ops ({} captured, showing up to 5):", self.slow_ops.len());
            for op in self.slow_ops.iter().take(5) {
                print!("{op}");
            }
        }
    }
}

/// A fully-wired store plus the generators that drive it.
pub struct LoadHarness {
    pub fs: Arc<FeatureStore>,
    pub workload: ChurnWorkload,
    cfg: LoadConfig,
    features: Vec<FeatureRef>,
    /// Observation pool PIT queries sample from.
    spine: Vec<(String, Timestamp)>,
    zipf: Zipf,
    home: String,
    /// Global event sequence (seq-deduped downstream, so sharing one
    /// counter across workers keeps every event unique).
    next_seq: AtomicU64,
    /// Shared event-time clock for ingested events.
    event_ts: AtomicI64,
}

impl LoadHarness {
    /// Open a geo-replicated store (background replication + compaction
    /// drivers live), install the churn dataset, batch-materialize the
    /// daily table, and start the streaming engine on the hourly table.
    pub fn setup(cfg: LoadConfig) -> Result<LoadHarness> {
        let fs = FeatureStore::open(
            Config::default_geo(),
            OpenOptions {
                with_engine: false,
                geo_replication: true,
                admission: Some(cfg.admission.clone()),
                trace: cfg.trace.clone(),
                ..Default::default()
            },
        )?;
        let workload = ChurnWorkload::install(&fs, cfg.dataset.clone())?;
        let history_end = cfg.dataset.days * DAY;
        fs.clock.set(history_end);
        // Batch path: materialize the full transaction history.
        fs.materialize_tick(&workload.txn_table)?;
        // Streaming path: the hourly table is fed live by the harness.
        fs.start_stream(
            &workload.interactions_table,
            StreamConfig {
                partitions: 4,
                max_backlog_events: cfg.max_backlog_events,
                ..Default::default()
            },
        )?;
        let features = workload.model_features();
        let spine: Vec<(String, Timestamp)> = workload
            .observation_spine(256)
            .into_iter()
            .map(|(k, ts, _label)| (k, ts))
            .collect();
        let zipf = Zipf::new(cfg.dataset.customers, cfg.zipf_s);
        let home = fs.config.home_region().to_string();
        Ok(LoadHarness {
            fs,
            workload,
            features,
            spine,
            zipf,
            home,
            next_seq: AtomicU64::new(0),
            event_ts: AtomicI64::new(history_end),
            cfg,
        })
    }

    fn run_op(&self, class: OpClass, rng: &mut Rng, stats: &mut [ClassReport; 3]) {
        let slot = match class {
            OpClass::Read => 0,
            OpClass::Ingest => 1,
            OpClass::Pit => 2,
        };
        stats[slot].issued += 1;
        let t0 = Instant::now();
        let outcome = match class {
            OpClass::Read => {
                // Zipf-hot keys across both tables in one mixed batch.
                let keys: Vec<String> = (0..self.cfg.read_batch)
                    .map(|_| format!("cust_{:05}", self.zipf.sample(rng)))
                    .collect();
                let requests: Vec<(&str, &str)> = keys
                    .iter()
                    .enumerate()
                    .map(|(i, k)| {
                        let table = if i % 2 == 0 {
                            self.workload.txn_table.as_str()
                        } else {
                            self.workload.interactions_table.as_str()
                        };
                        (table, k.as_str())
                    })
                    .collect();
                self.fs
                    .get_online_many_mixed(&self.workload.principal, &requests, &self.home)
                    .map(|_| ())
            }
            OpClass::Ingest => {
                let events: Vec<StreamEvent> = (0..self.cfg.ingest_batch)
                    .map(|_| {
                        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
                        let ts =
                            self.event_ts.fetch_add(self.cfg.event_step_secs, Ordering::Relaxed);
                        let key = format!("cust_{:05}", self.zipf.sample(rng));
                        StreamEvent::new(seq, key, ts, rng.f32())
                    })
                    .collect();
                self.fs
                    .stream_ingest(&self.workload.interactions_table, &events)
                    .map(|_| ())
            }
            OpClass::Pit => {
                let obs: Vec<(String, Timestamp)> = (0..self.cfg.pit_rows)
                    .map(|_| self.spine[rng.below(self.spine.len() as u64) as usize].clone())
                    .collect();
                self.fs
                    .get_training_frame(
                        &self.workload.principal,
                        None,
                        &obs,
                        &self.features,
                        PitConfig::default(),
                        &self.home,
                    )
                    .map(|_| ())
            }
        };
        match outcome {
            Ok(()) => {
                stats[slot].served += 1;
                stats[slot].hist.record(t0.elapsed().as_nanos() as u64);
            }
            Err(FsError::Overloaded { .. }) => stats[slot].shed += 1,
            Err(e) => panic!("load harness op failed non-overload: {e}"),
        }
    }

    fn run_phase(&self, idx: usize, phase: &PhaseSpec) -> PhaseReport {
        let before = self.fs.metrics.snapshot();
        let start = Instant::now();
        let merged = std::thread::scope(|s| {
            let handles: Vec<_> = (0..self.cfg.workers)
                .map(|w| {
                    let phase = phase.clone();
                    s.spawn(move || {
                        // Deterministic per-(phase, worker) op stream.
                        let mut rng = Rng::new(
                            self.cfg.seed ^ ((idx as u64) << 32) ^ (w as u64 + 1),
                        );
                        let mut stats: [ClassReport; 3] = Default::default();
                        for _ in 0..phase.ops_per_worker {
                            let class = phase.mix.pick(&mut rng);
                            self.run_op(class, &mut rng, &mut stats);
                            if phase.think_us > 0 {
                                std::thread::sleep(Duration::from_micros(phase.think_us));
                            }
                        }
                        stats
                    })
                })
                .collect();
            let mut merged: [ClassReport; 3] = Default::default();
            for h in handles {
                let stats = h.join().expect("load worker");
                for (m, s) in merged.iter_mut().zip(&stats) {
                    m.merge(s);
                }
            }
            merged
        });
        PhaseReport {
            name: phase.name.clone(),
            wall_secs: start.elapsed().as_secs_f64(),
            classes: CLASSES
                .iter()
                .zip(merged)
                .map(|(&(name, _), c)| (name.to_string(), c))
                .collect(),
            metrics_delta: self.fs.metrics.snapshot().delta(&before),
        }
    }

    /// Execute every phase with the stream poller (and, via the store,
    /// the replication + compaction drivers) running concurrently, then
    /// drain. Returns the per-phase report.
    pub fn run(&self) -> Result<LoadReport> {
        let stop = AtomicBool::new(false);
        let phases = std::thread::scope(|s| {
            // Poller: consumes the streamed table and advances the
            // simulated clock so lag-gated replication delivers.
            let poller = s.spawn(|| {
                while !stop.load(Ordering::Acquire) {
                    let _ = self.fs.poll_stream(&self.workload.interactions_table);
                    self.fs.clock.advance(1);
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
            let phases: Vec<PhaseReport> = self
                .cfg
                .phases
                .iter()
                .enumerate()
                .map(|(i, p)| self.run_phase(i, p))
                .collect();
            stop.store(true, Ordering::Release);
            poller.join().expect("stream poller");
            phases
        });
        self.fs.drain_stream(&self.workload.interactions_table)?;
        let slow_ops = self.fs.slow_ops().iter().map(|t| t.render()).collect();
        Ok(LoadReport {
            seed: self.cfg.seed,
            fast: std::env::var("GEOFS_BENCH_FAST").is_ok(),
            phases,
            slow_ops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> LoadConfig {
        let mut cfg = LoadConfig::standard(true);
        for p in &mut cfg.phases {
            p.ops_per_worker = p.ops_per_worker.min(20);
            p.think_us = 0;
        }
        // Fatten the PIT share so the mixed-phase coverage assertion
        // can't miss at this op count (seeded, so no flake either way).
        cfg.phases[0].mix = MixWeights { read: 2, ingest: 1, pit: 1 };
        cfg.workers = 2;
        cfg.dataset = ChurnWorkloadConfig { customers: 16, days: 3, ..Default::default() };
        cfg
    }

    #[test]
    fn standard_plan_admission_sizing_contract() {
        for fast in [true, false] {
            let cfg = LoadConfig::standard(fast);
            let pre: f64 = cfg.phases[..cfg.phases.len() - 1]
                .iter()
                .map(|p| {
                    let total = (p.mix.read + p.mix.ingest + p.mix.pit) as f64;
                    (cfg.workers * p.ops_per_worker * cfg.read_batch) as f64 * p.mix.read as f64
                        / total
                })
                .sum();
            // Pre-overload demand fits in the burst alone → no shed.
            assert!(pre < cfg.admission.tenant_burst, "fast={fast}");
            // Overload demand is ≥ 2× the burst → guaranteed shed.
            let last = cfg.phases.last().unwrap();
            let overload = (cfg.workers * last.ops_per_worker * cfg.read_batch) as f64
                * last.mix.read as f64
                / (last.mix.read + last.mix.ingest + last.mix.pit) as f64;
            assert!(overload >= 2.0 * cfg.admission.tenant_burst, "fast={fast}");
        }
    }

    #[test]
    fn harness_runs_and_reports() {
        let h = LoadHarness::setup(tiny()).unwrap();
        let r = h.run().unwrap();
        assert_eq!(r.phases.len(), 3);
        // Every issued op was exactly served or shed.
        for p in &r.phases {
            for (_, c) in &p.classes {
                assert_eq!(c.issued, c.served + c.shed, "phase {} conservation", p.name);
            }
            assert!(p.wall_secs > 0.0);
        }
        // The mixed phases actually exercised every class.
        let steady = r.phase("steady");
        assert!(steady.class("read").issued > 0);
        assert!(steady.class("ingest").issued > 0);
        assert!(steady.class("pit").issued > 0);
        // JSON round-trips through the parser with the expected shape.
        let js = r.to_json().to_string();
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("load_harness"));
        assert_eq!(parsed.get("phases").as_arr().unwrap().len(), 3);
        let p0 = &parsed.get("phases").as_arr().unwrap()[0];
        let read = p0.get("classes").get("read");
        for field in ["p50_us", "p99_us", "p999_us", "shed_rate", "throughput_per_s"] {
            assert!(read.get(field).as_f64().is_some(), "missing {field}");
        }
        // Per-phase metrics deltas are embedded: the steady phase serves
        // batches, so its delta must show non-zero serving counters.
        let counters = p0.get("metrics").get("counters");
        assert!(
            counters.get("serving_batches").as_f64().unwrap_or(0.0) > 0.0,
            "steady-phase metrics delta missing serving_batches"
        );
        assert!(parsed.get("slow_ops").as_arr().is_some());
        // The deltas really are per-phase, not cumulative: summed over
        // phases they equal the final counter value.
        let total: f64 = r
            .phases
            .iter()
            .map(|p| *p.metrics_delta.counters.get("serving_batches").unwrap_or(&0) as f64)
            .sum();
        assert_eq!(total as u64, h.fs.metrics.counter("serving_batches"));
        // And the standalone metrics artifact parses with every phase.
        let mj = Json::parse(&r.metrics_json().to_string()).unwrap();
        assert!(mj.get("phases").get("steady").get("counters").as_obj().is_some());
    }

    #[test]
    fn identical_seeds_issue_identical_traffic() {
        // The op sequence (issued counts per class per phase) is a pure
        // function of the seed; shed/latency may differ run to run.
        let a = LoadHarness::setup(tiny()).unwrap().run().unwrap();
        let b = LoadHarness::setup(tiny()).unwrap().run().unwrap();
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            for ((na, ca), (nb, cb)) in pa.classes.iter().zip(&pb.classes) {
                assert_eq!(na, nb);
                assert_eq!(ca.issued, cb.issued, "phase {} class {na}", pa.name);
            }
        }
    }
}
