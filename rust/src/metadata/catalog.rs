//! The metadata store (§3.1.4): CRUD + search over versioned assets,
//! with immutability enforcement (§4.1) and snapshotting for failover.

use std::collections::BTreeMap;
use std::sync::RwLock;

use super::assets::{EntitySpec, FeatureSetSpec, FeatureStoreSpec};
use crate::types::{FsError, Result};

/// Kind tag for search results / lineage nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssetKind {
    FeatureStore,
    Entity,
    FeatureSet,
}

/// Substring + tag search over assets (§1 "Search and reuse features").
#[derive(Debug, Default, Clone)]
pub struct SearchQuery {
    /// Case-insensitive substring over name + description.
    pub text: Option<String>,
    /// All listed tags must be present.
    pub tags: Vec<String>,
    pub kind: Option<AssetKind>,
}

impl SearchQuery {
    pub fn text(s: &str) -> Self {
        SearchQuery { text: Some(s.to_string()), ..Default::default() }
    }
    pub fn tag(s: &str) -> Self {
        SearchQuery { tags: vec![s.to_string()], ..Default::default() }
    }

    fn matches(&self, name: &str, description: &str, tags: &[String], kind: AssetKind) -> bool {
        if let Some(k) = self.kind {
            if k != kind {
                return false;
            }
        }
        if let Some(t) = &self.text {
            let t = t.to_lowercase();
            if !name.to_lowercase().contains(&t) && !description.to_lowercase().contains(&t) {
                return false;
            }
        }
        self.tags.iter().all(|t| tags.contains(t))
    }
}

/// One search hit.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchHit {
    pub kind: &'static str,
    pub name: String,
    pub version: Option<u32>,
    pub store: String,
}

#[derive(Debug, Default)]
struct StoreAssets {
    spec: Option<FeatureStoreSpec>,
    /// (name, version) → entity
    entities: BTreeMap<(String, u32), EntitySpec>,
    /// (name, version) → feature set
    feature_sets: BTreeMap<(String, u32), FeatureSetSpec>,
}

/// Thread-safe metadata catalog for one region's metadata store.
#[derive(Debug, Default)]
pub struct Catalog {
    stores: RwLock<BTreeMap<String, StoreAssets>>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- feature store management (§2.1) ---------------------------------

    pub fn create_store(&self, spec: FeatureStoreSpec) -> Result<()> {
        let mut g = self.stores.write().unwrap();
        if g.contains_key(&spec.name) {
            return Err(FsError::AlreadyExists(format!("feature store '{}'", spec.name)));
        }
        g.insert(spec.name.clone(), StoreAssets { spec: Some(spec), ..Default::default() });
        Ok(())
    }

    pub fn delete_store(&self, name: &str) -> Result<()> {
        self.stores
            .write()
            .unwrap()
            .remove(name)
            .map(|_| ())
            .ok_or_else(|| FsError::NotFound(format!("feature store '{name}'")))
    }

    pub fn get_store(&self, name: &str) -> Result<FeatureStoreSpec> {
        self.stores
            .read()
            .unwrap()
            .get(name)
            .and_then(|s| s.spec.clone())
            .ok_or_else(|| FsError::NotFound(format!("feature store '{name}'")))
    }

    pub fn list_stores(&self) -> Vec<String> {
        self.stores.read().unwrap().keys().cloned().collect()
    }

    // ---- entities ---------------------------------------------------------

    pub fn create_entity(&self, store: &str, spec: EntitySpec) -> Result<()> {
        spec.validate()?;
        let mut g = self.stores.write().unwrap();
        let s = g
            .get_mut(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        let key = (spec.name.clone(), spec.version);
        if s.entities.contains_key(&key) {
            return Err(FsError::AlreadyExists(format!("entity '{}:{}'", key.0, key.1)));
        }
        s.entities.insert(key, spec);
        Ok(())
    }

    pub fn get_entity(&self, store: &str, name: &str, version: u32) -> Result<EntitySpec> {
        let g = self.stores.read().unwrap();
        g.get(store)
            .and_then(|s| s.entities.get(&(name.to_string(), version)).cloned())
            .ok_or_else(|| FsError::NotFound(format!("entity '{name}:{version}' in '{store}'")))
    }

    /// Latest version of an entity.
    pub fn latest_entity(&self, store: &str, name: &str) -> Result<EntitySpec> {
        let g = self.stores.read().unwrap();
        let s = g
            .get(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        s.entities
            .iter()
            .filter(|((n, _), _)| n == name)
            .max_by_key(|((_, v), _)| *v)
            .map(|(_, e)| e.clone())
            .ok_or_else(|| FsError::NotFound(format!("entity '{name}' in '{store}'")))
    }

    // ---- feature sets -----------------------------------------------------

    pub fn create_feature_set(&self, store: &str, spec: FeatureSetSpec) -> Result<()> {
        spec.validate()?;
        let mut g = self.stores.write().unwrap();
        let s = g
            .get_mut(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        // The referenced entity must exist (any version).
        if !s.entities.keys().any(|(n, _)| *n == spec.entity) {
            return Err(FsError::NotFound(format!(
                "entity '{}' referenced by feature set '{}'",
                spec.entity, spec.name
            )));
        }
        let key = (spec.name.clone(), spec.version);
        if s.feature_sets.contains_key(&key) {
            return Err(FsError::AlreadyExists(format!("feature set '{}:{}'", key.0, key.1)));
        }
        s.feature_sets.insert(key, spec);
        Ok(())
    }

    pub fn get_feature_set(&self, store: &str, name: &str, version: u32) -> Result<FeatureSetSpec> {
        let g = self.stores.read().unwrap();
        g.get(store)
            .and_then(|s| s.feature_sets.get(&(name.to_string(), version)).cloned())
            .ok_or_else(|| {
                FsError::NotFound(format!("feature set '{name}:{version}' in '{store}'"))
            })
    }

    pub fn latest_feature_set(&self, store: &str, name: &str) -> Result<FeatureSetSpec> {
        let g = self.stores.read().unwrap();
        let s = g
            .get(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        s.feature_sets
            .iter()
            .filter(|((n, _), _)| n == name)
            .max_by_key(|((_, v), _)| *v)
            .map(|(_, fs)| fs.clone())
            .ok_or_else(|| FsError::NotFound(format!("feature set '{name}' in '{store}'")))
    }

    pub fn list_feature_sets(&self, store: &str) -> Result<Vec<FeatureSetSpec>> {
        let g = self.stores.read().unwrap();
        let s = g
            .get(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        Ok(s.feature_sets.values().cloned().collect())
    }

    /// Update a feature set *in place* — allowed only for mutable
    /// properties (§4.1). Immutable changes must go through
    /// [`Catalog::create_feature_set`] with a bumped version.
    pub fn update_feature_set(&self, store: &str, new: FeatureSetSpec) -> Result<()> {
        new.validate()?;
        let mut g = self.stores.write().unwrap();
        let s = g
            .get_mut(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        let key = (new.name.clone(), new.version);
        let current = s
            .feature_sets
            .get(&key)
            .ok_or_else(|| FsError::NotFound(format!("feature set '{}:{}'", key.0, key.1)))?;
        if let Some(prop) = current.immutable_violation(&new) {
            return Err(FsError::ImmutableProperty {
                asset: format!("feature set '{}:{}'", key.0, key.1),
                prop: prop.to_string(),
            });
        }
        s.feature_sets.insert(key, new);
        Ok(())
    }

    /// Create the next version of a feature set from a (possibly
    /// immutably-changed) spec: version = latest + 1.
    pub fn create_next_version(&self, store: &str, mut spec: FeatureSetSpec) -> Result<u32> {
        let latest = self.latest_feature_set(store, &spec.name)?;
        spec.version = latest.version + 1;
        let v = spec.version;
        self.create_feature_set(store, spec)?;
        Ok(v)
    }

    pub fn delete_feature_set(&self, store: &str, name: &str, version: u32) -> Result<()> {
        let mut g = self.stores.write().unwrap();
        let s = g
            .get_mut(store)
            .ok_or_else(|| FsError::NotFound(format!("feature store '{store}'")))?;
        s.feature_sets
            .remove(&(name.to_string(), version))
            .map(|_| ())
            .ok_or_else(|| FsError::NotFound(format!("feature set '{name}:{version}'")))
    }

    // ---- search (§1, §2.1) -------------------------------------------------

    pub fn search(&self, q: &SearchQuery) -> Vec<SearchHit> {
        let g = self.stores.read().unwrap();
        let mut hits = Vec::new();
        for (store_name, s) in g.iter() {
            if let Some(spec) = &s.spec {
                if q.matches(&spec.name, &spec.description, &spec.tags, AssetKind::FeatureStore) {
                    hits.push(SearchHit {
                        kind: "feature_store",
                        name: spec.name.clone(),
                        version: None,
                        store: store_name.clone(),
                    });
                }
            }
            for e in s.entities.values() {
                if q.matches(&e.name, &e.description, &e.tags, AssetKind::Entity) {
                    hits.push(SearchHit {
                        kind: "entity",
                        name: e.name.clone(),
                        version: Some(e.version),
                        store: store_name.clone(),
                    });
                }
            }
            for fs in s.feature_sets.values() {
                if q.matches(&fs.name, &fs.description, &fs.tags, AssetKind::FeatureSet) {
                    hits.push(SearchHit {
                        kind: "feature_set",
                        name: fs.name.clone(),
                        version: Some(fs.version),
                        store: store_name.clone(),
                    });
                }
            }
        }
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::{SourceSpec, TransformSpec};
    use crate::types::time::Granularity;

    fn catalog_with_store() -> Catalog {
        let c = Catalog::new();
        c.create_store(FeatureStoreSpec::new("fs1", "eastus")).unwrap();
        c.create_entity("fs1", EntitySpec::new("customer", 1, &["customer_id"])).unwrap();
        c
    }

    fn fset(name: &str, version: u32) -> FeatureSetSpec {
        FeatureSetSpec::rolling(
            name,
            version,
            "customer",
            SourceSpec::synthetic(1),
            Granularity::daily(),
            30,
        )
    }

    #[test]
    fn store_crud() {
        let c = catalog_with_store();
        assert_eq!(c.get_store("fs1").unwrap().region, "eastus");
        assert!(matches!(
            c.create_store(FeatureStoreSpec::new("fs1", "westus")),
            Err(FsError::AlreadyExists(_))
        ));
        assert_eq!(c.list_stores(), vec!["fs1"]);
        c.delete_store("fs1").unwrap();
        assert!(c.get_store("fs1").is_err());
    }

    #[test]
    fn feature_set_requires_entity() {
        let c = Catalog::new();
        c.create_store(FeatureStoreSpec::new("fs1", "eastus")).unwrap();
        assert!(matches!(
            c.create_feature_set("fs1", fset("txn", 1)),
            Err(FsError::NotFound(_))
        ));
    }

    #[test]
    fn versioning_and_latest() {
        let c = catalog_with_store();
        c.create_feature_set("fs1", fset("txn", 1)).unwrap();
        c.create_feature_set("fs1", fset("txn", 2)).unwrap();
        assert_eq!(c.latest_feature_set("fs1", "txn").unwrap().version, 2);
        assert_eq!(c.get_feature_set("fs1", "txn", 1).unwrap().version, 1);
    }

    #[test]
    fn immutable_update_rejected_mutable_allowed() {
        let c = catalog_with_store();
        c.create_feature_set("fs1", fset("txn", 1)).unwrap();

        // mutable change: ok
        let mut m = fset("txn", 1);
        m.description = "desc".into();
        m.materialization.schedule_interval_secs *= 2;
        c.update_feature_set("fs1", m).unwrap();
        assert_eq!(c.get_feature_set("fs1", "txn", 1).unwrap().description, "desc");

        // immutable change: rejected with the property name
        let mut im = fset("txn", 1);
        im.transform = TransformSpec::Udf("other".into());
        let err = c.update_feature_set("fs1", im).unwrap_err();
        assert!(matches!(err, FsError::ImmutableProperty { ref prop, .. } if prop == "transform"));
    }

    #[test]
    fn next_version_flow() {
        let c = catalog_with_store();
        c.create_feature_set("fs1", fset("txn", 1)).unwrap();
        let mut changed = fset("txn", 0);
        changed.transform = TransformSpec::Udf("udf2".into());
        let v = c.create_next_version("fs1", changed).unwrap();
        assert_eq!(v, 2);
        assert!(c.get_feature_set("fs1", "txn", 2).unwrap().transform.code().contains("udf2"));
    }

    #[test]
    fn duplicate_version_rejected() {
        let c = catalog_with_store();
        c.create_feature_set("fs1", fset("txn", 1)).unwrap();
        assert!(matches!(
            c.create_feature_set("fs1", fset("txn", 1)),
            Err(FsError::AlreadyExists(_))
        ));
    }

    #[test]
    fn search_by_text_tag_kind() {
        let c = catalog_with_store();
        let mut f = fset("txn_30d", 1);
        f.tags = vec!["churn".into()];
        f.description = "30 day transaction aggregates".into();
        c.create_feature_set("fs1", f).unwrap();

        assert_eq!(c.search(&SearchQuery::text("transaction")).len(), 1);
        assert_eq!(c.search(&SearchQuery::text("TXN")).len(), 1); // case-insensitive
        assert_eq!(c.search(&SearchQuery::tag("churn")).len(), 1);
        assert_eq!(c.search(&SearchQuery::tag("missing")).len(), 0);
        let q = SearchQuery { kind: Some(AssetKind::Entity), ..Default::default() };
        assert_eq!(c.search(&q).len(), 1); // just the entity
        // empty query matches everything (store + entity + fset)
        assert_eq!(c.search(&SearchQuery::default()).len(), 3);
    }

    #[test]
    fn latest_entity_resolution() {
        let c = catalog_with_store();
        c.create_entity("fs1", EntitySpec::new("customer", 3, &["customer_id", "tenant"]))
            .unwrap();
        assert_eq!(c.latest_entity("fs1", "customer").unwrap().version, 3);
    }
}
