//! Asset specifications: feature stores, entities, feature sets.

use crate::types::time::{Granularity, HOUR};
use crate::types::{FsError, Result};
use crate::util::json::Json;

/// Top-level feature store resource (§3.2): a globally-addressable RESTful
/// resource that owns assets and policies.
#[derive(Debug, Clone)]
pub struct FeatureStoreSpec {
    pub name: String,
    /// Home region (assets live where created — §4.1.2).
    pub region: String,
    pub description: String,
    pub tags: Vec<String>,
}

impl FeatureStoreSpec {
    pub fn new(name: &str, region: &str) -> Self {
        FeatureStoreSpec {
            name: name.to_string(),
            region: region.to_string(),
            description: String::new(),
            tags: Vec::new(),
        }
    }
}

/// Entity (§2.2): index/key columns for feature lookup and join.
/// Versioned; `index_columns` is immutable per version.
#[derive(Debug, Clone, PartialEq)]
pub struct EntitySpec {
    pub name: String,
    pub version: u32,
    pub index_columns: Vec<String>,
    pub description: String,
    pub tags: Vec<String>,
}

impl EntitySpec {
    pub fn new(name: &str, version: u32, index_columns: &[&str]) -> Self {
        EntitySpec {
            name: name.to_string(),
            version,
            index_columns: index_columns.iter().map(|s| s.to_string()).collect(),
            description: String::new(),
            tags: Vec::new(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.index_columns.is_empty() {
            return Err(FsError::Schema(format!("entity '{}' has no index columns", self.name)));
        }
        Ok(())
    }
}

/// Where the source data comes from and how late it can arrive.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceSpec {
    /// Connector kind: "synthetic", "jsonl", "csv".
    pub kind: String,
    /// Connector path / seed spec (connector-specific).
    pub path: String,
    /// Timestamp column in the source (documentation; connectors emit it).
    pub timestamp_column: String,
    /// Expected source delay (§4.4): events for time `t` may not be
    /// complete until `t + source_delay_secs`. The PIT query engine and
    /// the scheduler both honor this.
    pub source_delay_secs: i64,
}

impl SourceSpec {
    pub fn synthetic(seed: u64) -> Self {
        SourceSpec {
            kind: "synthetic".into(),
            path: format!("seed://{seed}"),
            timestamp_column: "ts".into(),
            source_delay_secs: 0,
        }
    }
}

/// Transformation (§4.2): either a DSL program the engine can optimize
/// (§3.1.6) or an opaque UDF it must treat as a black box.
#[derive(Debug, Clone, PartialEq)]
pub enum TransformSpec {
    /// DSL text, e.g.
    /// `"rolling(value, window=30d, aggs=[sum,cnt,mean,min,max])"`.
    Dsl(String),
    /// Named built-in UDF executed row-at-a-time by the compute layer
    /// (black box: no plan optimization).
    Udf(String),
}

impl TransformSpec {
    pub fn is_dsl(&self) -> bool {
        matches!(self, TransformSpec::Dsl(_))
    }
    pub fn code(&self) -> &str {
        match self {
            TransformSpec::Dsl(s) | TransformSpec::Udf(s) => s,
        }
    }
}

/// Materialization policy (§4.3) — *mutable* per version.
#[derive(Debug, Clone, PartialEq)]
pub struct MaterializationPolicy {
    pub offline_enabled: bool,
    pub online_enabled: bool,
    /// Cadence of scheduled incremental jobs, seconds of event time per
    /// job window.
    pub schedule_interval_secs: i64,
    /// Online store TTL; must exceed the refresh cadence for Eq. 2's
    /// "assuming TTL satisfies" premise to hold.
    pub online_ttl_secs: i64,
    /// Max bins per job window — the context-aware partitioning unit
    /// (§3.1.1).
    pub max_bins_per_job: i64,
}

impl Default for MaterializationPolicy {
    fn default() -> Self {
        MaterializationPolicy {
            offline_enabled: true,
            online_enabled: true,
            schedule_interval_secs: 24 * HOUR,
            online_ttl_secs: 14 * 24 * HOUR,
            max_bins_per_job: 256,
        }
    }
}

/// Feature set (§2.2): source + transformation + schema + policies.
///
/// Immutable per version: `entity`, `source`, `transform`, `granularity`,
/// `window_bins`, `feature_names` (the transformation defines them).
/// Mutable: `materialization`, `description`, `tags`.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureSetSpec {
    pub name: String,
    pub version: u32,
    /// Entity asset this feature set is keyed by (name; versions of the
    /// entity are resolved at retrieval time).
    pub entity: String,
    pub source: SourceSpec,
    pub transform: TransformSpec,
    /// Aggregation bin width.
    pub granularity: Granularity,
    /// Rolling window length in bins (DSL transforms).
    pub window_bins: usize,
    /// Output feature column names, in order.
    pub feature_names: Vec<String>,
    pub materialization: MaterializationPolicy,
    pub description: String,
    pub tags: Vec<String>,
}

impl FeatureSetSpec {
    /// The canonical rolling feature set over a value column.
    pub fn rolling(
        name: &str,
        version: u32,
        entity: &str,
        source: SourceSpec,
        granularity: Granularity,
        window_bins: usize,
    ) -> Self {
        let window_h = window_bins as i64 * granularity.secs() / HOUR;
        let feature_names = ["sum", "cnt", "mean", "min", "max"]
            .iter()
            .map(|a| format!("{window_h}h_{a}"))
            .collect();
        FeatureSetSpec {
            name: name.to_string(),
            version,
            entity: entity.to_string(),
            source,
            transform: TransformSpec::Dsl(format!(
                "rolling(value, window={window_bins}, aggs=[sum,cnt,mean,min,max])"
            )),
            granularity,
            window_bins,
            feature_names,
            materialization: MaterializationPolicy::default(),
            description: String::new(),
            tags: Vec::new(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.feature_names.is_empty() {
            return Err(FsError::Schema(format!(
                "feature set '{}' defines no feature columns",
                self.name
            )));
        }
        if self.window_bins == 0 {
            return Err(FsError::Schema("window_bins must be >= 1".into()));
        }
        if self.granularity.secs() <= 0 {
            return Err(FsError::Schema("granularity must be positive".into()));
        }
        if self.materialization.online_enabled
            && self.materialization.online_ttl_secs
                < self.materialization.schedule_interval_secs
        {
            return Err(FsError::Schema(
                "online TTL shorter than refresh cadence breaks Eq. 2's latest-record premise"
                    .into(),
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for f in &self.feature_names {
            if !seen.insert(f) {
                return Err(FsError::Schema(format!("duplicate feature column '{f}'")));
            }
        }
        Ok(())
    }

    /// Source lookback per Algorithm 1: enough history to fill the first
    /// output bin's window.
    pub fn source_lookback_secs(&self) -> i64 {
        (self.window_bins as i64 - 1) * self.granularity.secs()
    }

    /// `name:version` asset reference.
    pub fn reference(&self) -> String {
        format!("{}:{}", self.name, self.version)
    }

    /// Check whether changing to `new` mutates an immutable property
    /// (paper §4.1: requires a version bump instead).
    pub fn immutable_violation(&self, new: &FeatureSetSpec) -> Option<&'static str> {
        if self.entity != new.entity {
            return Some("entity");
        }
        if self.source != new.source {
            return Some("source");
        }
        if self.transform != new.transform {
            return Some("transform");
        }
        if self.granularity != new.granularity {
            return Some("granularity");
        }
        if self.window_bins != new.window_bins {
            return Some("window_bins");
        }
        if self.feature_names != new.feature_names {
            return Some("feature_names");
        }
        None
    }

    /// Serialize for metadata snapshots (geo failover).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("version", Json::num(self.version as f64)),
            ("entity", Json::str(&self.entity)),
            ("granularity", Json::num(self.granularity.secs() as f64)),
            ("window_bins", Json::num(self.window_bins as f64)),
            ("transform", Json::str(self.transform.code())),
            ("is_dsl", Json::Bool(self.transform.is_dsl())),
            (
                "features",
                Json::Arr(self.feature_names.iter().map(Json::str).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::DAY;

    fn spec() -> FeatureSetSpec {
        FeatureSetSpec::rolling(
            "txn_30d",
            1,
            "customer",
            SourceSpec::synthetic(1),
            Granularity::daily(),
            30,
        )
    }

    #[test]
    fn rolling_constructor_names_features() {
        let s = spec();
        assert_eq!(s.feature_names[0], "720h_sum");
        assert_eq!(s.feature_names.len(), 5);
        assert!(s.transform.is_dsl());
        assert!(s.validate().is_ok());
        assert_eq!(s.source_lookback_secs(), 29 * DAY);
        assert_eq!(s.reference(), "txn_30d:1");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = spec();
        s.window_bins = 0;
        assert!(s.validate().is_err());

        let mut s = spec();
        s.feature_names.clear();
        assert!(s.validate().is_err());

        let mut s = spec();
        s.feature_names = vec!["a".into(), "a".into()];
        assert!(s.validate().is_err());

        let mut s = spec();
        s.materialization.online_ttl_secs = 1;
        s.materialization.schedule_interval_secs = 100;
        assert!(s.validate().is_err());
    }

    #[test]
    fn immutable_violation_detection() {
        let s = spec();
        let mut changed = s.clone();
        changed.description = "new desc".into(); // mutable
        assert_eq!(s.immutable_violation(&changed), None);
        changed.materialization.schedule_interval_secs *= 2; // mutable
        assert_eq!(s.immutable_violation(&changed), None);

        let mut changed = s.clone();
        changed.transform = TransformSpec::Udf("my_udf".into());
        assert_eq!(s.immutable_violation(&changed), Some("transform"));

        let mut changed = s.clone();
        changed.window_bins = 7;
        assert_eq!(s.immutable_violation(&changed), Some("window_bins"));
    }

    #[test]
    fn entity_validation() {
        assert!(EntitySpec::new("customer", 1, &["customer_id"]).validate().is_ok());
        assert!(EntitySpec::new("bad", 1, &[]).validate().is_err());
    }

    #[test]
    fn json_snapshot_contains_identity() {
        let j = spec().to_json();
        assert_eq!(j.get("name").as_str(), Some("txn_30d"));
        assert_eq!(j.get("window_bins").as_usize(), Some(30));
        assert_eq!(j.get("features").as_arr().unwrap().len(), 5);
    }
}
