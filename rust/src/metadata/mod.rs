//! Asset metadata management and versioning (paper §4.1, Fig 3).
//!
//! A feature store contains versioned *assets* — entities and feature
//! sets — plus store-level policies.  Asset properties are classified
//! mutable vs immutable; changing an immutable property requires a
//! version bump (§4.1).  The catalog provides CRUD + search (§2.1
//! "Feature store asset management") and snapshot/restore for the geo
//! failover path.

pub mod assets;
pub mod catalog;

pub use assets::{
    EntitySpec, FeatureSetSpec, FeatureStoreSpec, MaterializationPolicy, SourceSpec,
    TransformSpec,
};
pub use catalog::{AssetKind, Catalog, SearchQuery};
