//! Seqlock-stamped open-addressing bucket array — the shard interior of
//! the online store. Point and batched reads are **wait-free with
//! respect to writers**: a reader never acquires a lock a writer can
//! hold; it only retries the handful of loads for a bucket a writer is
//! mutating *at that instant*.
//!
//! # Layout
//!
//! A [`SeqlockMap`] is a power-of-two array of [`Bucket`]s (linear
//! probing) plus a fixed **value arena** of `OnceLock<Arc<[f32]>>`
//! slots. Every field a reader touches is an individual atomic, so the
//! whole structure is unsafe-free: a torn read is impossible at the
//! language level — the seqlock stamp exists to make a *composite* read
//! of one bucket's fields atomic, not to paper over UB.
//!
//! * `vidx` encodes occupancy: [`EMPTY`] (never written), [`TOMBSTONE`]
//!   (deleted), else an index into the value arena.
//! * Variable-length feature vectors cannot be stored in atomics, so a
//!   bucket stores only the arena index. A write **never mutates a
//!   published arena slot**: it claims a fresh slot, sets its `OnceLock`
//!   (immutable from then on), and only then points the bucket at it
//!   inside the stamped write. Superseded slots leak until the owning
//!   table is rebuilt (grow / `scale_to`), which starts a fresh arena —
//!   the price of lock-free readers is deferred reclamation.
//!
//! # Writer protocol
//!
//! Writers are serialized per shard by a small `Mutex<WriteSide>` the
//! *caller* holds — readers never touch it. With the mutex held, a
//! mutation of bucket `b` is:
//!
//! ```text
//! s = b.stamp.load(Relaxed)          // even: bucket stable
//! b.stamp.store(s + 1, Relaxed)      // odd: write in progress
//! fence(Release)                     // (W1) stamp=odd precedes data stores
//! b.<fields>.store(.., Relaxed)      // the payload
//! b.stamp.store(s + 2, Release)      // (W2) data stores precede stamp=even
//! ```
//!
//! # Reader protocol
//!
//! ```text
//! loop {
//!   s1 = b.stamp.load(Acquire)       // (R1)
//!   if s1 is odd { retry }
//!   <fields> = b.<fields>.load(Relaxed)
//!   fence(Acquire)                   // (R2) field loads precede the recheck
//!   if b.stamp.load(Relaxed) == s1 { consistent — done }
//! }
//! ```
//!
//! # Why the orderings are sound
//!
//! This is the canonical C11 seqlock (Boehm, *Can seqlocks get along
//! with programming language memory models?*, MSPC'12):
//!
//! * **(R1) Acquire ↔ (W2) Release** on the same stamp word: when a
//!   reader's first load observes the even value a writer published
//!   with (W2), every data store sequenced before (W2) is visible to
//!   the reader's subsequent field loads. A fully-completed write is
//!   therefore read coherently.
//! * **(W1) release fence**: the odd-stamp store cannot be reordered
//!   after the data stores that follow the fence. If a reader's field
//!   loads observe *any* store of an in-progress write, the odd stamp
//!   is already visible, so either (R1) sees it (odd → retry) or the
//!   recheck after (R2) sees a changed stamp (→ retry).
//! * **(R2) acquire fence**: the field loads cannot be reordered after
//!   the recheck load. Without it the recheck could read the stamp
//!   *before* the fields it is supposed to validate, accepting a torn
//!   composite.
//! * The stamp is a u64 advancing by 2 per write — reuse of a stamp
//!   value (ABA) would need 2^63 writes between a reader's two loads.
//! * Arena slots: the `OnceLock::set` is sequenced before the bucket's
//!   `vidx` store inside the stamped section, so a reader that loaded a
//!   consistent `vidx` observes the slot initialized (via the same
//!   (R1)/(W2) pairing). `OnceLock::get() == None` is handled as one
//!   more retry out of caution, not as a reachable state.
//!
//! Writers reading their own shard (version compares, eviction scans,
//! rebuild gathers) hold the write mutex, so plain `Relaxed` loads
//! suffice there — no other writer exists, and readers never store.
//!
//! The companion ThreadSanitizer CI job runs the `online_store` and
//! `geo_fabric` suites under `-Zsanitizer=thread` as a standing
//! detector for regressions in this argument.

use std::sync::atomic::{fence, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::types::{EntityId, FeatureRecord, Timestamp};

/// `vidx` sentinel: bucket has never held an entry. Readers stop
/// probing here — writers never transition a bucket back to `EMPTY`
/// (deletion uses [`TOMBSTONE`]), so a probe chain a concurrent reader
/// is walking can never be cut short by a writer.
pub(crate) const EMPTY: u64 = u64::MAX;
/// `vidx` sentinel: entry deleted. Readers skip over it (the chain
/// continues); writers may reuse it for a *new* key on insert.
pub(crate) const TOMBSTONE: u64 = u64::MAX - 1;

/// One open-addressing slot. All fields are individual atomics; the
/// stamp makes their composite read atomic (module docs).
#[derive(Debug)]
struct Bucket {
    /// Even = stable, odd = write in progress; +2 per completed write.
    stamp: AtomicU64,
    entity: AtomicU64,
    event_ts: AtomicI64,
    creation_ts: AtomicI64,
    /// Processing-timeline write moment; TTL expiry is measured from
    /// here (read-time filter + eviction).
    written_at: AtomicI64,
    /// [`EMPTY`], [`TOMBSTONE`], or an index into the value arena.
    vidx: AtomicU64,
}

impl Bucket {
    fn new() -> Bucket {
        Bucket {
            stamp: AtomicU64::new(0),
            entity: AtomicU64::new(0),
            event_ts: AtomicI64::new(0),
            creation_ts: AtomicI64::new(0),
            written_at: AtomicI64::new(0),
            vidx: AtomicU64::new(EMPTY),
        }
    }
}

/// Writer-side bookkeeping for one shard, guarded by the shard's write
/// mutex (owned by the caller — `online_store::SeqShard`). Readers
/// never look at this.
#[derive(Debug, Default)]
pub(crate) struct WriteSide {
    /// Next never-used arena slot.
    pub arena_next: usize,
    /// Occupied buckets: live entries **plus tombstones** (both lengthen
    /// probe chains; only a rebuild reclaims tombstones).
    pub used: usize,
}

/// A consistent composite read of one live bucket. `values` is the
/// shared arena allocation — cloning the `Arc` is the only per-read
/// refcount traffic.
#[derive(Debug, Clone)]
pub(crate) struct ReadHit {
    pub event_ts: Timestamp,
    pub creation_ts: Timestamp,
    pub written_at: Timestamp,
    pub values: Arc<[f32]>,
}

/// One consistent bucket observation.
enum Slot {
    Empty,
    Tombstone,
    Full { entity: EntityId, event_ts: Timestamp, creation_ts: Timestamp, written_at: Timestamp, vidx: u64 },
}

/// Outcome of a writer's Algorithm-2 apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Applied {
    /// Inserted or overrode (the record now owns the entity's slot).
    Inserted,
    /// Version `<=` existing — no-op.
    Skipped,
}

/// Arena slots per bucket: overrides consume fresh slots without
/// consuming buckets, so the arena is sized ahead of the bucket array.
const ARENA_FACTOR: usize = 2;

/// Fixed-capacity seqlock bucket array. Capacity decisions (growth) are
/// the owner's job: writers must call [`SeqlockMap::has_room`] before a
/// batch and rebuild the map into a larger one when it says no.
#[derive(Debug)]
pub(crate) struct SeqlockMap {
    buckets: Box<[Bucket]>,
    /// `buckets.len() - 1` (power-of-two sizing).
    mask: usize,
    /// Value arena; slots are claimed in order and immutable once set.
    values: Box<[OnceLock<Arc<[f32]>>]>,
    /// Resident entries (including TTL-expired-not-yet-evicted) —
    /// readers' `len` without any lock.
    live: AtomicUsize,
}

impl SeqlockMap {
    /// A map with room for at least `expected` live entries plus the
    /// same again in tombstones/overrides before a rebuild is needed.
    pub fn with_room_for(expected: usize) -> SeqlockMap {
        let cap = (expected.max(4) * 2).next_power_of_two();
        SeqlockMap {
            buckets: (0..cap).map(|_| Bucket::new()).collect(),
            mask: cap - 1,
            values: (0..cap * ARENA_FACTOR).map(|_| OnceLock::new()).collect(),
            live: AtomicUsize::new(0),
        }
    }

    pub fn live(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// Max occupied buckets: keep ≥ 1/4 of the array `EMPTY` so probe
    /// chains stay short and reader probes always terminate.
    fn max_used(&self) -> usize {
        let cap = self.buckets.len();
        cap - cap / 4
    }

    /// Home bucket. The *high* hash bits index buckets: the caller
    /// already spent the low bits on `hash % n_shards`, and reusing them
    /// here would cluster every key of a shard into a fraction of its
    /// buckets whenever the shard count shares factors with the
    /// capacity.
    fn home(&self, hash: u64) -> usize {
        (hash >> 32) as usize & self.mask
    }

    /// Can a writer apply a batch of `incoming` records without
    /// overrunning buckets or arena? Conservative: counts every record
    /// as a fresh insert + fresh arena slot. Callers check this under
    /// the write mutex before applying and trigger a rebuild on `false`.
    pub fn has_room(&self, ws: &WriteSide, incoming: usize) -> bool {
        ws.used + incoming <= self.max_used() && ws.arena_next + incoming <= self.values.len()
    }

    // ---- reader side (no locks, ever) --------------------------------

    /// One consistent observation of bucket `i` (spins only while a
    /// writer is mid-write on this very bucket).
    fn load_bucket(&self, i: usize) -> Slot {
        let b = &self.buckets[i];
        loop {
            let s1 = b.stamp.load(Ordering::Acquire); // (R1)
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let vidx = b.vidx.load(Ordering::Relaxed);
            let entity = b.entity.load(Ordering::Relaxed);
            let event_ts = b.event_ts.load(Ordering::Relaxed);
            let creation_ts = b.creation_ts.load(Ordering::Relaxed);
            let written_at = b.written_at.load(Ordering::Relaxed);
            fence(Ordering::Acquire); // (R2)
            if b.stamp.load(Ordering::Relaxed) != s1 {
                std::hint::spin_loop();
                continue;
            }
            return match vidx {
                EMPTY => Slot::Empty,
                TOMBSTONE => Slot::Tombstone,
                _ => Slot::Full { entity, event_ts, creation_ts, written_at, vidx },
            };
        }
    }

    /// Arena fetch for a consistently-observed `vidx`. `None` only under
    /// the theoretical publish race the module docs rule out — treated
    /// as "retry the bucket".
    fn value(&self, vidx: u64) -> Option<Arc<[f32]>> {
        self.values[vidx as usize].get().cloned()
    }

    /// Wait-free point read. `hash` is the caller's avalanched entity
    /// hash (also used for shard routing).
    pub fn read(&self, entity: EntityId, hash: u64) -> Option<ReadHit> {
        let cap = self.buckets.len();
        let mut i = self.home(hash);
        for _ in 0..cap {
            match self.load_bucket(i) {
                Slot::Empty => return None,
                Slot::Tombstone => {}
                Slot::Full { entity: e, event_ts, creation_ts, written_at, vidx } => {
                    if e == entity {
                        match self.value(vidx) {
                            Some(values) =>
                                return Some(ReadHit { event_ts, creation_ts, written_at, values }),
                            None => continue, // re-observe this bucket
                        }
                    }
                }
            }
            i = (i + 1) & self.mask;
        }
        None
    }

    /// Visit every resident entry (including TTL-expired ones) with a
    /// per-bucket-consistent observation. Concurrent writers make this a
    /// *per-bucket* snapshot, not a map-wide one — callers that need a
    /// quiescent view (rebuilds) exclude writers first.
    pub fn for_each_resident(&self, mut f: impl FnMut(EntityId, ReadHit)) {
        for i in 0..self.buckets.len() {
            loop {
                match self.load_bucket(i) {
                    Slot::Full { entity, event_ts, creation_ts, written_at, vidx } => {
                        match self.value(vidx) {
                            Some(values) => {
                                f(entity, ReadHit { event_ts, creation_ts, written_at, values });
                                break;
                            }
                            None => continue,
                        }
                    }
                    _ => break,
                }
            }
        }
    }

    // ---- writer side (caller holds the shard write mutex) ------------

    fn begin_write(b: &Bucket) -> u64 {
        let s = b.stamp.load(Ordering::Relaxed);
        debug_assert_eq!(s & 1, 0, "write mutex must serialize writers");
        b.stamp.store(s.wrapping_add(1), Ordering::Relaxed);
        fence(Ordering::Release); // (W1)
        s
    }

    fn end_write(b: &Bucket, s: u64) {
        b.stamp.store(s.wrapping_add(2), Ordering::Release); // (W2)
    }

    /// Claim a fresh arena slot for `values`.
    fn alloc_value(&self, ws: &mut WriteSide, values: &[f32]) -> u64 {
        let vi = ws.arena_next;
        ws.arena_next += 1;
        self.values[vi]
            .set(Arc::from(values))
            .expect("arena slots are claimed exactly once");
        vi as u64
    }

    /// Algorithm 2 (online branch) for one record. The caller holds the
    /// shard write mutex and has verified [`Self::has_room`] for the
    /// batch this record belongs to.
    pub fn apply(&self, ws: &mut WriteSide, hash: u64, r: &FeatureRecord, now: Timestamp) -> Applied {
        let cap = self.buckets.len();
        let mut i = self.home(hash);
        let mut reusable: Option<usize> = None;
        for _ in 0..cap {
            let b = &self.buckets[i];
            // Plain loads: fields only change under the mutex we hold.
            let vidx = b.vidx.load(Ordering::Relaxed);
            if vidx == EMPTY {
                self.insert_at(ws, reusable.unwrap_or(i), r, now);
                return Applied::Inserted;
            }
            if vidx == TOMBSTONE {
                reusable.get_or_insert(i);
            } else if b.entity.load(Ordering::Relaxed) == r.entity {
                let existing = (b.event_ts.load(Ordering::Relaxed), b.creation_ts.load(Ordering::Relaxed));
                if r.version() <= existing {
                    return Applied::Skipped;
                }
                // Override in place: fresh arena slot, stamped swap.
                let vi = self.alloc_value(ws, &r.values);
                let s = Self::begin_write(b);
                b.event_ts.store(r.event_ts, Ordering::Relaxed);
                b.creation_ts.store(r.creation_ts, Ordering::Relaxed);
                b.written_at.store(now, Ordering::Relaxed);
                b.vidx.store(vi, Ordering::Relaxed);
                Self::end_write(b, s);
                return Applied::Inserted;
            }
            i = (i + 1) & self.mask;
        }
        unreachable!("has_room keeps ≥ cap/4 buckets EMPTY, so probes terminate");
    }

    fn insert_at(&self, ws: &mut WriteSide, i: usize, r: &FeatureRecord, now: Timestamp) {
        let vi = self.alloc_value(ws, &r.values);
        let b = &self.buckets[i];
        if b.vidx.load(Ordering::Relaxed) == EMPTY {
            ws.used += 1; // tombstone reuse keeps `used` flat
        }
        let s = Self::begin_write(b);
        b.entity.store(r.entity, Ordering::Relaxed);
        b.event_ts.store(r.event_ts, Ordering::Relaxed);
        b.creation_ts.store(r.creation_ts, Ordering::Relaxed);
        b.written_at.store(now, Ordering::Relaxed);
        b.vidx.store(vi, Ordering::Relaxed);
        Self::end_write(b, s);
        self.live.fetch_add(1, Ordering::Relaxed);
    }

    /// Build-time insert of a gathered entry (rebuild / `scale_to`).
    /// Caller guarantees unique entities and a map sized by
    /// [`Self::with_room_for`]; the map is not yet published, so there
    /// is no contention — the stamp protocol is kept for uniformity.
    pub fn seed(
        &self,
        ws: &mut WriteSide,
        entity: EntityId,
        hash: u64,
        hit: &ReadHit,
    ) {
        let vi = ws.arena_next;
        ws.arena_next += 1;
        self.values[vi]
            .set(hit.values.clone())
            .expect("arena slots are claimed exactly once");
        let cap = self.buckets.len();
        let mut i = self.home(hash);
        for _ in 0..cap {
            let b = &self.buckets[i];
            if b.vidx.load(Ordering::Relaxed) == EMPTY {
                ws.used += 1;
                let s = Self::begin_write(b);
                b.entity.store(entity, Ordering::Relaxed);
                b.event_ts.store(hit.event_ts, Ordering::Relaxed);
                b.creation_ts.store(hit.creation_ts, Ordering::Relaxed);
                b.written_at.store(hit.written_at, Ordering::Relaxed);
                b.vidx.store(vi as u64, Ordering::Relaxed);
                Self::end_write(b, s);
                self.live.fetch_add(1, Ordering::Relaxed);
                return;
            }
            i = (i + 1) & self.mask;
        }
        unreachable!("with_room_for sized the rebuild target");
    }

    /// Tombstone every entry whose TTL elapsed. Returns entries
    /// reclaimed. Arena slots are *not* reclaimed (rebuild-only); the
    /// caller holds the write mutex.
    pub fn tombstone_expired(&self, _ws: &mut WriteSide, ttl: i64, now: Timestamp) -> u64 {
        let mut n = 0;
        for b in self.buckets.iter() {
            let vidx = b.vidx.load(Ordering::Relaxed);
            if vidx == EMPTY || vidx == TOMBSTONE {
                continue;
            }
            if now - b.written_at.load(Ordering::Relaxed) >= ttl {
                let s = Self::begin_write(b);
                b.vidx.store(TOMBSTONE, Ordering::Relaxed);
                Self::end_write(b, s);
                self.live.fetch_sub(1, Ordering::Relaxed);
                n += 1;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::online_store::hash_of;

    fn rec(entity: u64, event: i64, created: i64, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn insert_read_override_skip() {
        let m = SeqlockMap::with_room_for(8);
        let mut ws = WriteSide::default();
        assert!(m.read(1, hash_of(1)).is_none());
        assert_eq!(m.apply(&mut ws, hash_of(1), &rec(1, 10, 20, 1.0), 100), Applied::Inserted);
        let hit = m.read(1, hash_of(1)).unwrap();
        assert_eq!((hit.event_ts, hit.creation_ts, hit.written_at), (10, 20, 100));
        assert_eq!(&hit.values[..], &[1.0]);
        // Stale version skips, fresher overrides.
        assert_eq!(m.apply(&mut ws, hash_of(1), &rec(1, 9, 99, 9.0), 101), Applied::Skipped);
        assert_eq!(m.apply(&mut ws, hash_of(1), &rec(1, 10, 30, 2.0), 102), Applied::Inserted);
        assert_eq!(&m.read(1, hash_of(1)).unwrap().values[..], &[2.0]);
        assert_eq!(m.live(), 1);
    }

    #[test]
    fn tombstone_then_reuse_keeps_chains_walkable() {
        let m = SeqlockMap::with_room_for(16);
        let mut ws = WriteSide::default();
        for e in 0..10u64 {
            m.apply(&mut ws, hash_of(e), &rec(e, 1, 1, e as f32), 0);
        }
        assert_eq!(m.tombstone_expired(&mut ws, 10, 100), 10);
        assert_eq!(m.live(), 0);
        for e in 0..10u64 {
            assert!(m.read(e, hash_of(e)).is_none(), "{e}");
        }
        // Reinsert through the tombstones.
        for e in 0..10u64 {
            m.apply(&mut ws, hash_of(e), &rec(e, 2, 2, -(e as f32)), 200);
        }
        for e in 0..10u64 {
            assert_eq!(&m.read(e, hash_of(e)).unwrap().values[..], &[-(e as f32)]);
        }
        assert_eq!(m.live(), 10);
    }

    #[test]
    fn has_room_is_conservative_and_resident_scan_sees_all() {
        let m = SeqlockMap::with_room_for(4);
        let mut ws = WriteSide::default();
        let mut inserted = 0u64;
        while m.has_room(&ws, 1) {
            m.apply(&mut ws, hash_of(inserted), &rec(inserted, 1, 1, 0.0), 0);
            inserted += 1;
        }
        assert!(inserted >= 4, "sized for at least the requested room");
        let mut seen = Vec::new();
        m.for_each_resident(|e, _| seen.push(e));
        seen.sort_unstable();
        assert_eq!(seen, (0..inserted).collect::<Vec<_>>());
    }
}
