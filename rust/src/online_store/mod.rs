//! Online store (§3.1.4): low-latency sink, Redis-equivalent substrate.
//!
//! Per Eq. 2 the online store keeps, for each entity, only the record
//! with `max(tuple(event_ts, creation_ts))`, "assuming TTL satisfies".
//! The merge follows Algorithm 2's online branch exactly:
//!
//! * key absent → insert
//! * new event_ts > existing → override
//! * equal event_ts and new creation_ts > existing → override
//! * otherwise → no-op
//!
//! # Concurrency design (the serving hot path)
//!
//! The store is an immutable-snapshot + sharded-lock design, built so
//! point reads never acquire a store-global lock:
//!
//! * All shard state lives in one [`ShardSet`] behind an `Arc`. Readers
//!   obtain the current `Arc` via a **generation-stamped thread-local
//!   cache**: a `get`/`get_many` does one atomic generation load and (on
//!   the fast path) zero shared-lock acquisitions before touching its
//!   single target shard's `RwLock`. Only when the generation changed
//!   (a `scale_to`/`set_ttl` swapped the set — rare) does a reader take
//!   the small `current` mutex once to refresh its cached `Arc`.
//! * Writers (`merge`, `evict_expired`) share an `admin` read lock —
//!   they run concurrently with each other and with all readers, taking
//!   only per-shard write locks. `scale_to`/`set_ttl` take the `admin`
//!   write lock, build a **new** `ShardSet` (rehash/ttl-update), and
//!   atomically publish it; readers still holding the old `Arc` keep
//!   reading the pre-swap snapshot (linearizable: the scale is a
//!   data-preserving no-op), then pick up the new set on their next
//!   operation via the generation check.
//! * TTL sweep (`evict_expired`) locks one shard at a time, so readers
//!   of other shards are never blocked; expired entries are filtered at
//!   read time regardless, so a sweep is pure space reclamation.
//! * Shard maps are nested `table → entity → entry`, so lookups never
//!   allocate a `(String, EntityId)` key; `get_many` groups keys by
//!   shard and takes each shard lock exactly once per batch.
//!
//! `hits`/`misses` stay plain atomic counters. Sharded like a Redis
//! cluster; `scale_to` rebalances shards online (§3.1.3 "scale up or
//! down the managed resources like Redis") without blocking readers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::offline_store::MergeStats;
use crate::types::{EntityId, FeatureRecord, FsError, Result, Timestamp};

/// Per-table entry: the single latest record (Eq. 2) + TTL bookkeeping.
#[derive(Debug, Clone)]
struct Entry {
    record: FeatureRecord,
    /// Wall-clock (processing timeline) moment this entry was last
    /// written; TTL expiry is measured from here, like a Redis SET with
    /// EXPIRE.
    written_at: Timestamp,
}

/// table name → entity → entry. Nested so the read path can look up
/// with `&str` (no per-read key allocation).
type TableMap = HashMap<String, HashMap<EntityId, Entry>>;

/// One shard: an independently locked slice of the key space.
type Shard = RwLock<TableMap>;

/// The immutable-topology snapshot readers operate on. The `shards`
/// vector and `ttls` map never change inside a published `ShardSet`;
/// only shard *contents* (behind per-shard locks) do.
#[derive(Debug)]
struct ShardSet {
    /// Monotonic publish counter; compared against the store's atomic
    /// generation by the thread-local snapshot cache.
    generation: u64,
    /// Shared across TTL-only swaps (`set_ttl` republishes the same
    /// shard vector with a new TTL table).
    shards: Arc<Vec<Shard>>,
    /// TTL per table (seconds on the processing timeline); absent = ∞.
    ttls: HashMap<String, i64>,
}

impl ShardSet {
    fn ttl_of(&self, table: &str) -> i64 {
        self.ttls.get(table).copied().unwrap_or(i64::MAX)
    }
}

fn live(e: &Entry, ttl: i64, now: Timestamp) -> bool {
    ttl == i64::MAX || now - e.written_at < ttl
}

/// splitmix-style avalanche so sequential ids spread across shards.
fn shard_of(entity: EntityId, n: usize) -> usize {
    let mut x = entity.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    (x ^ (x >> 31)) as usize % n
}

/// Process-unique store ids for the thread-local snapshot cache.
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread `(store_id, snapshot)` cache. Entries are `Weak` so
    /// an idle thread never pins a dropped store or a superseded
    /// (pre-scale) shard set — once the store publishes a new set, the
    /// old one is freed as soon as in-flight readers finish, not when
    /// every thread happens to touch the store again. Bounded FIFO.
    static SNAPSHOT_CACHE: RefCell<Vec<(u64, Weak<ShardSet>)>> = const { RefCell::new(Vec::new()) };
}

const SNAPSHOT_CACHE_CAP: usize = 8;

/// Sharded in-process KV store with lock-free snapshot reads.
#[derive(Debug)]
pub struct OnlineStore {
    store_id: u64,
    /// Generation of the currently published [`ShardSet`]; bumped with
    /// `Release` on every publish, read with `Acquire` by readers.
    generation: AtomicU64,
    /// Slow-path source of truth: held only long enough to clone/swap
    /// the `Arc` — never across a map access or a rehash.
    current: Mutex<Arc<ShardSet>>,
    /// Writer/topology coordination: `merge`/`evict_expired` take read
    /// (concurrent), `scale_to`/`set_ttl` take write (exclusive), and
    /// the read path takes nothing.
    admin: RwLock<()>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl Default for OnlineStore {
    fn default() -> Self {
        Self::new(8)
    }
}

impl OnlineStore {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0);
        let set = ShardSet {
            generation: 0,
            shards: Arc::new((0..shards).map(|_| RwLock::new(HashMap::new())).collect()),
            ttls: HashMap::new(),
        };
        OnlineStore {
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            generation: AtomicU64::new(0),
            current: Mutex::new(Arc::new(set)),
            admin: RwLock::new(()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Current snapshot. Fast path: one atomic load + thread-local hit.
    /// Slow path (first use on this thread, or after a topology/TTL
    /// publish): one brief `current` mutex lock to clone the `Arc`.
    fn snapshot(&self) -> Arc<ShardSet> {
        let gen = self.generation.load(Ordering::Acquire);
        let hit = SNAPSHOT_CACHE.with(|c| {
            c.borrow()
                .iter()
                .find(|(id, _)| *id == self.store_id)
                .and_then(|(_, w)| w.upgrade())
                .filter(|s| s.generation == gen)
        });
        if let Some(s) = hit {
            return s;
        }
        let fresh = self.current.lock().unwrap().clone();
        SNAPSHOT_CACHE.with(|c| {
            let mut c = c.borrow_mut();
            c.retain(|(id, _)| *id != self.store_id);
            if c.len() >= SNAPSHOT_CACHE_CAP {
                c.remove(0);
            }
            c.push((self.store_id, Arc::downgrade(&fresh)));
        });
        fresh
    }

    /// Publish a new shard set. Caller must hold the `admin` write lock.
    fn publish(&self, set: ShardSet) {
        let gen = set.generation;
        *self.current.lock().unwrap() = Arc::new(set);
        self.generation.store(gen, Ordering::Release);
    }

    pub fn shard_count(&self) -> usize {
        self.snapshot().shards.len()
    }

    /// Set a table's TTL. Publishes a new snapshot sharing the same
    /// shard vector (no data is touched or copied).
    pub fn set_ttl(&self, table: &str, ttl_secs: i64) {
        let _topology = self.admin.write().unwrap();
        let old = self.current.lock().unwrap().clone();
        let mut ttls = old.ttls.clone();
        ttls.insert(table.to_string(), ttl_secs);
        self.publish(ShardSet {
            generation: old.generation + 1,
            shards: old.shards.clone(),
            ttls,
        });
    }

    /// Algorithm 2 (online branch). `now` is the processing-timeline
    /// write moment (drives TTL). Records are grouped by shard so each
    /// shard's write lock is taken once per batch.
    pub fn merge(&self, table: &str, records: &[FeatureRecord], now: Timestamp) -> MergeStats {
        let mut stats = MergeStats::default();
        if records.is_empty() {
            return stats;
        }
        let _writers = self.admin.read().unwrap();
        let set = self.snapshot();
        let n = set.shards.len();
        if let [r] = records {
            // Point-upsert fast path: no grouping allocation.
            let mut shard = set.shards[shard_of(r.entity, n)].write().unwrap();
            let tm = Self::table_map(&mut shard, table);
            Self::apply(tm, r, now, &mut stats);
            return stats;
        }
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, r) in records.iter().enumerate() {
            by_shard[shard_of(r.entity, n)].push(i);
        }
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let mut shard = set.shards[s].write().unwrap();
            let tm = Self::table_map(&mut shard, table);
            for &i in idxs {
                Self::apply(tm, &records[i], now, &mut stats);
            }
        }
        stats
    }

    /// Merge a sequence of `(table, records)` batches, coalescing per
    /// table (first-seen order, single batches applied in place) into
    /// **one** shard-grouped [`OnlineStore::merge`] per table — the
    /// write-side analogue of `get_many`'s lock amortization, shared by
    /// the replication pumps and the serving write batcher. Alg 2 is
    /// order-independent-convergent and the concatenation preserves
    /// batch order, so the converged state equals per-batch application.
    pub fn merge_batches(
        &self,
        batches: &[(&str, &[FeatureRecord])],
        now: Timestamp,
    ) -> MergeStats {
        let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
        for (i, &(table, _)) in batches.iter().enumerate() {
            match groups.iter_mut().find(|(t, _)| *t == table) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((table, vec![i])),
            }
        }
        let mut stats = MergeStats::default();
        for (table, idxs) in &groups {
            if let &[i] = &idxs[..] {
                stats.add(self.merge(table, batches[i].1, now));
            } else {
                let mut records: Vec<FeatureRecord> =
                    Vec::with_capacity(idxs.iter().map(|&i| batches[i].1.len()).sum());
                for &i in idxs {
                    records.extend_from_slice(batches[i].1);
                }
                stats.add(self.merge(table, &records, now));
            }
        }
        stats
    }

    /// The table's entity map in `shard`, created on first write. Keyed
    /// by `&str` first so the steady-state write path (table already
    /// present) never allocates the table key — which is why the
    /// `entry` API (and clippy's map_entry shape) is deliberately
    /// avoided here.
    #[allow(clippy::map_entry)]
    fn table_map<'a>(shard: &'a mut TableMap, table: &str) -> &'a mut HashMap<EntityId, Entry> {
        if !shard.contains_key(table) {
            shard.insert(table.to_string(), HashMap::new());
        }
        shard.get_mut(table).expect("just ensured present")
    }

    fn apply(
        tm: &mut HashMap<EntityId, Entry>,
        r: &FeatureRecord,
        now: Timestamp,
        stats: &mut MergeStats,
    ) {
        match tm.get(&r.entity) {
            Some(e) if r.version() <= e.record.version() => stats.skipped += 1,
            _ => {
                tm.insert(r.entity, Entry { record: r.clone(), written_at: now });
                stats.inserted += 1;
            }
        }
    }

    /// Low-latency point lookup. Returns `None` for absent or TTL-expired
    /// entries — the caller distinguishes "not materialized" vs "no data"
    /// through the scheduler's data-state (§4.3). Acquires no
    /// store-global lock: one atomic load + one shard read lock.
    pub fn get(&self, table: &str, entity: EntityId, now: Timestamp) -> Option<FeatureRecord> {
        let set = self.snapshot();
        let ttl = set.ttl_of(table);
        let out = {
            let shard = set.shards[shard_of(entity, set.shards.len())].read().unwrap();
            shard
                .get(table)
                .and_then(|tm| tm.get(&entity))
                .filter(|e| live(e, ttl, now))
                .map(|e| e.record.clone())
        };
        match &out {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Batched lookup (the serving batcher's unit of work): keys are
    /// grouped by shard and each shard lock is taken exactly once, with
    /// one TTL resolution for the whole batch. Result order matches the
    /// input; `get_many(t, ks)[i] == get(t, ks[i])` for all `i`.
    pub fn get_many(
        &self,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
    ) -> Vec<Option<FeatureRecord>> {
        if entities.is_empty() {
            return Vec::new();
        }
        let set = self.snapshot();
        let n = set.shards.len();
        let ttl = set.ttl_of(table);
        let mut out: Vec<Option<FeatureRecord>> = vec![None; entities.len()];
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, &e) in entities.iter().enumerate() {
            by_shard[shard_of(e, n)].push(i);
        }
        let (mut hits, mut misses) = (0u64, 0u64);
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let shard = set.shards[s].read().unwrap();
            match shard.get(table) {
                None => misses += idxs.len() as u64,
                Some(tm) => {
                    for &i in idxs {
                        match tm.get(&entities[i]).filter(|e| live(e, ttl, now)) {
                            Some(e) => {
                                out[i] = Some(e.record.clone());
                                hits += 1;
                            }
                            None => misses += 1,
                        }
                    }
                }
            }
        }
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.misses.fetch_add(misses, Ordering::Relaxed);
        out
    }

    /// Everything currently live in a table — the online→offline
    /// bootstrap read (§4.5.5).
    pub fn dump_table(&self, table: &str, now: Timestamp) -> Vec<FeatureRecord> {
        let set = self.snapshot();
        let ttl = set.ttl_of(table);
        let mut out = Vec::new();
        for s in set.shards.iter() {
            let shard = s.read().unwrap();
            if let Some(tm) = shard.get(table) {
                out.extend(tm.values().filter(|e| live(e, ttl, now)).map(|e| e.record.clone()));
            }
        }
        out.sort_by_key(|r| r.entity);
        out
    }

    /// Drop TTL-expired entries (Redis does this lazily + actively; we
    /// expose it so tests and the freshness monitor can force it). Locks
    /// one shard at a time — readers of other shards are unaffected and
    /// readers never see expired data regardless (read-time filter).
    pub fn evict_expired(&self, now: Timestamp) -> u64 {
        let _writers = self.admin.read().unwrap();
        let set = self.snapshot();
        let mut evicted = 0;
        for s in set.shards.iter() {
            let mut shard = s.write().unwrap();
            for (table, tm) in shard.iter_mut() {
                let ttl = set.ttl_of(table);
                if ttl == i64::MAX {
                    continue;
                }
                tm.retain(|_, e| {
                    let keep = live(e, ttl, now);
                    if !keep {
                        evicted += 1;
                    }
                    keep
                });
            }
            shard.retain(|_, tm| !tm.is_empty());
        }
        evicted
    }

    /// Scale to `n` shards, rehashing all entries (§3.1.3). Writers are
    /// paused for the rebalance (the `admin` write lock), but readers
    /// are **never** blocked: they keep serving the pre-scale snapshot
    /// until the new shard set is published, then switch over via the
    /// generation check on their next operation.
    pub fn scale_to(&self, n: usize) -> Result<()> {
        if n == 0 {
            return Err(FsError::InvalidArg("shard count must be > 0".into()));
        }
        let _topology = self.admin.write().unwrap();
        let old = self.current.lock().unwrap().clone();
        // The new maps are private to this call until published, so the
        // rehash takes no destination locks at all. Entries are cloned
        // (not drained) so in-flight readers of the old set stay
        // coherent; per (old shard, table) the entries are bucketed by
        // destination first, so each table key is cloned per bucket,
        // not per entry.
        let mut new_maps: Vec<TableMap> = (0..n).map(|_| HashMap::new()).collect();
        for s in old.shards.iter() {
            // Writers are excluded by the admin write lock; concurrent
            // readers share these read locks.
            let shard = s.read().unwrap();
            for (table, tm) in shard.iter() {
                let mut buckets: Vec<Vec<(EntityId, Entry)>> = vec![Vec::new(); n];
                for (&entity, entry) in tm.iter() {
                    buckets[shard_of(entity, n)].push((entity, entry.clone()));
                }
                for (dest, bucket) in buckets.into_iter().enumerate() {
                    if !bucket.is_empty() {
                        new_maps[dest].entry(table.clone()).or_default().extend(bucket);
                    }
                }
            }
        }
        self.publish(ShardSet {
            generation: old.generation + 1,
            shards: Arc::new(new_maps.into_iter().map(RwLock::new).collect()),
            ttls: old.ttls.clone(),
        });
        Ok(())
    }

    /// Resident entries (including not-yet-evicted expired ones).
    pub fn len(&self) -> usize {
        let set = self.snapshot();
        set.shards
            .iter()
            .map(|s| s.read().unwrap().values().map(HashMap::len).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: EntityId, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn alg2_insert_override_noop() {
        let s = OnlineStore::new(4);
        // insert
        s.merge("t", &[rec(1, 100, 150, 1.0)], 150);
        assert_eq!(s.get("t", 1, 150).unwrap().values[0], 1.0);
        // newer event_ts → override
        s.merge("t", &[rec(1, 200, 160, 2.0)], 160);
        assert_eq!(s.get("t", 1, 160).unwrap().values[0], 2.0);
        // older event_ts → no-op (late merge of an old window)
        let m = s.merge("t", &[rec(1, 100, 999, 9.0)], 999);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 999).unwrap().values[0], 2.0);
        // same event_ts, newer creation_ts → override (late-arriving data
        // recompute — Fig 5's R3)
        s.merge("t", &[rec(1, 200, 500, 3.0)], 500);
        assert_eq!(s.get("t", 1, 500).unwrap().values[0], 3.0);
        // same event_ts, older creation_ts → no-op
        let m = s.merge("t", &[rec(1, 200, 170, 9.0)], 555);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 555).unwrap().values[0], 3.0);
    }

    #[test]
    fn merge_order_independent_converged_state() {
        // Any delivery order of the same record set converges to the same
        // online state (Eq. 2) — the eventual-consistency core.
        let records = vec![
            rec(1, 10, 11, 0.0),
            rec(1, 20, 21, 1.0),
            rec(1, 20, 99, 2.0),
            rec(1, 30, 31, 3.0),
            rec(2, 5, 6, 4.0),
        ];
        let mut perm = records.clone();
        for rot in 0..perm.len() {
            perm.rotate_left(1);
            let s = OnlineStore::new(2);
            for r in &perm {
                s.merge("t", std::slice::from_ref(r), r.creation_ts);
            }
            assert_eq!(s.get("t", 1, 1_000).unwrap().version(), (30, 31), "rot={rot}");
            assert_eq!(s.get("t", 2, 1_000).unwrap().version(), (5, 6));
        }
    }

    #[test]
    fn merge_batches_equals_per_batch_application() {
        let direct = OnlineStore::new(2);
        let coalesced = OnlineStore::new(2);
        // Mixed tables, a same-event recompute, and a stale no-op.
        let batches: Vec<(&str, Vec<FeatureRecord>)> = vec![
            ("a", vec![rec(1, 100, 110, 1.0)]),
            ("b", vec![rec(1, 5, 6, 3.0)]),
            ("a", vec![rec(1, 100, 300, 2.0), rec(2, 10, 20, 9.0)]),
            ("a", vec![rec(1, 90, 400, 0.5)]),
        ];
        let mut direct_stats = MergeStats::default();
        for (t, rs) in &batches {
            direct_stats.add(direct.merge(t, rs, 50));
        }
        let refs: Vec<(&str, &[FeatureRecord])> =
            batches.iter().map(|(t, rs)| (*t, rs.as_slice())).collect();
        let stats = coalesced.merge_batches(&refs, 50);
        assert_eq!(stats.inserted + stats.skipped, direct_stats.inserted + direct_stats.skipped);
        for (t, e) in [("a", 1u64), ("a", 2), ("b", 1)] {
            assert_eq!(
                coalesced.get(t, e, 60).map(|r| (r.version(), r.values.clone())),
                direct.get(t, e, 60).map(|r| (r.version(), r.values.clone())),
                "{t}/{e}"
            );
        }
        assert!(coalesced.merge_batches(&[], 50) == MergeStats::default());
    }

    #[test]
    fn ttl_expiry_and_eviction() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 100);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 1_000);
        assert!(s.get("t", 1, 1_050).is_some());
        assert!(s.get("t", 1, 1_100).is_none()); // expired at exactly ttl
        assert_eq!(s.len(), 1); // still resident until evicted
        assert_eq!(s.evict_expired(1_100), 1);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn tables_are_isolated() {
        let s = OnlineStore::new(2);
        s.merge("a", &[rec(1, 10, 20, 1.0)], 20);
        s.merge("b", &[rec(1, 99, 100, 2.0)], 100);
        assert_eq!(s.get("a", 1, 200).unwrap().values[0], 1.0);
        assert_eq!(s.get("b", 1, 200).unwrap().values[0], 2.0);
        assert_eq!(s.dump_table("a", 200).len(), 1);
    }

    #[test]
    fn get_many_preserves_order() {
        let s = OnlineStore::new(4);
        s.merge("t", &[rec(5, 10, 20, 5.0), rec(7, 10, 20, 7.0)], 20);
        let got = s.get_many("t", &[7, 6, 5], 100);
        assert_eq!(got[0].as_ref().unwrap().values[0], 7.0);
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().unwrap().values[0], 5.0);
    }

    #[test]
    fn get_many_matches_point_gets_and_counts() {
        let s = OnlineStore::new(4);
        s.set_ttl("t", 500);
        let rows: Vec<_> = (0..64).map(|i| rec(i, 10, 20, i as f32)).collect();
        s.merge("t", &rows, 100);
        let keys: Vec<EntityId> = (0..96).collect(); // 64 hits, 32 misses
        let batched = s.get_many("t", &keys, 300);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(batched[i], s.get("t", k, 300), "key {k}");
        }
        // get_many counted one hit/miss per key (then the loop doubled them).
        assert_eq!(s.hits.load(Ordering::Relaxed), 2 * 64);
        assert_eq!(s.misses.load(Ordering::Relaxed), 2 * 32);
        // TTL applies to the batch exactly as to point reads.
        assert!(s.get_many("t", &keys, 700).iter().all(Option::is_none));
    }

    #[test]
    fn get_many_empty_and_unknown_table() {
        let s = OnlineStore::new(4);
        assert!(s.get_many("t", &[], 0).is_empty());
        let got = s.get_many("ghost", &[1, 2, 3], 0);
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(Option::is_none));
        assert_eq!(s.misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn scale_preserves_data() {
        let s = OnlineStore::new(2);
        let rows: Vec<_> = (0..500).map(|i| rec(i, 10, 20, i as f32)).collect();
        s.merge("t", &rows, 20);
        s.scale_to(16).unwrap();
        assert_eq!(s.shard_count(), 16);
        for i in 0..500 {
            assert_eq!(s.get("t", i, 100).unwrap().values[0], i as f32);
        }
        s.scale_to(1).unwrap();
        assert_eq!(s.len(), 500);
        assert!(s.scale_to(0).is_err());
    }

    #[test]
    fn scale_preserves_ttls() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 100);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 1_000);
        s.scale_to(8).unwrap();
        assert!(s.get("t", 1, 1_050).is_some());
        assert!(s.get("t", 1, 1_200).is_none(), "TTL must survive resharding");
    }

    #[test]
    fn snapshots_refresh_across_scales() {
        // Same thread: write → scale → read must see the post-scale set
        // (generation check invalidates the thread-local cache).
        let s = OnlineStore::new(2);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        let _ = s.get("t", 1, 30); // warm the snapshot cache
        for shards in [5, 3, 12, 1] {
            s.scale_to(shards).unwrap();
            assert_eq!(s.shard_count(), shards);
            assert_eq!(s.get("t", 1, 30).unwrap().values[0], 1.0);
            s.merge("t", &[rec(2, 10, 20, 2.0)], 20);
            assert!(s.get("t", 2, 30).is_some());
        }
    }

    #[test]
    fn dump_table_skips_expired() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 50);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 0);
        s.merge("t", &[rec(2, 10, 20, 2.0)], 100);
        let dump = s.dump_table("t", 120);
        assert_eq!(dump.len(), 1);
        assert_eq!(dump[0].entity, 2);
    }

    #[test]
    fn hit_miss_accounting() {
        let s = OnlineStore::new(2);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        s.get("t", 1, 30);
        s.get("t", 2, 30);
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_merges_converge() {
        let s = Arc::new(OnlineStore::new(8));
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let r = rec(i % 50, (i as i64) + 1, (i as i64) + 2 + t as i64, t as f32);
                        s.merge("t", &[r], 1_000);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every entity holds the max-version record written for it.
        for e in 0..50u64 {
            let got = s.get("t", e, 10_000).unwrap();
            // max i with i%50==e is 150+e → event_ts 151+e, creation from
            // the thread with largest t.
            assert_eq!(got.event_ts, 151 + e as i64);
            assert_eq!(got.creation_ts, 151 + e as i64 + 1 + 7);
        }
    }
}
