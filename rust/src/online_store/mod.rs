//! Online store (§3.1.4): low-latency sink, Redis-equivalent substrate.
//!
//! Per Eq. 2 the online store keeps, for each entity, only the record
//! with `max(tuple(event_ts, creation_ts))`, "assuming TTL satisfies".
//! The merge follows Algorithm 2's online branch exactly:
//!
//! * key absent → insert
//! * new event_ts > existing → override
//! * equal event_ts and new creation_ts > existing → override
//! * otherwise → no-op
//!
//! # Concurrency design (the serving hot path)
//!
//! Reads are **wait-free with respect to writers**: no point or batched
//! read ever acquires a `Mutex` or `RwLock` — there is no lock a reader
//! and a writer both take. The pieces:
//!
//! * Shard interiors are [`seqlock::SeqlockMap`]s: open-addressing
//!   bucket arrays where every field is an atomic and an even/odd
//!   stamp makes each bucket's composite read atomic (see that module
//!   for the full memory-ordering argument). Readers retry the few
//!   loads of a bucket only while a writer is mid-write on *that*
//!   bucket; writers serialize on a small per-shard `Mutex<WriteSide>`
//!   readers never touch.
//! * Topology is an immutable [`ShardSet`] snapshot (`table →
//!   TableShards → shards`) behind a generation-stamped thread-local
//!   cache. The slow path (first use on a thread, or after a publish)
//!   goes through the [`PubLedger`] — an append-only array of `Weak`
//!   publications indexed by generation — so even a cache miss is
//!   atomics + `Weak::upgrade`, never a mutex.
//! * Writers (`merge`, `evict_expired`) share the `admin` read lock (so
//!   they never race a topology swap) and take only per-shard write
//!   mutexes. `scale_to`/`set_ttl`/table creation/shard growth take the
//!   `admin` write lock, build a **new** `ShardSet` (or new per-table
//!   shard arrays), and publish it; readers on the old snapshot keep
//!   serving it untouched and pick up the new one on their next
//!   operation via the generation check.
//! * Shard growth is rebuild-on-full: each published `SeqlockMap` has
//!   fixed capacity; a merge whose batch might not fit rebuilds that
//!   table's shards at a doubled size and **retries the whole batch**
//!   (Alg 2 application is idempotent, so re-applying records that
//!   landed before the rebuild only reclassifies them from `inserted`
//!   to `skipped` — `inserted + skipped == records.len()` always
//!   holds).
//! * TTL expiry is filtered at read time from the bucket's
//!   `written_at`; `evict_expired` tombstones expired buckets one shard
//!   mutex at a time (pure space reclamation — readers of the same
//!   shard are not blocked, they just stop seeing the entries). Value
//!   arena slots of overridden/evicted entries are reclaimed at the
//!   next rebuild of that table, not eagerly.
//!
//! `hits`/`misses` stay plain atomic counters. Sharded like a Redis
//! cluster; `scale_to` rebalances shards online (§3.1.3 "scale up or
//! down the managed resources like Redis") without blocking readers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock, Weak};

use crate::offline_store::MergeStats;
use crate::types::{EntityId, FeatureRecord, FsError, Result, Timestamp};

mod seqlock;

use seqlock::{ReadHit, SeqlockMap, WriteSide};

/// splitmix-style avalanche: the low bits route to a shard, the high
/// bits index buckets inside the shard's `SeqlockMap` (decorrelated so
/// a shard's keys spread over its whole bucket array).
pub(crate) fn hash_of(entity: EntityId) -> u64 {
    let mut x = entity.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

fn shard_idx(hash: u64, n: usize) -> usize {
    (hash % n as u64) as usize
}

/// One shard: a seqlock bucket array plus the write mutex serializing
/// its writers. Readers use `map` only.
#[derive(Debug)]
struct SeqShard {
    write: Mutex<WriteSide>,
    map: SeqlockMap,
}

impl SeqShard {
    fn with_room_for(expected: usize) -> SeqShard {
        SeqShard { write: Mutex::new(WriteSide::default()), map: SeqlockMap::with_room_for(expected) }
    }
}

/// One table's shard array. Shared (`Arc`) across `ShardSet`
/// publications that do not touch this table, so a TTL change or
/// another table's growth never copies data.
#[derive(Debug)]
struct TableShards {
    shards: Vec<SeqShard>,
}

/// Room for this many entries per shard in a freshly-created table.
const INITIAL_SHARD_ROOM: usize = 8;

impl TableShards {
    fn new(n_shards: usize, per_shard_room: usize) -> TableShards {
        TableShards {
            shards: (0..n_shards).map(|_| SeqShard::with_room_for(per_shard_room)).collect(),
        }
    }

    /// Rebuild into `n_shards` with room for every resident entry plus
    /// `extra` incoming ones per shard. Caller holds the `admin` write
    /// lock, so no writer mutates `self` during the gather.
    fn rebuilt(&self, n_shards: usize, extra: usize) -> TableShards {
        let mut gathered: Vec<Vec<(EntityId, u64, ReadHit)>> = (0..n_shards).map(|_| Vec::new()).collect();
        for shard in &self.shards {
            shard.map.for_each_resident(|entity, hit| {
                let h = hash_of(entity);
                gathered[shard_idx(h, n_shards)].push((entity, h, hit));
            });
        }
        let shards = gathered
            .into_iter()
            .map(|entries| {
                let shard =
                    SeqShard::with_room_for((entries.len() + extra).max(INITIAL_SHARD_ROOM));
                let mut ws = shard.write.lock().unwrap();
                for (entity, h, hit) in &entries {
                    shard.map.seed(&mut ws, *entity, *h, hit);
                }
                drop(ws);
                shard
            })
            .collect();
        TableShards { shards }
    }
}

/// The immutable-topology snapshot readers operate on. Everything
/// inside a published `ShardSet` is fixed except shard *contents*
/// (mutated through the seqlock write protocol).
#[derive(Debug)]
struct ShardSet {
    /// Monotonic publish counter; compared against the store's atomic
    /// generation by the thread-local snapshot cache, and the entry's
    /// index in the [`PubLedger`].
    generation: u64,
    n_shards: usize,
    tables: HashMap<String, Arc<TableShards>>,
    /// TTL per table (seconds on the processing timeline); absent = ∞.
    ttls: HashMap<String, i64>,
}

impl ShardSet {
    fn ttl_of(&self, table: &str) -> i64 {
        self.ttls.get(table).copied().unwrap_or(i64::MAX)
    }
}

fn live_at(hit: &ReadHit, ttl: i64, now: Timestamp) -> bool {
    ttl == i64::MAX || now - hit.written_at < ttl
}

/// Process-unique store ids for the thread-local snapshot cache.
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread `(store_id, snapshot)` cache. Entries are `Weak` so
    /// an idle thread never pins a dropped store or a superseded
    /// (pre-scale) shard set — once the store publishes a new set, the
    /// old one is freed as soon as in-flight readers finish, not when
    /// every thread happens to touch the store again. Bounded FIFO.
    static SNAPSHOT_CACHE: RefCell<Vec<(u64, Weak<ShardSet>)>> = const { RefCell::new(Vec::new()) };
}

const SNAPSHOT_CACHE_CAP: usize = 8;

/// First ledger chunk's slot count; chunk `k` holds `LEDGER_BASE << k`.
const LEDGER_BASE: usize = 64;
/// 48 geometric chunks cover ~2^53 publications.
const LEDGER_CHUNKS: usize = 48;

/// Lock-free publication ledger: generation → `Weak<ShardSet>`. An
/// append-only array grown in geometrically-sized `OnceLock` chunks so
/// a reader resolving any generation is two `OnceLock::get`s and a
/// `Weak::upgrade` — the snapshot slow path takes no mutex. Superseded
/// publications cost one dead `Weak` (~a pointer) each; the strong ref
/// for the live one is held by the store's publisher-only `current`.
struct PubLedger {
    chunks: [OnceLock<Box<[OnceLock<Weak<ShardSet>>]>>; LEDGER_CHUNKS],
}

impl PubLedger {
    fn new() -> PubLedger {
        PubLedger { chunks: std::array::from_fn(|_| OnceLock::new()) }
    }

    /// (chunk, offset) for a generation: chunk `k` spans
    /// `[LEDGER_BASE·(2^k − 1), LEDGER_BASE·(2^{k+1} − 1))`.
    fn locate(generation: u64) -> (usize, usize) {
        let idx = usize::try_from(generation).expect("generation fits usize");
        let k = (idx / LEDGER_BASE + 1).ilog2() as usize;
        assert!(k < LEDGER_CHUNKS, "publication ledger exhausted");
        let base = LEDGER_BASE * ((1usize << k) - 1);
        (k, idx - base)
    }

    /// Record a publication. Publisher-only (under the `admin` write
    /// lock), and always *before* the generation counter advances.
    fn put(&self, generation: u64, set: Weak<ShardSet>) {
        let (k, off) = Self::locate(generation);
        let chunk = self.chunks[k]
            .get_or_init(|| (0..(LEDGER_BASE << k)).map(|_| OnceLock::new()).collect());
        chunk[off].set(set).expect("generations are published once");
    }

    /// Resolve a generation to its live snapshot. `None` when that
    /// publication was superseded and dropped — the caller re-reads the
    /// generation counter and retries with a newer one.
    fn get(&self, generation: u64) -> Option<Arc<ShardSet>> {
        let (k, off) = Self::locate(generation);
        self.chunks[k].get()?.get(off)?.get()?.upgrade()
    }
}

impl fmt::Debug for PubLedger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("PubLedger { .. }")
    }
}

/// Sharded in-process KV store whose read path is wait-free w.r.t.
/// writers (no reader-visible locks at all — see module docs).
#[derive(Debug)]
pub struct OnlineStore {
    store_id: u64,
    /// Generation of the currently published [`ShardSet`]; stored with
    /// `Release` on every publish, read with `Acquire` by readers.
    generation: AtomicU64,
    /// Publisher-side strong reference keeping the latest publication
    /// alive. **Never** locked on the read path — readers resolve
    /// snapshots through the [`PubLedger`].
    current: Mutex<Arc<ShardSet>>,
    ledger: PubLedger,
    /// Writer/topology coordination: `merge`/`evict_expired` take read
    /// (concurrent), publishes (`scale_to`/`set_ttl`/table
    /// creation/growth) take write (exclusive), and the read path takes
    /// nothing.
    admin: RwLock<()>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl Default for OnlineStore {
    fn default() -> Self {
        Self::new(8)
    }
}

impl OnlineStore {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0);
        let set = Arc::new(ShardSet {
            generation: 0,
            n_shards: shards,
            tables: HashMap::new(),
            ttls: HashMap::new(),
        });
        let ledger = PubLedger::new();
        ledger.put(0, Arc::downgrade(&set));
        OnlineStore {
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            generation: AtomicU64::new(0),
            current: Mutex::new(set),
            ledger,
            admin: RwLock::new(()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Current snapshot. Fast path: one atomic load + thread-local hit.
    /// Slow path (first use on this thread, or after a topology/TTL
    /// publish): ledger lookup + `Weak::upgrade` — still no lock. An
    /// upgrade can only fail for a superseded generation, in which case
    /// the generation counter has already advanced past it.
    fn snapshot(&self) -> Arc<ShardSet> {
        let mut gen = self.generation.load(Ordering::Acquire);
        let hit = SNAPSHOT_CACHE.with(|c| {
            c.borrow()
                .iter()
                .find(|(id, _)| *id == self.store_id)
                .and_then(|(_, w)| w.upgrade())
                .filter(|s| s.generation == gen)
        });
        if let Some(s) = hit {
            return s;
        }
        let fresh = loop {
            if let Some(s) = self.ledger.get(gen) {
                break s;
            }
            // A dead publication means a newer one exists; its generation
            // store may not be visible on this thread yet (Weak::upgrade's
            // failure read is Relaxed) — spin until the counter moves.
            let newer = self.generation.load(Ordering::Acquire);
            if newer == gen {
                std::hint::spin_loop();
            } else {
                gen = newer;
            }
        };
        SNAPSHOT_CACHE.with(|c| {
            let mut c = c.borrow_mut();
            c.retain(|(id, _)| *id != self.store_id);
            if c.len() >= SNAPSHOT_CACHE_CAP {
                c.remove(0);
            }
            c.push((self.store_id, Arc::downgrade(&fresh)));
        });
        fresh
    }

    /// Publish a new shard set. Caller must hold the `admin` write lock.
    /// Order matters for lock-free readers: ledger slot first, then the
    /// generation counter (`Release`), then retire the old strong ref —
    /// so a reader holding either generation value can always resolve
    /// it, or observes the newer generation.
    fn publish(&self, set: ShardSet) {
        let gen = set.generation;
        let arc = Arc::new(set);
        self.ledger.put(gen, Arc::downgrade(&arc));
        self.generation.store(gen, Ordering::Release);
        *self.current.lock().unwrap() = arc;
    }

    /// The latest publication (publisher side; caller holds `admin`).
    fn current(&self) -> Arc<ShardSet> {
        self.current.lock().unwrap().clone()
    }

    pub fn shard_count(&self) -> usize {
        self.snapshot().n_shards
    }

    /// Set a table's TTL. Publishes a new snapshot sharing every
    /// table's shard array (no data is touched or copied).
    pub fn set_ttl(&self, table: &str, ttl_secs: i64) {
        let _topology = self.admin.write().unwrap();
        let old = self.current();
        let mut ttls = old.ttls.clone();
        ttls.insert(table.to_string(), ttl_secs);
        self.publish(ShardSet {
            generation: old.generation + 1,
            n_shards: old.n_shards,
            tables: old.tables.clone(),
            ttls,
        });
    }

    /// Algorithm 2 (online branch). `now` is the processing-timeline
    /// write moment (drives TTL). Retries the whole batch after
    /// creating the table or growing its shards; per attempt the stats
    /// are rebuilt from scratch, so `inserted + skipped ==
    /// records.len()` even when a growth retry reclassifies records
    /// applied before the rebuild as `skipped`.
    pub fn merge(&self, table: &str, records: &[FeatureRecord], now: Timestamp) -> MergeStats {
        if records.is_empty() {
            return MergeStats::default();
        }
        loop {
            let missing_table = {
                let _writers = self.admin.read().unwrap();
                let set = self.snapshot();
                match set.tables.get(table) {
                    None => true,
                    Some(ts) => {
                        if let Some(stats) = Self::merge_into(ts, records, now) {
                            return stats;
                        }
                        false
                    }
                }
            };
            if missing_table {
                self.ensure_table(table);
            } else {
                self.grow_table(table, records.len());
            }
        }
    }

    /// Apply a batch into one table's shards. Returns `None` when some
    /// shard lacks room for its slice of the batch (checked under that
    /// shard's write mutex *before* applying any of its records) — the
    /// caller grows the table and retries.
    fn merge_into(ts: &TableShards, records: &[FeatureRecord], now: Timestamp) -> Option<MergeStats> {
        let n = ts.shards.len();
        let mut stats = MergeStats::default();
        if let [r] = records {
            // Point-upsert fast path: no grouping allocation.
            let h = hash_of(r.entity);
            let shard = &ts.shards[shard_idx(h, n)];
            let mut ws = shard.write.lock().unwrap();
            if !shard.map.has_room(&ws, 1) {
                return None;
            }
            match shard.map.apply(&mut ws, h, r, now) {
                seqlock::Applied::Inserted => stats.inserted += 1,
                seqlock::Applied::Skipped => stats.skipped += 1,
            }
            return Some(stats);
        }
        let mut hashes: Vec<u64> = Vec::with_capacity(records.len());
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, r) in records.iter().enumerate() {
            let h = hash_of(r.entity);
            hashes.push(h);
            by_shard[shard_idx(h, n)].push(i);
        }
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let shard = &ts.shards[s];
            let mut ws = shard.write.lock().unwrap();
            if !shard.map.has_room(&ws, idxs.len()) {
                return None;
            }
            for &i in idxs {
                match shard.map.apply(&mut ws, hashes[i], &records[i], now) {
                    seqlock::Applied::Inserted => stats.inserted += 1,
                    seqlock::Applied::Skipped => stats.skipped += 1,
                }
            }
        }
        Some(stats)
    }

    /// Publish a snapshot containing `table` (no-op if a racing merge
    /// already created it).
    fn ensure_table(&self, table: &str) {
        let _topology = self.admin.write().unwrap();
        let old = self.current();
        if old.tables.contains_key(table) {
            return;
        }
        let mut tables = old.tables.clone();
        tables.insert(
            table.to_string(),
            Arc::new(TableShards::new(old.n_shards, INITIAL_SHARD_ROOM)),
        );
        self.publish(ShardSet {
            generation: old.generation + 1,
            n_shards: old.n_shards,
            tables,
            ttls: old.ttls.clone(),
        });
    }

    /// Rebuild one table's shards with room for everything resident
    /// plus `incoming` more, and publish. Readers on the old snapshot
    /// are untouched; the gather is quiescent because we hold the
    /// `admin` write lock (no writer runs).
    fn grow_table(&self, table: &str, incoming: usize) {
        let _topology = self.admin.write().unwrap();
        let old = self.current();
        let Some(ts) = old.tables.get(table) else { return };
        let mut tables = old.tables.clone();
        tables.insert(table.to_string(), Arc::new(ts.rebuilt(old.n_shards, incoming)));
        self.publish(ShardSet {
            generation: old.generation + 1,
            n_shards: old.n_shards,
            tables,
            ttls: old.ttls.clone(),
        });
    }

    /// Merge a sequence of `(table, records)` batches, coalescing per
    /// table (first-seen order, single batches applied in place) into
    /// **one** [`OnlineStore::merge`] per table — the write-side batch
    /// amortization shared by the replication pumps and the serving
    /// write batcher. Alg 2 is order-independent-convergent and the
    /// concatenation preserves batch order, so the converged state
    /// equals per-batch application.
    pub fn merge_batches(
        &self,
        batches: &[(&str, &[FeatureRecord])],
        now: Timestamp,
    ) -> MergeStats {
        let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
        for (i, &(table, _)) in batches.iter().enumerate() {
            match groups.iter_mut().find(|(t, _)| *t == table) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((table, vec![i])),
            }
        }
        let mut stats = MergeStats::default();
        for (table, idxs) in &groups {
            if let &[i] = &idxs[..] {
                stats.add(self.merge(table, batches[i].1, now));
            } else {
                let mut records: Vec<FeatureRecord> =
                    Vec::with_capacity(idxs.iter().map(|&i| batches[i].1.len()).sum());
                for &i in idxs {
                    records.extend_from_slice(batches[i].1);
                }
                stats.add(self.merge(table, &records, now));
            }
        }
        stats
    }

    /// The wait-free probe shared by `get`/`get_many`: snapshot lookup,
    /// seqlock bucket read, TTL filter. No locks anywhere on this path.
    fn probe(set: &ShardSet, table: &str, entity: EntityId, ttl: i64, now: Timestamp) -> Option<FeatureRecord> {
        let ts = set.tables.get(table)?;
        let h = hash_of(entity);
        let hit = ts.shards[shard_idx(h, ts.shards.len())].map.read(entity, h)?;
        if !live_at(&hit, ttl, now) {
            return None;
        }
        Some(FeatureRecord::new(entity, hit.event_ts, hit.creation_ts, &hit.values[..]))
    }

    /// Low-latency point lookup. Returns `None` for absent or TTL-expired
    /// entries — the caller distinguishes "not materialized" vs "no data"
    /// through the scheduler's data-state (§4.3). Wait-free w.r.t.
    /// writers: one atomic generation load, one seqlock bucket probe,
    /// zero lock acquisitions.
    pub fn get(&self, table: &str, entity: EntityId, now: Timestamp) -> Option<FeatureRecord> {
        let set = self.snapshot();
        let out = Self::probe(&set, table, entity, set.ttl_of(table), now);
        match &out {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Batched lookup (the serving batcher's unit of work): one
    /// snapshot load and one TTL resolution amortized over the batch,
    /// then a wait-free seqlock probe per key — there are no shard
    /// locks left to group by, so keys are served in input order.
    /// `get_many(t, ks)[i] == get(t, ks[i])` for all `i`.
    pub fn get_many(
        &self,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
    ) -> Vec<Option<FeatureRecord>> {
        if entities.is_empty() {
            return Vec::new();
        }
        let set = self.snapshot();
        let ttl = set.ttl_of(table);
        let (mut hits, mut misses) = (0u64, 0u64);
        let out: Vec<Option<FeatureRecord>> = entities
            .iter()
            .map(|&e| {
                let r = Self::probe(&set, table, e, ttl, now);
                match &r {
                    Some(_) => hits += 1,
                    None => misses += 1,
                }
                r
            })
            .collect();
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.misses.fetch_add(misses, Ordering::Relaxed);
        out
    }

    /// Everything currently live in a table — the online→offline
    /// bootstrap read (§4.5.5). Lock-free scan; with concurrent writers
    /// each *bucket* is observed consistently, the table as a whole is
    /// not a point-in-time cut (same contract the per-shard-locked scan
    /// had across shards).
    pub fn dump_table(&self, table: &str, now: Timestamp) -> Vec<FeatureRecord> {
        let set = self.snapshot();
        let ttl = set.ttl_of(table);
        let mut out = Vec::new();
        if let Some(ts) = set.tables.get(table) {
            for shard in &ts.shards {
                shard.map.for_each_resident(|entity, hit| {
                    if live_at(&hit, ttl, now) {
                        out.push(FeatureRecord::new(entity, hit.event_ts, hit.creation_ts, &hit.values[..]));
                    }
                });
            }
        }
        out.sort_by_key(|r| r.entity);
        out
    }

    /// Drop TTL-expired entries (Redis does this lazily + actively; we
    /// expose it so tests and the freshness monitor can force it).
    /// Takes one shard write mutex at a time — readers are never
    /// blocked anywhere (expired entries are filtered at read time
    /// regardless), and writers of other shards proceed.
    pub fn evict_expired(&self, now: Timestamp) -> u64 {
        let _writers = self.admin.read().unwrap();
        let set = self.snapshot();
        let mut evicted = 0;
        for (table, ts) in set.tables.iter() {
            let ttl = set.ttl_of(table);
            if ttl == i64::MAX {
                continue;
            }
            for shard in &ts.shards {
                let mut ws = shard.write.lock().unwrap();
                evicted += shard.map.tombstone_expired(&mut ws, ttl, now);
            }
        }
        evicted
    }

    /// Scale to `n` shards, rehashing all entries (§3.1.3). Writers are
    /// paused for the rebalance (the `admin` write lock), but readers
    /// are **never** blocked: they keep serving the pre-scale snapshot
    /// until the new shard set is published, then switch over via the
    /// generation check on their next operation. Rebuilding also starts
    /// fresh value arenas, reclaiming slots leaked by overrides and
    /// evictions.
    pub fn scale_to(&self, n: usize) -> Result<()> {
        if n == 0 {
            return Err(FsError::InvalidArg("shard count must be > 0".into()));
        }
        let _topology = self.admin.write().unwrap();
        let old = self.current();
        let tables = old
            .tables
            .iter()
            .map(|(name, ts)| (name.clone(), Arc::new(ts.rebuilt(n, 0))))
            .collect();
        self.publish(ShardSet {
            generation: old.generation + 1,
            n_shards: n,
            tables,
            ttls: old.ttls.clone(),
        });
        Ok(())
    }

    /// Resident entries (including not-yet-evicted expired ones).
    /// Lock-free: sums the shards' atomic live counters.
    pub fn len(&self) -> usize {
        let set = self.snapshot();
        set.tables
            .values()
            .map(|ts| ts.shards.iter().map(|s| s.map.live()).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: EntityId, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn alg2_insert_override_noop() {
        let s = OnlineStore::new(4);
        // insert
        s.merge("t", &[rec(1, 100, 150, 1.0)], 150);
        assert_eq!(s.get("t", 1, 150).unwrap().values[0], 1.0);
        // newer event_ts → override
        s.merge("t", &[rec(1, 200, 160, 2.0)], 160);
        assert_eq!(s.get("t", 1, 160).unwrap().values[0], 2.0);
        // older event_ts → no-op (late merge of an old window)
        let m = s.merge("t", &[rec(1, 100, 999, 9.0)], 999);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 999).unwrap().values[0], 2.0);
        // same event_ts, newer creation_ts → override (late-arriving data
        // recompute — Fig 5's R3)
        s.merge("t", &[rec(1, 200, 500, 3.0)], 500);
        assert_eq!(s.get("t", 1, 500).unwrap().values[0], 3.0);
        // same event_ts, older creation_ts → no-op
        let m = s.merge("t", &[rec(1, 200, 170, 9.0)], 555);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 555).unwrap().values[0], 3.0);
    }

    #[test]
    fn merge_order_independent_converged_state() {
        // Any delivery order of the same record set converges to the same
        // online state (Eq. 2) — the eventual-consistency core.
        let records = vec![
            rec(1, 10, 11, 0.0),
            rec(1, 20, 21, 1.0),
            rec(1, 20, 99, 2.0),
            rec(1, 30, 31, 3.0),
            rec(2, 5, 6, 4.0),
        ];
        let mut perm = records.clone();
        for rot in 0..perm.len() {
            perm.rotate_left(1);
            let s = OnlineStore::new(2);
            for r in &perm {
                s.merge("t", std::slice::from_ref(r), r.creation_ts);
            }
            assert_eq!(s.get("t", 1, 1_000).unwrap().version(), (30, 31), "rot={rot}");
            assert_eq!(s.get("t", 2, 1_000).unwrap().version(), (5, 6));
        }
    }

    #[test]
    fn merge_batches_equals_per_batch_application() {
        let direct = OnlineStore::new(2);
        let coalesced = OnlineStore::new(2);
        // Mixed tables, a same-event recompute, and a stale no-op.
        let batches: Vec<(&str, Vec<FeatureRecord>)> = vec![
            ("a", vec![rec(1, 100, 110, 1.0)]),
            ("b", vec![rec(1, 5, 6, 3.0)]),
            ("a", vec![rec(1, 100, 300, 2.0), rec(2, 10, 20, 9.0)]),
            ("a", vec![rec(1, 90, 400, 0.5)]),
        ];
        let mut direct_stats = MergeStats::default();
        for (t, rs) in &batches {
            direct_stats.add(direct.merge(t, rs, 50));
        }
        let refs: Vec<(&str, &[FeatureRecord])> =
            batches.iter().map(|(t, rs)| (*t, rs.as_slice())).collect();
        let stats = coalesced.merge_batches(&refs, 50);
        assert_eq!(stats.inserted + stats.skipped, direct_stats.inserted + direct_stats.skipped);
        for (t, e) in [("a", 1u64), ("a", 2), ("b", 1)] {
            assert_eq!(
                coalesced.get(t, e, 60).map(|r| (r.version(), r.values.clone())),
                direct.get(t, e, 60).map(|r| (r.version(), r.values.clone())),
                "{t}/{e}"
            );
        }
        assert!(coalesced.merge_batches(&[], 50) == MergeStats::default());
    }

    #[test]
    fn ttl_expiry_and_eviction() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 100);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 1_000);
        assert!(s.get("t", 1, 1_050).is_some());
        assert!(s.get("t", 1, 1_100).is_none()); // expired at exactly ttl
        assert_eq!(s.len(), 1); // still resident until evicted
        assert_eq!(s.evict_expired(1_100), 1);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn tables_are_isolated() {
        let s = OnlineStore::new(2);
        s.merge("a", &[rec(1, 10, 20, 1.0)], 20);
        s.merge("b", &[rec(1, 99, 100, 2.0)], 100);
        assert_eq!(s.get("a", 1, 200).unwrap().values[0], 1.0);
        assert_eq!(s.get("b", 1, 200).unwrap().values[0], 2.0);
        assert_eq!(s.dump_table("a", 200).len(), 1);
    }

    #[test]
    fn get_many_preserves_order() {
        let s = OnlineStore::new(4);
        s.merge("t", &[rec(5, 10, 20, 5.0), rec(7, 10, 20, 7.0)], 20);
        let got = s.get_many("t", &[7, 6, 5], 100);
        assert_eq!(got[0].as_ref().unwrap().values[0], 7.0);
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().unwrap().values[0], 5.0);
    }

    #[test]
    fn get_many_matches_point_gets_and_counts() {
        let s = OnlineStore::new(4);
        s.set_ttl("t", 500);
        let rows: Vec<_> = (0..64).map(|i| rec(i, 10, 20, i as f32)).collect();
        s.merge("t", &rows, 100);
        let keys: Vec<EntityId> = (0..96).collect(); // 64 hits, 32 misses
        let batched = s.get_many("t", &keys, 300);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(batched[i], s.get("t", k, 300), "key {k}");
        }
        // get_many counted one hit/miss per key (then the loop doubled them).
        assert_eq!(s.hits.load(Ordering::Relaxed), 2 * 64);
        assert_eq!(s.misses.load(Ordering::Relaxed), 2 * 32);
        // TTL applies to the batch exactly as to point reads.
        assert!(s.get_many("t", &keys, 700).iter().all(Option::is_none));
    }

    #[test]
    fn get_many_empty_and_unknown_table() {
        let s = OnlineStore::new(4);
        assert!(s.get_many("t", &[], 0).is_empty());
        let got = s.get_many("ghost", &[1, 2, 3], 0);
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(Option::is_none));
        assert_eq!(s.misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn scale_preserves_data() {
        let s = OnlineStore::new(2);
        let rows: Vec<_> = (0..500).map(|i| rec(i, 10, 20, i as f32)).collect();
        s.merge("t", &rows, 20);
        s.scale_to(16).unwrap();
        assert_eq!(s.shard_count(), 16);
        for i in 0..500 {
            assert_eq!(s.get("t", i, 100).unwrap().values[0], i as f32);
        }
        s.scale_to(1).unwrap();
        assert_eq!(s.len(), 500);
        assert!(s.scale_to(0).is_err());
    }

    #[test]
    fn scale_preserves_ttls() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 100);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 1_000);
        s.scale_to(8).unwrap();
        assert!(s.get("t", 1, 1_050).is_some());
        assert!(s.get("t", 1, 1_200).is_none(), "TTL must survive resharding");
    }

    #[test]
    fn snapshots_refresh_across_scales() {
        // Same thread: write → scale → read must see the post-scale set
        // (generation check invalidates the thread-local cache).
        let s = OnlineStore::new(2);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        let _ = s.get("t", 1, 30); // warm the snapshot cache
        for shards in [5, 3, 12, 1] {
            s.scale_to(shards).unwrap();
            assert_eq!(s.shard_count(), shards);
            assert_eq!(s.get("t", 1, 30).unwrap().values[0], 1.0);
            s.merge("t", &[rec(2, 10, 20, 2.0)], 20);
            assert!(s.get("t", 2, 30).is_some());
        }
    }

    #[test]
    fn dump_table_skips_expired() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 50);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 0);
        s.merge("t", &[rec(2, 10, 20, 2.0)], 100);
        let dump = s.dump_table("t", 120);
        assert_eq!(dump.len(), 1);
        assert_eq!(dump[0].entity, 2);
    }

    #[test]
    fn hit_miss_accounting() {
        let s = OnlineStore::new(2);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        s.get("t", 1, 30);
        s.get("t", 2, 30);
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_merges_converge() {
        let s = Arc::new(OnlineStore::new(8));
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let r = rec(i % 50, (i as i64) + 1, (i as i64) + 2 + t as i64, t as f32);
                        s.merge("t", &[r], 1_000);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every entity holds the max-version record written for it.
        for e in 0..50u64 {
            let got = s.get("t", e, 10_000).unwrap();
            // max i with i%50==e is 150+e → event_ts 151+e, creation from
            // the thread with largest t.
            assert_eq!(got.event_ts, 151 + e as i64);
            assert_eq!(got.creation_ts, 151 + e as i64 + 1 + 7);
        }
    }

    #[test]
    fn growth_retry_conserves_stats_totals() {
        // A batch far bigger than a fresh table's initial room forces at
        // least one rebuild-and-retry mid-merge; totals must still be
        // exactly one count per record, and re-merging the same batch
        // must classify every record as skipped.
        let s = OnlineStore::new(3);
        let rows: Vec<_> = (0..1_000).map(|i| rec(i, 10, 20, i as f32)).collect();
        let m = s.merge("t", &rows, 20);
        assert_eq!(m.inserted + m.skipped, 1_000);
        assert_eq!(s.len(), 1_000);
        let again = s.merge("t", &rows, 30);
        assert_eq!(again.inserted, 0);
        assert_eq!(again.skipped, 1_000);
    }

    #[test]
    fn reads_are_lock_free_under_a_held_write_mutex() {
        // A reader must complete while a writer-side shard mutex is held
        // (the old RwLock interior would deadlock this test): pin the
        // write mutex of every shard, then read on the same thread.
        let s = Arc::new(OnlineStore::new(2));
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        let set = s.snapshot();
        let guards: Vec<_> =
            set.tables["t"].shards.iter().map(|sh| sh.write.lock().unwrap()).collect();
        assert_eq!(s.get("t", 1, 30).unwrap().values[0], 1.0);
        assert_eq!(s.get_many("t", &[1, 2], 30)[1], None);
        assert_eq!(s.len(), 1);
        drop(guards);
    }
}
