//! Online store (§3.1.4): low-latency sink, Redis-equivalent substrate.
//!
//! Per Eq. 2 the online store keeps, for each entity, only the record
//! with `max(tuple(event_ts, creation_ts))`, "assuming TTL satisfies".
//! The merge follows Algorithm 2's online branch exactly:
//!
//! * key absent → insert
//! * new event_ts > existing → override
//! * equal event_ts and new creation_ts > existing → override
//! * otherwise → no-op
//!
//! Sharded like a Redis cluster; `scale_to` rebalances shards online
//! (§3.1.3 "scale up or down the managed resources like Redis").

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::offline_store::MergeStats;
use crate::types::{EntityId, FeatureRecord, FsError, Result, Timestamp};

/// Per-table entry: the single latest record (Eq. 2) + TTL bookkeeping.
#[derive(Debug, Clone)]
struct Entry {
    record: FeatureRecord,
    /// Wall-clock (processing timeline) moment this entry was last
    /// written; TTL expiry is measured from here, like a Redis SET with
    /// EXPIRE.
    written_at: Timestamp,
}

type ShardMap = HashMap<(String, EntityId), Entry>;

/// Sharded in-process KV store.
#[derive(Debug)]
pub struct OnlineStore {
    shards: RwLock<Vec<RwLock<ShardMap>>>,
    /// TTL per table (seconds on the processing timeline); default ∞.
    ttls: RwLock<HashMap<String, i64>>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl Default for OnlineStore {
    fn default() -> Self {
        Self::new(8)
    }
}

impl OnlineStore {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0);
        OnlineStore {
            shards: RwLock::new((0..shards).map(|_| RwLock::new(HashMap::new())).collect()),
            ttls: RwLock::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.read().unwrap().len()
    }

    pub fn set_ttl(&self, table: &str, ttl_secs: i64) {
        self.ttls.write().unwrap().insert(table.to_string(), ttl_secs);
    }

    fn shard_of(&self, entity: EntityId, n: usize) -> usize {
        // splitmix-style avalanche so sequential ids spread.
        let mut x = entity.wrapping_add(0x9e3779b97f4a7c15);
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
        (x ^ (x >> 31)) as usize % n
    }

    /// Algorithm 2 (online branch). `now` is the processing-timeline
    /// write moment (drives TTL).
    pub fn merge(&self, table: &str, records: &[FeatureRecord], now: Timestamp) -> MergeStats {
        let mut stats = MergeStats::default();
        let shards = self.shards.read().unwrap();
        let n = shards.len();
        for r in records {
            let key = (table.to_string(), r.entity);
            let mut shard = shards[self.shard_of(r.entity, n)].write().unwrap();
            match shard.get(&key) {
                None => {
                    shard.insert(key, Entry { record: r.clone(), written_at: now });
                    stats.inserted += 1;
                }
                Some(e) if r.version() > e.record.version() => {
                    shard.insert(key, Entry { record: r.clone(), written_at: now });
                    stats.inserted += 1;
                }
                Some(_) => stats.skipped += 1,
            }
        }
        stats
    }

    /// Low-latency point lookup. Returns `None` for absent or TTL-expired
    /// entries — the caller distinguishes "not materialized" vs "no data"
    /// through the scheduler's data-state (§4.3).
    pub fn get(&self, table: &str, entity: EntityId, now: Timestamp) -> Option<FeatureRecord> {
        let shards = self.shards.read().unwrap();
        let n = shards.len();
        let shard = shards[self.shard_of(entity, n)].read().unwrap();
        let out = shard.get(&(table.to_string(), entity)).and_then(|e| {
            let ttl = self.ttls.read().unwrap().get(table).copied().unwrap_or(i64::MAX);
            if ttl != i64::MAX && now - e.written_at >= ttl {
                None // expired
            } else {
                Some(e.record.clone())
            }
        });
        match &out {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    /// Batched lookup (the serving batcher's unit of work).
    pub fn get_many(
        &self,
        table: &str,
        entities: &[EntityId],
        now: Timestamp,
    ) -> Vec<Option<FeatureRecord>> {
        entities.iter().map(|&e| self.get(table, e, now)).collect()
    }

    /// Everything currently live in a table — the online→offline
    /// bootstrap read (§4.5.5).
    pub fn dump_table(&self, table: &str, now: Timestamp) -> Vec<FeatureRecord> {
        let ttl = self.ttls.read().unwrap().get(table).copied().unwrap_or(i64::MAX);
        let shards = self.shards.read().unwrap();
        let mut out = Vec::new();
        for s in shards.iter() {
            for ((t, _), e) in s.read().unwrap().iter() {
                if t == table && (ttl == i64::MAX || now - e.written_at < ttl) {
                    out.push(e.record.clone());
                }
            }
        }
        out.sort_by_key(|r| r.entity);
        out
    }

    /// Drop TTL-expired entries (Redis does this lazily + actively; we
    /// expose it so tests and the freshness monitor can force it).
    pub fn evict_expired(&self, now: Timestamp) -> u64 {
        let ttls = self.ttls.read().unwrap().clone();
        let shards = self.shards.read().unwrap();
        let mut evicted = 0;
        for s in shards.iter() {
            let mut g = s.write().unwrap();
            g.retain(|(table, _), e| {
                let ttl = ttls.get(table).copied().unwrap_or(i64::MAX);
                let keep = ttl == i64::MAX || now - e.written_at < ttl;
                if !keep {
                    evicted += 1;
                }
                keep
            });
        }
        evicted
    }

    /// Scale to `n` shards, rehashing all entries (§3.1.3). Readers are
    /// briefly blocked by the outer write lock — the paper's "scale
    /// up/down managed Redis" with a short rebalance pause.
    pub fn scale_to(&self, n: usize) -> Result<()> {
        if n == 0 {
            return Err(FsError::InvalidArg("shard count must be > 0".into()));
        }
        let mut shards = self.shards.write().unwrap();
        let mut entries: Vec<((String, EntityId), Entry)> = Vec::new();
        for s in shards.iter() {
            entries.extend(s.write().unwrap().drain());
        }
        let new: Vec<RwLock<ShardMap>> = (0..n).map(|_| RwLock::new(HashMap::new())).collect();
        for (key, entry) in entries {
            let idx = self.shard_of(key.1, n);
            new[idx].write().unwrap().insert(key, entry);
        }
        *shards = new;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.shards.read().unwrap().iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: EntityId, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn alg2_insert_override_noop() {
        let s = OnlineStore::new(4);
        // insert
        s.merge("t", &[rec(1, 100, 150, 1.0)], 150);
        assert_eq!(s.get("t", 1, 150).unwrap().values[0], 1.0);
        // newer event_ts → override
        s.merge("t", &[rec(1, 200, 160, 2.0)], 160);
        assert_eq!(s.get("t", 1, 160).unwrap().values[0], 2.0);
        // older event_ts → no-op (late merge of an old window)
        let m = s.merge("t", &[rec(1, 100, 999, 9.0)], 999);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 999).unwrap().values[0], 2.0);
        // same event_ts, newer creation_ts → override (late-arriving data
        // recompute — Fig 5's R3)
        s.merge("t", &[rec(1, 200, 500, 3.0)], 500);
        assert_eq!(s.get("t", 1, 500).unwrap().values[0], 3.0);
        // same event_ts, older creation_ts → no-op
        let m = s.merge("t", &[rec(1, 200, 170, 9.0)], 555);
        assert_eq!(m.skipped, 1);
        assert_eq!(s.get("t", 1, 555).unwrap().values[0], 3.0);
    }

    #[test]
    fn merge_order_independent_converged_state() {
        // Any delivery order of the same record set converges to the same
        // online state (Eq. 2) — the eventual-consistency core.
        let records = vec![
            rec(1, 10, 11, 0.0),
            rec(1, 20, 21, 1.0),
            rec(1, 20, 99, 2.0),
            rec(1, 30, 31, 3.0),
            rec(2, 5, 6, 4.0),
        ];
        let mut perm = records.clone();
        for rot in 0..perm.len() {
            perm.rotate_left(1);
            let s = OnlineStore::new(2);
            for r in &perm {
                s.merge("t", std::slice::from_ref(r), r.creation_ts);
            }
            assert_eq!(s.get("t", 1, 1_000).unwrap().version(), (30, 31), "rot={rot}");
            assert_eq!(s.get("t", 2, 1_000).unwrap().version(), (5, 6));
        }
    }

    #[test]
    fn ttl_expiry_and_eviction() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 100);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 1_000);
        assert!(s.get("t", 1, 1_050).is_some());
        assert!(s.get("t", 1, 1_100).is_none()); // expired at exactly ttl
        assert_eq!(s.len(), 1); // still resident until evicted
        assert_eq!(s.evict_expired(1_100), 1);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn tables_are_isolated() {
        let s = OnlineStore::new(2);
        s.merge("a", &[rec(1, 10, 20, 1.0)], 20);
        s.merge("b", &[rec(1, 99, 100, 2.0)], 100);
        assert_eq!(s.get("a", 1, 200).unwrap().values[0], 1.0);
        assert_eq!(s.get("b", 1, 200).unwrap().values[0], 2.0);
        assert_eq!(s.dump_table("a", 200).len(), 1);
    }

    #[test]
    fn get_many_preserves_order() {
        let s = OnlineStore::new(4);
        s.merge("t", &[rec(5, 10, 20, 5.0), rec(7, 10, 20, 7.0)], 20);
        let got = s.get_many("t", &[7, 6, 5], 100);
        assert_eq!(got[0].as_ref().unwrap().values[0], 7.0);
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().unwrap().values[0], 5.0);
    }

    #[test]
    fn scale_preserves_data() {
        let s = OnlineStore::new(2);
        let rows: Vec<_> = (0..500).map(|i| rec(i, 10, 20, i as f32)).collect();
        s.merge("t", &rows, 20);
        s.scale_to(16).unwrap();
        assert_eq!(s.shard_count(), 16);
        for i in 0..500 {
            assert_eq!(s.get("t", i, 100).unwrap().values[0], i as f32);
        }
        s.scale_to(1).unwrap();
        assert_eq!(s.len(), 500);
        assert!(s.scale_to(0).is_err());
    }

    #[test]
    fn dump_table_skips_expired() {
        let s = OnlineStore::new(2);
        s.set_ttl("t", 50);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 0);
        s.merge("t", &[rec(2, 10, 20, 2.0)], 100);
        let dump = s.dump_table("t", 120);
        assert_eq!(dump.len(), 1);
        assert_eq!(dump[0].entity, 2);
    }

    #[test]
    fn hit_miss_accounting() {
        let s = OnlineStore::new(2);
        s.merge("t", &[rec(1, 10, 20, 1.0)], 20);
        s.get("t", 1, 30);
        s.get("t", 2, 30);
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_merges_converge() {
        use std::sync::Arc;
        let s = Arc::new(OnlineStore::new(8));
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let r = rec(i % 50, (i as i64) + 1, (i as i64) + 2 + t as i64, t as f32);
                        s.merge("t", &[r], 1_000);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every entity holds the max-version record written for it.
        for e in 0..50u64 {
            let got = s.get("t", e, 10_000).unwrap();
            // max i with i%50==e is 150+e → event_ts 151+e, creation from
            // the thread with largest t.
            assert_eq!(got.event_ts, 151 + e as i64);
            assert_eq!(got.creation_ts, 151 + e as i64 + 1 + 7);
        }
    }
}
