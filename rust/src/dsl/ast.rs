//! DSL abstract syntax.

use crate::types::{FsError, Result};

/// Supported rolling aggregations — the five the compute artifact emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Agg {
    Sum,
    Cnt,
    Mean,
    Min,
    Max,
}

impl Agg {
    pub const ALL: [Agg; 5] = [Agg::Sum, Agg::Cnt, Agg::Mean, Agg::Min, Agg::Max];

    pub fn parse(s: &str) -> Result<Agg> {
        match s {
            "sum" => Ok(Agg::Sum),
            "cnt" | "count" => Ok(Agg::Cnt),
            "mean" | "avg" => Ok(Agg::Mean),
            "min" => Ok(Agg::Min),
            "max" => Ok(Agg::Max),
            other => Err(FsError::Dsl(format!("unknown aggregation '{other}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Agg::Sum => "sum",
            Agg::Cnt => "cnt",
            Agg::Mean => "mean",
            Agg::Min => "min",
            Agg::Max => "max",
        }
    }

    /// Index of this aggregation in the artifact's output tuple.
    pub fn output_index(self) -> usize {
        match self {
            Agg::Sum => 0,
            Agg::Cnt => 1,
            Agg::Mean => 2,
            Agg::Min => 3,
            Agg::Max => 4,
        }
    }
}

/// `rolling(<value_col>, window=<bins|Nd|Nh>, aggs=[..])`.
#[derive(Debug, Clone, PartialEq)]
pub struct RollingSpec {
    pub value_col: String,
    pub window_bins: usize,
    pub aggs: Vec<Agg>,
}

impl RollingSpec {
    pub fn validate(&self) -> Result<()> {
        if self.window_bins == 0 {
            return Err(FsError::Dsl("window must be >= 1 bin".into()));
        }
        if self.aggs.is_empty() {
            return Err(FsError::Dsl("at least one aggregation required".into()));
        }
        let mut seen = std::collections::HashSet::new();
        for a in &self.aggs {
            if !seen.insert(a) {
                return Err(FsError::Dsl(format!("duplicate aggregation '{}'", a.name())));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agg_parse_and_names() {
        assert_eq!(Agg::parse("sum").unwrap(), Agg::Sum);
        assert_eq!(Agg::parse("avg").unwrap(), Agg::Mean);
        assert_eq!(Agg::parse("count").unwrap(), Agg::Cnt);
        assert!(Agg::parse("median").is_err());
        for a in Agg::ALL {
            assert_eq!(Agg::parse(a.name()).unwrap(), a);
        }
    }

    #[test]
    fn output_indices_are_distinct() {
        let mut idx: Vec<_> = Agg::ALL.iter().map(|a| a.output_index()).collect();
        idx.sort();
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rolling_validation() {
        let ok = RollingSpec { value_col: "v".into(), window_bins: 3, aggs: vec![Agg::Sum] };
        assert!(ok.validate().is_ok());
        let zero = RollingSpec { window_bins: 0, ..ok.clone() };
        assert!(zero.validate().is_err());
        let dup = RollingSpec { aggs: vec![Agg::Sum, Agg::Sum], ..ok.clone() };
        assert!(dup.validate().is_err());
        let empty = RollingSpec { aggs: vec![], ..ok };
        assert!(empty.validate().is_err());
    }
}
