//! Built-in UDF registry and the in-process rolling recompute.
//!
//! The paper's UDF contract is `udf(source_df, context) → feature_df`
//! (§4.2).  Our Rust equivalent operates on the binned planes: a UDF
//! receives the `[E, halo + T]` per-bin partials and must produce the
//! `[E, T]` rolling planes.  `udf_rolling_recompute` is the reference
//! black-box implementation — it recomputes every window from scratch
//! (O(T·W)), which is precisely the cost profile the planner cannot
//! optimize away for opaque UDFs.

use std::collections::HashMap;
use std::sync::Arc;

use crate::runtime::{rolling_reference, BinPlanes, RollPlanes};
use crate::types::{FsError, Result};

/// A UDF over binned planes. `window` comes from the feature-set spec's
/// context (the paper's `context` argument).
pub type PlaneUdf = Arc<dyn Fn(&BinPlanes, usize) -> Result<RollPlanes> + Send + Sync>;

/// Named registry of built-in UDFs (§3.1.7's SDK would let customers
/// register their own; the registry is the extension point).
#[derive(Clone)]
pub struct UdfRegistry {
    udfs: HashMap<String, PlaneUdf>,
}

impl std::fmt::Debug for UdfRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "UdfRegistry({:?})", self.udfs.keys().collect::<Vec<_>>())
    }
}

impl Default for UdfRegistry {
    fn default() -> Self {
        let mut r = UdfRegistry { udfs: HashMap::new() };
        r.register("rolling_recompute", Arc::new(|planes, w| Ok(udf_rolling_recompute(planes, w))));
        r.register(
            "rolling_recompute_2x",
            // A deliberately heavier UDF (recomputes twice) for ablation
            // benches: black-box cost is opaque to the planner.
            Arc::new(|planes, w| {
                let _ = udf_rolling_recompute(planes, w);
                Ok(udf_rolling_recompute(planes, w))
            }),
        );
        r
    }
}

impl UdfRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, name: &str, udf: PlaneUdf) {
        self.udfs.insert(name.to_string(), udf);
    }

    pub fn get(&self, name: &str) -> Result<PlaneUdf> {
        self.udfs
            .get(name)
            .cloned()
            .ok_or_else(|| FsError::NotFound(format!("udf '{name}'")))
    }

    pub fn names(&self) -> Vec<String> {
        let mut n: Vec<_> = self.udfs.keys().cloned().collect();
        n.sort();
        n
    }
}

/// The black-box rolling recompute: every output bin re-reduces its full
/// window from the input planes.
pub fn udf_rolling_recompute(planes: &BinPlanes, window: usize) -> RollPlanes {
    rolling_reference(planes, window)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planes() -> BinPlanes {
        let mut b = BinPlanes::empty(2, 6);
        b.add_event(0, 0, 1.0);
        b.add_event(0, 3, 5.0);
        b.add_event(1, 5, -2.0);
        b
    }

    #[test]
    fn registry_resolves_builtin() {
        let r = UdfRegistry::new();
        let udf = r.get("rolling_recompute").unwrap();
        let out = udf(&planes(), 3).unwrap();
        assert_eq!(out.sum.cols, 4); // 6 - (3-1)
        assert!(r.get("nope").is_err());
    }

    #[test]
    fn registry_lists_names() {
        let r = UdfRegistry::new();
        assert!(r.names().contains(&"rolling_recompute".to_string()));
    }

    #[test]
    fn custom_registration() {
        let mut r = UdfRegistry::new();
        r.register(
            "zeros",
            Arc::new(|p, w| {
                let out = udf_rolling_recompute(p, w);
                Ok(RollPlanes {
                    sum: crate::runtime::Tensor2::zeros(out.sum.rows, out.sum.cols),
                    ..out
                })
            }),
        );
        let out = r.get("zeros").unwrap()(&planes(), 2).unwrap();
        assert!(out.sum.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn recompute_matches_reference_by_construction() {
        let p = planes();
        let a = udf_rolling_recompute(&p, 2);
        let b = rolling_reference(&p, 2);
        assert_eq!(a.sum.data, b.sum.data);
        assert_eq!(a.min.data, b.min.data);
    }
}
