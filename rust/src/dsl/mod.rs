//! Transformation DSL and query planning (paper §3.1.6).
//!
//! When a feature set declares its transformation in the DSL ("a common
//! case is rolling window aggregation"), the engine understands the
//! computation and plans it onto the optimized AOT artifact.  A UDF is a
//! black box: the engine can only run it as-is, so it gets the naive
//! per-window recompute plan.  `benches/dsl_vs_udf.rs` measures exactly
//! this gap (experiment E5).

pub mod ast;
pub mod parser;
pub mod planner;
pub mod udf;

pub use ast::{Agg, RollingSpec};
pub use parser::parse_rolling;
pub use planner::{plan_transform, ExecutionPlan, PlanKind};
pub use udf::{udf_rolling_recompute, UdfRegistry};
