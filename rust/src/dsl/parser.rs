//! Recursive-descent parser for the transformation DSL.
//!
//! Grammar:
//! ```text
//! rolling    := "rolling" "(" ident "," kwargs ")"
//! kwargs     := kwarg ("," kwarg)*
//! kwarg      := "window" "=" duration | "aggs" "=" "[" agg ("," agg)* "]"
//! duration   := INT | INT ("d"|"h"|"m")     -- suffixed forms need the
//!                                              feature-set granularity
//! ```

use super::ast::{Agg, RollingSpec};
use crate::types::time::{Granularity, DAY, HOUR, MINUTE};
use crate::types::{FsError, Result};

struct Lexer<'a> {
    src: &'a str,
    pos: usize,
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Int(i64),
    /// integer with a duration suffix, e.g. `30d`
    Duration(i64, char),
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Eq,
    End,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Lexer { src, pos: 0 }
    }

    fn err(&self, msg: &str) -> FsError {
        FsError::Dsl(format!("at byte {}: {msg}", self.pos))
    }

    fn next_tok(&mut self) -> Result<Tok> {
        let b = self.src.as_bytes();
        while self.pos < b.len() && (b[self.pos] as char).is_whitespace() {
            self.pos += 1;
        }
        if self.pos >= b.len() {
            return Ok(Tok::End);
        }
        let c = b[self.pos] as char;
        self.pos += 1;
        match c {
            '(' => Ok(Tok::LParen),
            ')' => Ok(Tok::RParen),
            '[' => Ok(Tok::LBracket),
            ']' => Ok(Tok::RBracket),
            ',' => Ok(Tok::Comma),
            '=' => Ok(Tok::Eq),
            c if c.is_ascii_digit() => {
                let start = self.pos - 1;
                while self.pos < b.len() && (b[self.pos] as char).is_ascii_digit() {
                    self.pos += 1;
                }
                let n: i64 = self.src[start..self.pos]
                    .parse()
                    .map_err(|_| self.err("bad integer"))?;
                if self.pos < b.len() && matches!(b[self.pos] as char, 'd' | 'h' | 'm') {
                    let suffix = b[self.pos] as char;
                    self.pos += 1;
                    Ok(Tok::Duration(n, suffix))
                } else {
                    Ok(Tok::Int(n))
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = self.pos - 1;
                while self.pos < b.len()
                    && ((b[self.pos] as char).is_ascii_alphanumeric() || b[self.pos] == b'_')
                {
                    self.pos += 1;
                }
                Ok(Tok::Ident(self.src[start..self.pos].to_string()))
            }
            other => Err(self.err(&format!("unexpected character '{other}'"))),
        }
    }
}

struct Parser<'a> {
    lex: Lexer<'a>,
    cur: Tok,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Result<Self> {
        let mut lex = Lexer::new(src);
        let cur = lex.next_tok()?;
        Ok(Parser { lex, cur })
    }

    fn bump(&mut self) -> Result<Tok> {
        let next = self.lex.next_tok()?;
        Ok(std::mem::replace(&mut self.cur, next))
    }

    fn expect(&mut self, want: Tok) -> Result<()> {
        if self.cur == want {
            self.bump()?;
            Ok(())
        } else {
            Err(FsError::Dsl(format!("expected {want:?}, found {:?}", self.cur)))
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.bump()? {
            Tok::Ident(s) => Ok(s),
            other => Err(FsError::Dsl(format!("expected identifier, found {other:?}"))),
        }
    }
}

/// Convert a window duration token to bins given the feature-set
/// granularity; bare integers are already bins.
fn to_bins(tok: Tok, g: Granularity) -> Result<usize> {
    let secs = match tok {
        Tok::Int(n) => return Ok(n.max(0) as usize),
        Tok::Duration(n, 'd') => n * DAY,
        Tok::Duration(n, 'h') => n * HOUR,
        Tok::Duration(n, 'm') => n * MINUTE,
        other => return Err(FsError::Dsl(format!("expected window duration, found {other:?}"))),
    };
    if secs % g.secs() != 0 {
        return Err(FsError::Dsl(format!(
            "window {secs}s is not a multiple of the feature-set granularity {}s",
            g.secs()
        )));
    }
    Ok((secs / g.secs()) as usize)
}

/// Parse `rolling(value, window=.., aggs=[..])`.
pub fn parse_rolling(src: &str, granularity: Granularity) -> Result<RollingSpec> {
    let mut p = Parser::new(src)?;
    let head = p.ident()?;
    if head != "rolling" {
        return Err(FsError::Dsl(format!("expected 'rolling', found '{head}'")));
    }
    p.expect(Tok::LParen)?;
    let value_col = p.ident()?;
    let mut window_bins: Option<usize> = None;
    let mut aggs: Option<Vec<Agg>> = None;

    while p.cur == Tok::Comma {
        p.bump()?;
        let key = p.ident()?;
        p.expect(Tok::Eq)?;
        match key.as_str() {
            "window" => {
                let tok = p.bump()?;
                window_bins = Some(to_bins(tok, granularity)?);
            }
            "aggs" => {
                p.expect(Tok::LBracket)?;
                let mut list = Vec::new();
                loop {
                    let name = p.ident()?;
                    list.push(Agg::parse(&name)?);
                    if p.cur == Tok::Comma {
                        p.bump()?;
                    } else {
                        break;
                    }
                }
                p.expect(Tok::RBracket)?;
                aggs = Some(list);
            }
            other => return Err(FsError::Dsl(format!("unknown kwarg '{other}'"))),
        }
    }
    p.expect(Tok::RParen)?;
    if p.cur != Tok::End {
        return Err(FsError::Dsl("trailing input after rolling(...)".into()));
    }

    let spec = RollingSpec {
        value_col,
        window_bins: window_bins.ok_or_else(|| FsError::Dsl("missing window=".into()))?,
        aggs: aggs.unwrap_or_else(|| Agg::ALL.to_vec()),
    };
    spec.validate()?;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_canonical_form() {
        let s = parse_rolling(
            "rolling(value, window=30, aggs=[sum,cnt,mean,min,max])",
            Granularity::daily(),
        )
        .unwrap();
        assert_eq!(s.value_col, "value");
        assert_eq!(s.window_bins, 30);
        assert_eq!(s.aggs.len(), 5);
    }

    #[test]
    fn parses_duration_suffixes() {
        let s = parse_rolling("rolling(v, window=30d)", Granularity::daily()).unwrap();
        assert_eq!(s.window_bins, 30);
        let s = parse_rolling("rolling(v, window=24h)", Granularity::hourly()).unwrap();
        assert_eq!(s.window_bins, 24);
        let s = parse_rolling("rolling(v, window=2d)", Granularity::hourly()).unwrap();
        assert_eq!(s.window_bins, 48);
        // defaults to all aggs
        assert_eq!(s.aggs, Agg::ALL.to_vec());
    }

    #[test]
    fn granularity_mismatch_rejected() {
        // 90 minutes over hourly bins is not integral.
        assert!(parse_rolling("rolling(v, window=90m)", Granularity::hourly()).is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let s =
            parse_rolling("  rolling ( v , window = 7 , aggs = [ sum , max ] ) ", Granularity::daily())
                .unwrap();
        assert_eq!(s.window_bins, 7);
        assert_eq!(s.aggs, vec![Agg::Sum, Agg::Max]);
    }

    #[test]
    fn rejects_malformed() {
        let g = Granularity::daily();
        for bad in [
            "scrolling(v, window=3)",
            "rolling(v)",
            "rolling(v, window=3, aggs=[])",
            "rolling(v, window=3, aggs=[sum,sum])",
            "rolling(v, window=3) trailing",
            "rolling(v, window=)",
            "rolling(v, wndow=3)",
            "rolling(v, window=3, aggs=[median])",
            "rolling(v, window=0)",
            "rolling",
            "",
        ] {
            assert!(parse_rolling(bad, g).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn roundtrips_assets_constructor_format() {
        // FeatureSetSpec::rolling emits this exact shape — keep in sync.
        let code = "rolling(value, window=30, aggs=[sum,cnt,mean,min,max])";
        assert!(parse_rolling(code, Granularity::daily()).is_ok());
    }
}
