//! Query planner (§3.1.6): choose the execution strategy for a
//! feature-set transformation.
//!
//! * DSL rolling transform + a fitting AOT artifact → **optimized plan**
//!   (the fused Pallas program).
//! * DSL transform with no fitting artifact → naive-HLO plan if present,
//!   else the in-process Rust fallback (correctness first).
//! * UDF → black box: always the Rust row-engine recompute.

use super::ast::RollingSpec;
use super::parser::parse_rolling;
use crate::metadata::assets::TransformSpec;
use crate::runtime::{Manifest, Variant};
use crate::types::time::Granularity;
use crate::types::{FsError, Result};

/// How the transformation will execute.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanKind {
    /// AOT artifact via PJRT, with the given plan variant.
    Artifact(Variant),
    /// In-process Rust evaluation (UDF black box or no-artifact fallback).
    RustUdf,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub kind: PlanKind,
    pub rolling: RollingSpec,
    /// Why this plan was chosen (surfaced in logs/monitoring).
    pub rationale: String,
}

/// Plan a transformation against the artifact manifest.
pub fn plan_transform(
    transform: &TransformSpec,
    granularity: Granularity,
    manifest: Option<&Manifest>,
) -> Result<ExecutionPlan> {
    match transform {
        TransformSpec::Dsl(code) => {
            let rolling = parse_rolling(code, granularity)?;
            let window = rolling.window_bins;
            let has_artifact = manifest
                .map(|m| m.windows().contains(&window))
                .unwrap_or(false);
            if has_artifact {
                Ok(ExecutionPlan {
                    kind: PlanKind::Artifact(Variant::Dsl),
                    rolling,
                    rationale: format!(
                        "DSL rolling window={window}: optimized AOT plan (fused one-pass kernel)"
                    ),
                })
            } else {
                Ok(ExecutionPlan {
                    kind: PlanKind::RustUdf,
                    rolling,
                    rationale: format!(
                        "DSL rolling window={window}: no AOT artifact for this window; \
                         falling back to in-process evaluation"
                    ),
                })
            }
        }
        TransformSpec::Udf(name) => {
            // Black box: the engine cannot see inside the UDF (§3.1.6
            // "feature store treats the UDF as a black box"). The built-in
            // registry resolves the name to a Rust implementation; its
            // rolling parameters come from the feature-set spec via the
            // registry, so here we only need a placeholder RollingSpec for
            // the record schema.
            if name.is_empty() {
                return Err(FsError::Dsl("empty udf name".into()));
            }
            Ok(ExecutionPlan {
                kind: PlanKind::RustUdf,
                rolling: RollingSpec {
                    value_col: "value".into(),
                    window_bins: 0, // filled by the UDF registry at execution
                    aggs: super::ast::Agg::ALL.to_vec(),
                },
                rationale: format!("UDF '{name}': black box, per-window recompute plan"),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest_with_windows(ws: &[usize]) -> Manifest {
        let arts = ws
            .iter()
            .map(|w| {
                format!(
                    r#"{{"name":"a{w}","shape":"s","variant":"dsl","file":"f","entities":8,
                        "time_bins":16,"window":{w},"entity_block":8,"inputs":[],"outputs":[]}}"#
                )
            })
            .collect::<Vec<_>>()
            .join(",");
        Manifest::parse(
            &format!(r#"{{"format":1,"artifacts":[{arts}]}}"#),
            PathBuf::new(),
        )
        .unwrap()
    }

    #[test]
    fn dsl_with_artifact_gets_optimized_plan() {
        let m = manifest_with_windows(&[4, 30]);
        let t = TransformSpec::Dsl("rolling(value, window=30)".into());
        let plan = plan_transform(&t, Granularity::daily(), Some(&m)).unwrap();
        assert_eq!(plan.kind, PlanKind::Artifact(Variant::Dsl));
        assert_eq!(plan.rolling.window_bins, 30);
    }

    #[test]
    fn dsl_without_artifact_falls_back() {
        let m = manifest_with_windows(&[4]);
        let t = TransformSpec::Dsl("rolling(value, window=99)".into());
        let plan = plan_transform(&t, Granularity::daily(), Some(&m)).unwrap();
        assert_eq!(plan.kind, PlanKind::RustUdf);
        assert!(plan.rationale.contains("falling back"));
    }

    #[test]
    fn no_manifest_falls_back() {
        let t = TransformSpec::Dsl("rolling(value, window=4)".into());
        let plan = plan_transform(&t, Granularity::daily(), None).unwrap();
        assert_eq!(plan.kind, PlanKind::RustUdf);
    }

    #[test]
    fn udf_is_black_box() {
        let m = manifest_with_windows(&[4]);
        let t = TransformSpec::Udf("rolling_recompute".into());
        let plan = plan_transform(&t, Granularity::daily(), Some(&m)).unwrap();
        assert_eq!(plan.kind, PlanKind::RustUdf);
        assert!(plan.rationale.contains("black box"));
    }

    #[test]
    fn bad_dsl_propagates_error() {
        let t = TransformSpec::Dsl("garbage(".into());
        assert!(plan_transform(&t, Granularity::daily(), None).is_err());
    }
}
