//! Snapshot GC: delete files no live manifest generation references.
//!
//! **Live set.** The files referenced by the two newest valid manifest
//! generations, plus those two manifest files themselves. Keeping the
//! previous generation pinned means a crash *during* a commit — after
//! the new manifest's data files exist but before anything references
//! them — can never race GC into deleting the only valid root.
//!
//! **Two-pass deletion.** A freshly created fragment or segment is
//! briefly unreferenced: it exists on disk before the manifest commit
//! that adds it lands. A single list-then-delete sweep could reap it in
//! that window. GC therefore only *marks* an unreferenced file on the
//! pass that first sees it and deletes it on a later pass **if it is
//! still unreferenced** — any file that was in the middle of being
//! committed has either made it into the manifest by then (kept) or its
//! writer crashed (a true orphan, safe to reap). `.tmp` files are
//! excluded entirely: they are swept at open time, when no writer can
//! be mid-rename.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::DurableStore;
use crate::types::Result;
use crate::util::backoff::{retry, Backoff};
use crate::util::wake::Wake;

/// One GC pass's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GcStats {
    /// Files deleted this pass (marked unreferenced on an earlier pass).
    pub removed: usize,
    /// Files newly marked; deletion candidates for the next pass.
    pub pending: usize,
    /// Files pinned by the live manifest generations.
    pub live: usize,
}

/// One mark-or-sweep pass over the store directory (see module docs).
pub fn collect(store: &DurableStore) -> Result<GcStats> {
    let live = store.manifests().live_files();
    let listed = store.fs().list(store.dir())?;
    let mut pending = store.gc_pending().lock().unwrap();
    let mut next_pending: HashSet<String> = HashSet::new();
    let mut stats = GcStats::default();
    for path in listed {
        let Some(name) = path.file_name().and_then(|n| n.to_str()).map(str::to_string) else {
            continue;
        };
        if name.ends_with(".tmp") {
            continue; // open-time sweep territory, not ours
        }
        if live.contains(&name) {
            stats.live += 1;
            continue;
        }
        if pending.contains(&name) {
            match store.fs().remove(&path) {
                Ok(()) => stats.removed += 1,
                Err(e) => {
                    log::warn!("gc: removing {name} failed ({e}); will retry");
                    next_pending.insert(name);
                }
            }
        } else {
            next_pending.insert(name);
        }
    }
    stats.pending = next_pending.len();
    *pending = next_pending;
    Ok(stats)
}

/// Background GC thread: periodic passes (plus on-demand pings),
/// transient I/O errors retried with bounded backoff, persistent
/// errors logged — never fatal to the driver. Dropping stops it.
pub struct GcDriver {
    stop: Arc<AtomicBool>,
    wake: Arc<Wake>,
    removed: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl GcDriver {
    pub fn spawn(store: Arc<DurableStore>, period: Duration) -> GcDriver {
        Self::spawn_with_backoff(store, period, Backoff::default())
    }

    pub fn spawn_with_backoff(
        store: Arc<DurableStore>,
        period: Duration,
        policy: Backoff,
    ) -> GcDriver {
        let stop = Arc::new(AtomicBool::new(false));
        let wake = Arc::new(Wake::default());
        let removed = Arc::new(AtomicU64::new(0));
        let (stop2, wake2, removed2) = (stop.clone(), wake.clone(), removed.clone());
        let handle = std::thread::Builder::new()
            .name("geofs-storage-gc".into())
            .spawn(move || {
                let mut seen = 0u64;
                loop {
                    if stop2.load(Ordering::Acquire) {
                        return;
                    }
                    seen = wake2.wait(seen, period);
                    if stop2.load(Ordering::Acquire) {
                        return;
                    }
                    match retry(&policy, || collect(&store)) {
                        Ok(stats) => {
                            removed2.fetch_add(stats.removed as u64, Ordering::Relaxed);
                        }
                        Err(e) => log::warn!("gc pass failed: {e}"),
                    }
                }
            })
            .expect("spawn storage gc driver");
        GcDriver { stop, wake, removed, handle: Some(handle) }
    }

    /// Nudge the driver to run a pass now (e.g. right after a checkpoint
    /// dropped a pile of references).
    pub fn ping(&self) {
        self.wake.ping();
    }

    /// Files deleted since spawn (test/metrics hook).
    pub fn removed(&self) -> u64 {
        self.removed.load(Ordering::Relaxed)
    }
}

impl Drop for GcDriver {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.wake.ping();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
