//! The durable log: a [`PartitionedLog`] memory mirror backed by
//! manifest-addressed fragment files.
//!
//! [`DurableLog`] keeps the crate's existing in-memory log as the read
//! path (reads, tails, truncation all hit RAM exactly as before) and
//! adds a write-ahead file path in front of it: an append encodes the
//! record, writes one checksummed frame to the partition's active
//! fragment, fsyncs (the **ack**), and only then pushes into the
//! memory mirror — all under one per-partition writer lock, so file
//! order and memory order are identical by construction.
//!
//! **Crash-safe fragment lifecycle.** A fragment file is created and
//! fsynced, then a manifest generation referencing it is committed,
//! and only then does the first record land in it — so every acked
//! record lives in a manifest-referenced file, and a crash between
//! create and commit strands only an empty, unreferenced file for GC.
//! Rolls (size-bounded) seal the old fragment and open the next one in
//! a single manifest commit; the sealed frame `count` is derived from
//! the memory mirror's high-water mark, i.e. exactly the acked
//! appends. A failed roll is not fatal: the log keeps appending to the
//! oversized active fragment and retries the roll on a later append.
//!
//! **Recovery.** `open` replays the manifest's fragment list per
//! partition in base order: sealed fragments must decode exactly
//! `count` frames (anything less fails closed, [`FsError::Corrupt`]);
//! the final, unsealed fragment tolerates a torn tail — its valid
//! prefix is the recovered state, and recovery seals it at that count
//! so the torn bytes can never be mistaken for records later. Offsets
//! below the manifest's per-partition `bases` were truncated before
//! the crash and are skipped on replay.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::fragment::{read_fragment, FragmentMeta, FragmentWriter};
use super::manifest::{Manifest, ManifestStore};
use super::vfs::{corrupt, Vfs};
use crate::geo::replication::ReplBatch;
use crate::stream::log::{PartitionedLog, StreamEvent};
use crate::types::{FeatureRecord, Result};
use crate::util::backoff::{retry, Backoff};

/// A record type the durable log can persist. Encoding is the storage
/// layer's own little-endian framing — checksums and lengths live in
/// the fragment frame, not here.
pub trait LogRecord: Clone + Send + Sync + 'static {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(bytes: &[u8]) -> Result<Self>
    where
        Self: Sized;
}

// ---- byte cursor ----------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            return Err(corrupt("log record truncated"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(corrupt("log record has trailing bytes"));
        }
        Ok(())
    }
}

/// Sanity bound for decoded counts (a torn length field must not
/// trigger a giant allocation).
const MAX_DECODE_ITEMS: u32 = 16 << 20;

impl LogRecord for StreamEvent {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.ts.to_le_bytes());
        out.extend_from_slice(&self.value.to_le_bytes());
        out.extend_from_slice(&(self.key.len() as u32).to_le_bytes());
        out.extend_from_slice(self.key.as_bytes());
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(bytes);
        let seq = c.u64()?;
        let ts = c.i64()?;
        let value = c.f32()?;
        let klen = c.u32()? as usize;
        let key = std::str::from_utf8(c.take(klen)?)
            .map_err(|_| corrupt("stream event key is not utf-8"))?
            .to_string();
        c.done()?;
        Ok(StreamEvent { seq, key, ts, value })
    }
}

impl LogRecord for ReplBatch {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.appended_at.to_le_bytes());
        out.extend_from_slice(&(self.table.len() as u32).to_le_bytes());
        out.extend_from_slice(self.table.as_bytes());
        out.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for r in self.records.iter() {
            out.extend_from_slice(&r.entity.to_le_bytes());
            out.extend_from_slice(&r.event_ts.to_le_bytes());
            out.extend_from_slice(&r.creation_ts.to_le_bytes());
            out.extend_from_slice(&(r.values.len() as u32).to_le_bytes());
            for v in r.values.iter() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(bytes);
        let appended_at = c.i64()?;
        let tlen = c.u32()? as usize;
        let table = std::str::from_utf8(c.take(tlen)?)
            .map_err(|_| corrupt("repl batch table is not utf-8"))?
            .to_string();
        let n = c.u32()?;
        if n > MAX_DECODE_ITEMS {
            return Err(corrupt("repl batch record count implausible"));
        }
        let mut records = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let entity = c.u64()?;
            let event_ts = c.i64()?;
            let creation_ts = c.i64()?;
            let nv = c.u32()?;
            if nv > MAX_DECODE_ITEMS {
                return Err(corrupt("repl batch value count implausible"));
            }
            let mut values = Vec::with_capacity(nv as usize);
            for _ in 0..nv {
                values.push(c.f32()?);
            }
            records.push(FeatureRecord::new(entity, event_ts, creation_ts, values));
        }
        c.done()?;
        Ok(ReplBatch { table, records: records.into(), appended_at })
    }
}

// ---- the durable log -------------------------------------------------

/// Tuning knobs for one durable log.
#[derive(Debug, Clone)]
pub struct DurableLogOptions {
    /// Roll the active fragment once it exceeds this size.
    pub fragment_max_bytes: u64,
    /// fsync each appended frame (the ack point). Turning this off
    /// trades the ack guarantee for throughput — E-DUR measures both.
    pub fsync_every_append: bool,
    /// Retry policy for roll-time manifest commits (transient I/O).
    pub roll_retry: Backoff,
}

impl Default for DurableLogOptions {
    fn default() -> Self {
        DurableLogOptions {
            fragment_max_bytes: 1 << 20,
            fsync_every_append: true,
            roll_retry: Backoff::default(),
        }
    }
}

struct PartWriter {
    /// The active fragment's writer + file name. `None` until the first
    /// append (or after a failed append retires the fragment).
    active: Option<(FragmentWriter, String)>,
}

/// Write-ahead, manifest-addressed log over a [`PartitionedLog`] memory
/// mirror. See module docs for the protocol.
pub struct DurableLog<T: LogRecord> {
    name: String,
    prefix: String,
    fs: Arc<dyn Vfs>,
    dir: PathBuf,
    manifests: Arc<ManifestStore>,
    opts: DurableLogOptions,
    mem: PartitionedLog<T>,
    writers: Vec<Mutex<PartWriter>>,
}

/// Registry hook: a checkpoint commit pulls every open log's fresh
/// truncation floors into the manifest (and drops fully-reclaimed
/// sealed fragments from the reference set).
pub trait LogSection: Send + Sync {
    fn refresh(&self, m: &mut Manifest);
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

impl<T: LogRecord> DurableLog<T> {
    /// Open (or create) the named log inside `manifests`' store
    /// directory, replaying its fragments into the memory mirror. For a
    /// log already present in the manifest, the manifest's partition
    /// count is authoritative; `partitions` sizes a brand-new log.
    pub fn open(
        name: &str,
        partitions: usize,
        fs: Arc<dyn Vfs>,
        manifests: Arc<ManifestStore>,
        opts: DurableLogOptions,
    ) -> Result<Arc<DurableLog<T>>> {
        let m = manifests.current();
        let existing = m.logs.get(name);
        let partitions = existing.map(|lm| lm.partitions).unwrap_or(partitions.max(1));
        let mem = PartitionedLog::new(partitions);
        let dir = manifests.dir().to_path_buf();
        // (file name, recovered frame count) of each partition's
        // formerly-active fragment — sealed below in one commit.
        let mut seal: Vec<(String, u64)> = Vec::new();
        if let Some(lm) = existing {
            for p in 0..partitions {
                let mut frags: Vec<&FragmentMeta> =
                    lm.fragments.iter().filter(|f| f.partition == p).collect();
                frags.sort_by_key(|f| f.base);
                let floor = lm.bases.get(p).copied().unwrap_or(0);
                let mut items: Vec<T> = Vec::new();
                let mut items_base = floor;
                let mut expected: Option<u64> = None;
                for f in frags {
                    if let Some(exp) = expected {
                        if f.base != exp {
                            return Err(corrupt(format!(
                                "log '{name}' p{p}: fragment {} base {} breaks continuity \
                                 (expected {exp})",
                                f.file, f.base
                            )));
                        }
                    }
                    let data = read_fragment(
                        fs.as_ref(),
                        &dir.join(&f.file),
                        f.sealed.then_some(f.count),
                    )?;
                    if data.partition != p || data.base != f.base {
                        return Err(corrupt(format!(
                            "log '{name}' p{p}: fragment {} header disagrees with manifest",
                            f.file
                        )));
                    }
                    let count = data.payloads.len() as u64;
                    for (i, payload) in data.payloads.iter().enumerate() {
                        let off = f.base + i as u64;
                        if off < floor {
                            continue; // truncated before the crash
                        }
                        if items.is_empty() {
                            items_base = off;
                        }
                        items.push(T::decode(payload)?);
                    }
                    if !f.sealed {
                        seal.push((f.file.clone(), count));
                    }
                    expected = Some(f.base + count);
                }
                let high = expected.unwrap_or(floor).max(floor);
                if items.is_empty() {
                    items_base = high;
                }
                mem.restore_partition(p, items_base, items);
            }
        }
        let register = existing.is_none();
        if register || !seal.is_empty() {
            let name_owned = name.to_string();
            manifests.update(move |m| {
                let lm = m.logs.entry(name_owned).or_insert_with(|| {
                    super::manifest::LogManifest {
                        partitions,
                        bases: vec![0; partitions],
                        fragments: Vec::new(),
                    }
                });
                for (file, count) in &seal {
                    if let Some(f) = lm.fragments.iter_mut().find(|f| &f.file == file) {
                        f.sealed = true;
                        f.count = *count;
                    }
                }
            })?;
        }
        Ok(Arc::new(DurableLog {
            name: name.to_string(),
            prefix: sanitize(name),
            fs,
            dir,
            manifests,
            opts,
            mem,
            writers: (0..partitions).map(|_| Mutex::new(PartWriter { active: None })).collect(),
        }))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn partitions(&self) -> usize {
        self.mem.partitions()
    }

    /// The memory mirror — the read path (tails, backlog, staleness)
    /// is identical to the RAM-only log.
    pub fn mem(&self) -> &PartitionedLog<T> {
        &self.mem
    }

    /// Durably append one record to `partition`: frame → fsync (ack) →
    /// memory mirror. Returns the record's offset.
    pub fn append(&self, partition: usize, item: T) -> Result<u64> {
        let mut w = self.writers[partition].lock().unwrap();
        if w.active.is_none() {
            self.start_fragment(&mut w, partition)?;
        }
        let mut buf = Vec::new();
        item.encode(&mut buf);
        let res = {
            let (writer, _) = w.active.as_mut().unwrap();
            writer.append(&buf, self.opts.fsync_every_append)
        };
        if let Err(e) = res {
            // The fragment may now carry a torn frame: retire it so no
            // later append writes past the tear. Seal at the acked
            // count; if even that commit fails, recovery's
            // valid-prefix read of the (still unsealed) fragment
            // reaches the same acked frames.
            let (writer, file) = w.active.take().unwrap();
            let count = writer.count;
            let name = self.name.clone();
            let _ = self.manifests.update(move |m| {
                if let Some(lm) = m.logs.get_mut(&name) {
                    if let Some(f) = lm.fragments.iter_mut().find(|f| f.file == file) {
                        f.sealed = true;
                        f.count = count;
                    }
                }
            });
            return Err(e);
        }
        let off = self.mem.append(partition, item);
        if w.active.as_ref().map(|(fw, _)| fw.bytes).unwrap_or(0) >= self.opts.fragment_max_bytes {
            self.roll(&mut w, partition);
        }
        Ok(off)
    }

    /// Truncate the memory mirror below `offset`. The manifest's
    /// `bases` catch up lazily at the next commit (roll or checkpoint):
    /// replaying a few already-truncated records after a crash is
    /// harmless — sinks are idempotent and cursors are restored — while
    /// an eagerly-advanced base that outran a failed commit would not
    /// be.
    pub fn truncate_below(&self, partition: usize, offset: u64) -> u64 {
        self.mem.truncate_below(partition, offset)
    }

    /// Create the next fragment for `partition` and commit a manifest
    /// generation that (a) seals any previous active fragment at its
    /// acked count and (b) references the new fragment — all before the
    /// first append lands in it.
    fn start_fragment(&self, w: &mut PartWriter, partition: usize) -> Result<()> {
        let base = self.mem.high_water(partition);
        let file = format!("{}-p{partition}-{base:012}.frag", self.prefix);
        let path = self.dir.join(&file);
        let writer = FragmentWriter::create(self.fs.as_ref(), &path, partition, base)?;
        let commit = retry(&self.opts.roll_retry, || {
            self.manifests.update(|m| {
                let lm = m
                    .logs
                    .get_mut(&self.name)
                    .expect("durable log registered in manifest at open");
                for f in lm.fragments.iter_mut() {
                    if f.partition == partition && !f.sealed {
                        f.sealed = true;
                        f.count = base - f.base;
                    }
                }
                lm.fragments.push(FragmentMeta {
                    file: file.clone(),
                    partition,
                    base,
                    sealed: false,
                    count: 0,
                });
                Self::refresh_log(&self.mem, lm);
            })
        });
        match commit {
            Ok(_) => {
                w.active = Some((writer, file));
                Ok(())
            }
            Err(e) => {
                // Unreferenced and empty: remove eagerly, GC as backstop.
                let _ = self.fs.remove(&path);
                Err(e)
            }
        }
    }

    /// Size-bounded roll. Best-effort: on persistent commit failure the
    /// old (oversized) fragment stays active and the roll is retried by
    /// a later append.
    fn roll(&self, w: &mut PartWriter, partition: usize) {
        let saved = w.active.take();
        if let Err(e) = self.start_fragment(w, partition) {
            log::warn!(
                "durable log '{}' p{partition}: fragment roll failed ({e}); \
                 continuing on oversized fragment"
            , self.name);
            w.active = saved;
        }
    }

    fn refresh_log(mem: &PartitionedLog<T>, lm: &mut super::manifest::LogManifest) {
        for p in 0..lm.partitions.min(lm.bases.len()) {
            let b = mem.base_offset(p);
            if b > lm.bases[p] {
                lm.bases[p] = b;
            }
        }
        let bases = lm.bases.clone();
        lm.fragments.retain(|f| {
            !(f.sealed && f.base + f.count <= bases.get(f.partition).copied().unwrap_or(0))
        });
    }
}

impl<T: LogRecord> LogSection for DurableLog<T> {
    fn refresh(&self, m: &mut Manifest) {
        if let Some(lm) = m.logs.get_mut(&self.name) {
            Self::refresh_log(&self.mem, lm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::RealFs;
    use crate::testkit::TempDir;

    fn ev(seq: u64, key: &str, ts: i64, v: f32) -> StreamEvent {
        StreamEvent::new(seq, key, ts, v)
    }

    fn open_store(dir: &std::path::Path) -> Arc<ManifestStore> {
        Arc::new(ManifestStore::open(Arc::new(RealFs), dir, 0).unwrap())
    }

    fn open_log(
        ms: &Arc<ManifestStore>,
        opts: DurableLogOptions,
    ) -> Arc<DurableLog<StreamEvent>> {
        DurableLog::open("stream/t", 2, Arc::new(RealFs), ms.clone(), opts).unwrap()
    }

    #[test]
    fn stream_event_codec_roundtrips() {
        let e = ev(42, "cust\u{1f}7", -5, 1.25);
        let mut buf = Vec::new();
        e.encode(&mut buf);
        assert_eq!(StreamEvent::decode(&buf).unwrap(), e);
        // Truncations and trailing junk are typed corruption.
        for cut in 0..buf.len() {
            assert!(StreamEvent::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
        let mut long = buf.clone();
        long.push(0);
        assert!(StreamEvent::decode(&long).is_err());
    }

    #[test]
    fn repl_batch_codec_roundtrips() {
        let b = ReplBatch {
            table: "txn:agg".into(),
            records: vec![
                FeatureRecord::new(7, 100, 200, vec![1.0, 2.0]),
                FeatureRecord::new(9, -3, 0, Vec::<f32>::new()),
            ]
            .into(),
            appended_at: 1_234,
        };
        let mut buf = Vec::new();
        b.encode(&mut buf);
        let d = ReplBatch::decode(&buf).unwrap();
        assert_eq!(d.table, b.table);
        assert_eq!(d.appended_at, b.appended_at);
        assert_eq!(d.records.len(), 2);
        assert_eq!(d.records[0].entity, 7);
        assert_eq!(&d.records[0].values[..], &[1.0, 2.0]);
        assert_eq!(d.records[1].version(), (-3, 0));
        for cut in 0..buf.len() {
            assert!(ReplBatch::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn append_recover_roundtrip() {
        let dir = TempDir::new("wal");
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, DurableLogOptions::default());
            for i in 0..10u64 {
                let off = log.append((i % 2) as usize, ev(i, "k", i as i64, i as f32)).unwrap();
                assert_eq!(off, i / 2);
            }
        }
        // Reopen from disk only: everything acked comes back, in order.
        let ms = open_store(dir.path());
        let log = open_log(&ms, DurableLogOptions::default());
        for p in 0..2 {
            let got = log.mem().read_from(p, 0, usize::MAX);
            assert_eq!(got.len(), 5, "partition {p}");
            for (i, (off, e)) in got.iter().enumerate() {
                assert_eq!(*off, i as u64);
                assert_eq!(e.seq % 2, p as u64);
            }
        }
        // And the log accepts appends at the recovered high water.
        assert_eq!(log.append(0, ev(100, "k", 0, 0.0)).unwrap(), 5);
    }

    #[test]
    fn size_bounded_rolls_seal_fragments() {
        let dir = TempDir::new("wal-roll");
        let opts = DurableLogOptions { fragment_max_bytes: 64, ..Default::default() };
        let ms = open_store(dir.path());
        let log = open_log(&ms, opts.clone());
        for i in 0..20u64 {
            log.append(0, ev(i, "key", 0, 0.0)).unwrap();
        }
        let m = ms.current();
        let lm = &m.logs["stream/t"];
        let sealed = lm.fragments.iter().filter(|f| f.sealed).count();
        assert!(sealed >= 2, "small cap must have rolled, got {:?}", lm.fragments);
        assert_eq!(
            lm.fragments.iter().filter(|f| !f.sealed && f.partition == 0).count(),
            1,
            "exactly one active fragment per appending partition"
        );
        // Sealed counts tile the offset space contiguously.
        let mut frags: Vec<_> =
            lm.fragments.iter().filter(|f| f.partition == 0).collect();
        frags.sort_by_key(|f| f.base);
        let mut expect = 0u64;
        for f in frags.iter().filter(|f| f.sealed) {
            assert_eq!(f.base, expect);
            expect += f.count;
        }
        // Recovery across many fragments reproduces the full history.
        drop(log);
        let ms2 = open_store(dir.path());
        let log2 = open_log(&ms2, opts);
        let seqs: Vec<u64> =
            log2.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn torn_active_tail_recovers_prefix_and_seals() {
        let dir = TempDir::new("wal-torn");
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, DurableLogOptions::default());
            for i in 0..4u64 {
                log.append(0, ev(i, "k", 0, 0.0)).unwrap();
            }
        }
        // Tear the active fragment's last frame (crash mid-append).
        let frag = dir.file("stream_t-p0-000000000000.frag");
        let bytes = std::fs::read(&frag).unwrap();
        std::fs::write(&frag, &bytes[..bytes.len() - 3]).unwrap();
        let ms = open_store(dir.path());
        let log = open_log(&ms, DurableLogOptions::default());
        let seqs: Vec<u64> =
            log.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "valid prefix only, never a torn record");
        // Recovery sealed the torn fragment at the recovered count…
        let lm = &ms.current().logs["stream/t"];
        let f = lm.fragments.iter().find(|f| f.file.ends_with("p0-000000000000.frag")).unwrap();
        assert!(f.sealed && f.count == 3, "{f:?}");
        // …so appends land in a new fragment and a second recovery
        // still sees a consistent log.
        log.append(0, ev(9, "k", 0, 0.0)).unwrap();
        drop(log);
        let ms2 = open_store(dir.path());
        let log2 = open_log(&ms2, DurableLogOptions::default());
        let seqs: Vec<u64> =
            log2.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 9]);
    }

    #[test]
    fn truncation_floor_survives_restart_lazily() {
        let dir = TempDir::new("wal-trunc");
        let opts = DurableLogOptions { fragment_max_bytes: 64, ..Default::default() };
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, opts.clone());
            for i in 0..12u64 {
                log.append(0, ev(i, "key", 0, 0.0)).unwrap();
            }
            assert!(log.truncate_below(0, 9) > 0);
            // Force a manifest commit carrying the new base (what a
            // checkpoint or the next roll does).
            ms.update(|m| LogSection::refresh(log.as_ref(), m)).unwrap();
            let lm = &ms.current().logs["stream/t"];
            assert_eq!(lm.bases[0], 9);
            assert!(
                lm.fragments.iter().all(|f| !f.sealed || f.base + f.count > 9),
                "fully-reclaimed sealed fragments leave the manifest: {:?}",
                lm.fragments
            );
        }
        let ms = open_store(dir.path());
        let log = open_log(&ms, opts);
        assert_eq!(log.mem().base_offset(0), 9);
        let seqs: Vec<u64> =
            log.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![9, 10, 11], "offsets below the floor stay truncated");
        assert_eq!(log.mem().high_water(0), 12);
    }
}
