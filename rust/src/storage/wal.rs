//! The durable log: a [`PartitionedLog`] memory mirror backed by
//! manifest-addressed fragment files.
//!
//! [`DurableLog`] keeps the crate's existing in-memory log as the read
//! path (reads, tails, truncation all hit RAM exactly as before) and
//! adds a write-ahead file path in front of it. The ack contract is the
//! same under every [`SyncPolicy`]: **ack = your frame is covered by a
//! completed sync**. How a frame gets covered is the policy:
//!
//! * [`SyncPolicy::PerAppend`] (default) — each append writes its frame
//!   and fsyncs it before returning, all under the per-partition writer
//!   lock. One sync per record: the original, byte-identical protocol.
//! * [`SyncPolicy::GroupCommit`] — appenders encode and checksum their
//!   frame off the write path, stage it into a per-partition commit
//!   queue and park on a wake channel. The first staged appender
//!   becomes the **leader** (leader/follower — no dedicated committer
//!   thread): it optionally waits `max_delay_us` for the batch to fill,
//!   drains the queue in ticket order, writes every staged frame in one
//!   buffered [`Vfs`] write, issues **one** fsync, mirrors the batch
//!   into RAM, and wakes exactly the waiters that sync covered. N
//!   concurrent appenders cost ~1 sync, not N. A failed sync seals the
//!   fragment at the last *covered* count, so a staged-but-unacked
//!   frame can never be recovered as acked.
//! * [`SyncPolicy::OsManaged`] — never fsync on the append path; `Ok`
//!   only means the OS has the bytes. Trades the guarantee for
//!   throughput (E-DUR measures both sides).
//!
//! File order and memory order are identical by construction: the
//! direct path holds the writer lock across write + mirror, and under
//! group commit only the leader (which holds the same lock) mirrors,
//! in ticket order. [`DurableLog::append_many`] batches one caller's
//! records under a single sync regardless of policy — one streaming
//! poll round's dual-write pays one sync, not one per record.
//!
//! **Crash-safe fragment lifecycle.** A fragment file is created and
//! fsynced, then a manifest generation referencing it is committed,
//! and only then does the first record land in it — so every acked
//! record lives in a manifest-referenced file, and a crash between
//! create and commit strands only an empty, unreferenced file for GC.
//! Rolls (size-bounded) seal the old fragment and open the next one in
//! a single manifest commit; the sealed frame `count` is derived from
//! the memory mirror's high-water mark, i.e. exactly the acked
//! appends. Under group commit the roll happens *after* the batch's
//! waiters are woken — fragment rolls live outside the ack critical
//! path. A failed roll is not fatal: the log keeps appending to the
//! oversized active fragment and retries the roll on a later append.
//!
//! **Recovery.** `open` replays the manifest's fragment list per
//! partition in base order: sealed fragments must decode exactly
//! `count` frames (anything less fails closed, [`FsError::Corrupt`]);
//! the final, unsealed fragment tolerates a torn tail — its valid
//! prefix is the recovered state, and recovery seals it at that count
//! so the torn bytes can never be mistaken for records later. Offsets
//! below the manifest's per-partition `bases` were truncated before
//! the crash and are skipped on replay. Partitions never share a
//! fragment file, so with [`DurableLogOptions::recovery_pool`] attached
//! the per-partition replay fans out across the shared worker pool.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::fragment::{encode_frame, read_fragment, FragmentMeta, FragmentWriter};
use super::manifest::{Manifest, ManifestStore};
use super::vfs::{corrupt, Vfs};
use crate::exec::ThreadPool;
use crate::geo::replication::ReplBatch;
use crate::monitor::metrics::{Counter, LatencyHandle, MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::stream::log::{PartitionedLog, StreamEvent};
use crate::types::{FeatureRecord, FsError, Result};
use crate::util::backoff::{retry, Backoff};
use crate::util::wake::Wake;

/// A record type the durable log can persist. Encoding is the storage
/// layer's own little-endian framing — checksums and lengths live in
/// the fragment frame, not here.
pub trait LogRecord: Clone + Send + Sync + 'static {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(bytes: &[u8]) -> Result<Self>
    where
        Self: Sized;
}

// ---- byte cursor ----------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            return Err(corrupt("log record truncated"));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(corrupt("log record has trailing bytes"));
        }
        Ok(())
    }
}

/// Sanity bound for decoded counts (a torn length field must not
/// trigger a giant allocation).
const MAX_DECODE_ITEMS: u32 = 16 << 20;

impl LogRecord for StreamEvent {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.ts.to_le_bytes());
        out.extend_from_slice(&self.value.to_le_bytes());
        out.extend_from_slice(&(self.key.len() as u32).to_le_bytes());
        out.extend_from_slice(self.key.as_bytes());
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(bytes);
        let seq = c.u64()?;
        let ts = c.i64()?;
        let value = c.f32()?;
        let klen = c.u32()? as usize;
        let key = std::str::from_utf8(c.take(klen)?)
            .map_err(|_| corrupt("stream event key is not utf-8"))?
            .to_string();
        c.done()?;
        Ok(StreamEvent { seq, key, ts, value })
    }
}

impl LogRecord for ReplBatch {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.appended_at.to_le_bytes());
        out.extend_from_slice(&(self.table.len() as u32).to_le_bytes());
        out.extend_from_slice(self.table.as_bytes());
        out.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for r in self.records.iter() {
            out.extend_from_slice(&r.entity.to_le_bytes());
            out.extend_from_slice(&r.event_ts.to_le_bytes());
            out.extend_from_slice(&r.creation_ts.to_le_bytes());
            out.extend_from_slice(&(r.values.len() as u32).to_le_bytes());
            for v in r.values.iter() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(bytes);
        let appended_at = c.i64()?;
        let tlen = c.u32()? as usize;
        let table = std::str::from_utf8(c.take(tlen)?)
            .map_err(|_| corrupt("repl batch table is not utf-8"))?
            .to_string();
        let n = c.u32()?;
        if n > MAX_DECODE_ITEMS {
            return Err(corrupt("repl batch record count implausible"));
        }
        let mut records = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let entity = c.u64()?;
            let event_ts = c.i64()?;
            let creation_ts = c.i64()?;
            let nv = c.u32()?;
            if nv > MAX_DECODE_ITEMS {
                return Err(corrupt("repl batch value count implausible"));
            }
            let mut values = Vec::with_capacity(nv as usize);
            for _ in 0..nv {
                values.push(c.f32()?);
            }
            records.push(FeatureRecord::new(entity, event_ts, creation_ts, values));
        }
        c.done()?;
        Ok(ReplBatch { table, records: records.into(), appended_at })
    }
}

// ---- sync policy -----------------------------------------------------

/// How (and when) appended frames reach stable storage — i.e. what an
/// `Ok` from [`DurableLog::append`] means. Under every policy the
/// invariant recovery relies on is the same: a record is **acked** iff
/// a completed sync covers its frame (for [`SyncPolicy::OsManaged`],
/// iff the write was handed to the OS — the documented weaker trade).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// One fsync per append call (the default): the appender's own
    /// frame is synced before `append` returns. Byte-identical to the
    /// original per-frame ack path.
    PerAppend,
    /// Amortized ack: appenders stage frames into a per-partition
    /// commit queue; a leader drains the queue and issues one fsync
    /// covering the whole staged batch (see module docs). The ack
    /// guarantee is unchanged — only the sync *rate* drops.
    GroupCommit {
        /// How long a leader lingers for the batch to fill before
        /// syncing (0 = sync whatever is staged immediately).
        max_delay_us: u64,
        /// Most frames one sync may cover (0 = unbounded).
        max_batch: usize,
    },
    /// Never fsync from the append path; the OS flushes when it likes.
    /// Keeps the format, drops the guarantee.
    OsManaged,
}

/// Tuning knobs for one durable log.
#[derive(Clone)]
pub struct DurableLogOptions {
    /// Roll the active fragment once it exceeds this size.
    pub fragment_max_bytes: u64,
    /// The ack protocol: per-frame fsync, group commit, or OS-managed.
    pub sync: SyncPolicy,
    /// Retry policy for roll-time manifest commits (transient I/O).
    pub roll_retry: Backoff,
    /// Registry for the `wal_sync_total` / `wal_group_size` /
    /// `wal_ack_wait_us` series; `None` publishes nothing.
    pub metrics: Option<Arc<MetricsRegistry>>,
    /// Fan recovery's per-partition fragment replay across this pool
    /// (`None` replays sequentially, the pre-pool behavior).
    pub recovery_pool: Option<Arc<ThreadPool>>,
}

impl Default for DurableLogOptions {
    fn default() -> Self {
        DurableLogOptions {
            fragment_max_bytes: 1 << 20,
            sync: SyncPolicy::PerAppend,
            roll_retry: Backoff::default(),
            metrics: None,
            recovery_pool: None,
        }
    }
}

impl std::fmt::Debug for DurableLogOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DurableLogOptions")
            .field("fragment_max_bytes", &self.fragment_max_bytes)
            .field("sync", &self.sync)
            .field("metrics", &self.metrics.is_some())
            .field("recovery_pool", &self.recovery_pool.is_some())
            .finish_non_exhaustive()
    }
}

// ---- wal metrics -----------------------------------------------------

/// Pre-registered handles for the WAL series. Registering at open (not
/// first touch) means `export()` lists the names even before the first
/// sync, so dashboards and the completeness test see them immediately.
struct WalMetrics {
    /// Completed fsyncs issued by the append path.
    sync_total: Counter,
    /// Frames covered per completed sync — the amortization factor.
    group_size: LatencyHandle,
    /// Appender-observed wait from staging to a covering sync, µs
    /// (group commit only; the direct path's wait *is* the append).
    ack_wait_us: LatencyHandle,
}

impl WalMetrics {
    fn new(reg: &MetricsRegistry) -> WalMetrics {
        WalMetrics {
            sync_total: reg.counter_handle(MetricKind::System, names::WAL_SYNC_TOTAL),
            group_size: reg.latency_handle(MetricKind::System, names::WAL_GROUP_SIZE),
            ack_wait_us: reg.latency_handle(MetricKind::System, names::WAL_ACK_WAIT_US),
        }
    }
}

// ---- group-commit state ----------------------------------------------

/// One staged frame: encoded and checksummed by its appender (off the
/// write path — the leader only concatenates), waiting for a covering
/// sync.
struct Staged<T> {
    ticket: u64,
    frame: Vec<u8>,
    item: T,
}

/// Per-partition commit queue. Tickets are dense and resolve in order:
/// only a leader moves frames out of `staged`, and it publishes exactly
/// one result per drained ticket, so "my ticket is unresolved and no
/// leader is active" always means "my frame is still staged and it is
/// my turn to lead".
struct CommitQueue<T> {
    staged: VecDeque<Staged<T>>,
    next_ticket: u64,
    /// A leader is currently delaying/draining/syncing a batch.
    leader: bool,
    /// ticket → acked offset, or the batch's shared failure. Entries
    /// are removed by the waiter that owns the ticket.
    results: HashMap<u64, std::result::Result<u64, Arc<FsError>>>,
}

struct GroupState<T> {
    q: Mutex<CommitQueue<T>>,
    /// Parks followers awaiting their ack and a delaying leader
    /// awaiting a fuller batch — the same lossless counter channel the
    /// background drivers use (`util::wake`).
    wake: Wake,
}

impl<T> GroupState<T> {
    fn new() -> GroupState<T> {
        GroupState {
            q: Mutex::new(CommitQueue {
                staged: VecDeque::new(),
                next_ticket: 0,
                leader: false,
                results: HashMap::new(),
            }),
            wake: Wake::default(),
        }
    }
}

/// Re-materialize a shared batch failure for one waiter. [`FsError`]
/// holds `std::io::Error` and cannot be `Clone`; the variants whose
/// identity matters downstream (`is_transient` classification, typed
/// corruption) are preserved, the rest degrade to `Other`.
fn fan_out_err(e: &FsError) -> FsError {
    match e {
        FsError::Io(io) => FsError::Io(std::io::Error::new(io.kind(), io.to_string())),
        FsError::InjectedFault(s) => FsError::InjectedFault(s.clone()),
        FsError::RegionDown(s) => FsError::RegionDown(s.clone()),
        FsError::Corrupt(s) => FsError::Corrupt(s.clone()),
        other => FsError::Other(other.to_string()),
    }
}

// ---- the durable log -------------------------------------------------

struct PartWriter {
    /// The active fragment's writer + file name. `None` until the first
    /// append (or after a failed append retires the fragment).
    active: Option<(FragmentWriter, String)>,
    /// Frames of the active fragment covered by a completed sync — the
    /// count a failed write/sync seals the fragment at, so nothing past
    /// the ack point is ever recovered as data. Under `PerAppend` (and
    /// `OsManaged`, whose documented ack point is the write itself)
    /// this tracks the writer's frame count; under group commit it
    /// advances only when a batch's single sync completes.
    covered: u64,
}

/// Write-ahead, manifest-addressed log over a [`PartitionedLog`] memory
/// mirror. See module docs for the protocol.
pub struct DurableLog<T: LogRecord> {
    name: String,
    prefix: String,
    fs: Arc<dyn Vfs>,
    dir: PathBuf,
    manifests: Arc<ManifestStore>,
    opts: DurableLogOptions,
    mem: PartitionedLog<T>,
    writers: Vec<Mutex<PartWriter>>,
    groups: Vec<GroupState<T>>,
    metrics: Option<WalMetrics>,
}

/// Registry hook: a checkpoint commit pulls every open log's fresh
/// truncation floors into the manifest (and drops fully-reclaimed
/// sealed fragments from the reference set).
pub trait LogSection: Send + Sync {
    fn refresh(&self, m: &mut Manifest);
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// One partition's recovered state.
struct PartReplay<T> {
    items_base: u64,
    items: Vec<T>,
    /// (file name, recovered frame count) of the partition's
    /// formerly-active fragment, to be sealed in one commit by `open`.
    seal: Vec<(String, u64)>,
}

/// Replay one partition's fragment chain (base order, continuity
/// checked) into memory. Pure read path — safe to run for different
/// partitions concurrently, since partitions never share a fragment.
fn replay_partition<T: LogRecord>(
    fs: &dyn Vfs,
    dir: &Path,
    name: &str,
    p: usize,
    frags: &[FragmentMeta],
    floor: u64,
) -> Result<PartReplay<T>> {
    let mut items: Vec<T> = Vec::new();
    let mut items_base = floor;
    let mut seal = Vec::new();
    let mut expected: Option<u64> = None;
    for f in frags {
        if let Some(exp) = expected {
            if f.base != exp {
                return Err(corrupt(format!(
                    "log '{name}' p{p}: fragment {} base {} breaks continuity \
                     (expected {exp})",
                    f.file, f.base
                )));
            }
        }
        let data = read_fragment(fs, &dir.join(&f.file), f.sealed.then_some(f.count))?;
        if data.partition != p || data.base != f.base {
            return Err(corrupt(format!(
                "log '{name}' p{p}: fragment {} header disagrees with manifest",
                f.file
            )));
        }
        let count = data.payloads.len() as u64;
        for (i, payload) in data.payloads.iter().enumerate() {
            let off = f.base + i as u64;
            if off < floor {
                continue; // truncated before the crash
            }
            if items.is_empty() {
                items_base = off;
            }
            items.push(T::decode(payload)?);
        }
        if !f.sealed {
            seal.push((f.file.clone(), count));
        }
        expected = Some(f.base + count);
    }
    let high = expected.unwrap_or(floor).max(floor);
    if items.is_empty() {
        items_base = high;
    }
    Ok(PartReplay { items_base, items, seal })
}

impl<T: LogRecord> DurableLog<T> {
    /// Open (or create) the named log inside `manifests`' store
    /// directory, replaying its fragments into the memory mirror. For a
    /// log already present in the manifest, the manifest's partition
    /// count is authoritative; `partitions` sizes a brand-new log.
    pub fn open(
        name: &str,
        partitions: usize,
        fs: Arc<dyn Vfs>,
        manifests: Arc<ManifestStore>,
        opts: DurableLogOptions,
    ) -> Result<Arc<DurableLog<T>>> {
        let m = manifests.current();
        let existing = m.logs.get(name);
        let partitions = existing.map(|lm| lm.partitions).unwrap_or(partitions.max(1));
        let mem = PartitionedLog::new(partitions);
        let dir = manifests.dir().to_path_buf();
        // (file name, recovered frame count) of each partition's
        // formerly-active fragment — sealed below in one commit.
        let mut seal: Vec<(String, u64)> = Vec::new();
        if let Some(lm) = existing {
            let work: Vec<(Vec<FragmentMeta>, u64)> = (0..partitions)
                .map(|p| {
                    let mut frags: Vec<FragmentMeta> =
                        lm.fragments.iter().filter(|f| f.partition == p).cloned().collect();
                    frags.sort_by_key(|f| f.base);
                    (frags, lm.bases.get(p).copied().unwrap_or(0))
                })
                .collect();
            let replays: Vec<Result<PartReplay<T>>> = match &opts.recovery_pool {
                // The WAL-tail replay fans out per partition; results
                // join in partition order so errors surface exactly as
                // in the sequential path.
                Some(pool) if partitions > 1 => {
                    let handles: Vec<_> = work
                        .into_iter()
                        .enumerate()
                        .map(|(p, (frags, floor))| {
                            let fs = fs.clone();
                            let dir = dir.clone();
                            let name = name.to_string();
                            pool.submit(move || {
                                replay_partition::<T>(fs.as_ref(), &dir, &name, p, &frags, floor)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join()).collect()
                }
                _ => work
                    .into_iter()
                    .enumerate()
                    .map(|(p, (frags, floor))| {
                        replay_partition::<T>(fs.as_ref(), &dir, name, p, &frags, floor)
                    })
                    .collect(),
            };
            for (p, r) in replays.into_iter().enumerate() {
                let r = r?;
                seal.extend(r.seal);
                mem.restore_partition(p, r.items_base, r.items);
            }
        }
        let register = existing.is_none();
        if register || !seal.is_empty() {
            let name_owned = name.to_string();
            manifests.update(move |m| {
                let lm = m.logs.entry(name_owned).or_insert_with(|| {
                    super::manifest::LogManifest {
                        partitions,
                        bases: vec![0; partitions],
                        fragments: Vec::new(),
                    }
                });
                for (file, count) in &seal {
                    if let Some(f) = lm.fragments.iter_mut().find(|f| &f.file == file) {
                        f.sealed = true;
                        f.count = *count;
                    }
                }
            })?;
        }
        let metrics = opts.metrics.as_ref().map(|m| WalMetrics::new(m));
        Ok(Arc::new(DurableLog {
            name: name.to_string(),
            prefix: sanitize(name),
            fs,
            dir,
            manifests,
            opts,
            mem,
            writers: (0..partitions)
                .map(|_| Mutex::new(PartWriter { active: None, covered: 0 }))
                .collect(),
            groups: (0..partitions).map(|_| GroupState::new()).collect(),
            metrics,
        }))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn partitions(&self) -> usize {
        self.mem.partitions()
    }

    /// The memory mirror — the read path (tails, backlog, staleness)
    /// is identical to the RAM-only log.
    pub fn mem(&self) -> &PartitionedLog<T> {
        &self.mem
    }

    /// Durably append one record to `partition`; `Ok(offset)` means a
    /// completed sync covers the record's frame (see [`SyncPolicy`] for
    /// the per-policy fine print).
    pub fn append(&self, partition: usize, item: T) -> Result<u64> {
        match self.opts.sync {
            SyncPolicy::GroupCommit { max_delay_us, max_batch } => {
                self.group_append(partition, std::slice::from_ref(&item), max_delay_us, max_batch)
            }
            _ => self.direct_append(partition, std::slice::from_ref(&item)),
        }
    }

    /// Durably append a batch to `partition` under a **single sync**:
    /// the frames share one buffered write, and one fsync covers them
    /// all (under group commit the batch stages as one unit and may
    /// additionally share its sync with other appenders' frames).
    /// Returns the first record's offset; on `Err` none of the batch is
    /// acked.
    pub fn append_many(&self, partition: usize, items: &[T]) -> Result<u64> {
        if items.is_empty() {
            return Ok(self.mem.high_water(partition));
        }
        match self.opts.sync {
            SyncPolicy::GroupCommit { max_delay_us, max_batch } => {
                self.group_append(partition, items, max_delay_us, max_batch)
            }
            _ => self.direct_append(partition, items),
        }
    }

    /// `PerAppend` / `OsManaged` write path — the original protocol:
    /// frame(s) → (optional) fsync → memory mirror, all under the
    /// partition writer lock. A multi-item batch shares one buffered
    /// write and one sync.
    fn direct_append(&self, partition: usize, items: &[T]) -> Result<u64> {
        let fsync = matches!(self.opts.sync, SyncPolicy::PerAppend);
        let mut buf = Vec::new();
        let mut payload = Vec::new();
        for item in items {
            payload.clear();
            item.encode(&mut payload);
            encode_frame(&mut buf, &payload);
        }
        let mut w = self.writers[partition].lock().unwrap();
        if w.active.is_none() {
            self.start_fragment(&mut w, partition)?;
        }
        let res = {
            let (writer, _) = w.active.as_mut().unwrap();
            writer.append_framed(&buf, items.len() as u64, fsync)
        };
        if let Err(e) = res {
            self.retire_active(w);
            return Err(e);
        }
        let count = w.active.as_ref().map(|(fw, _)| fw.count).unwrap_or(0);
        w.covered = count;
        if fsync {
            if let Some(m) = &self.metrics {
                m.sync_total.inc(1);
                m.group_size.observe(items.len() as u64);
            }
        }
        let mut first = 0u64;
        for (i, item) in items.iter().enumerate() {
            let off = self.mem.append(partition, item.clone());
            if i == 0 {
                first = off;
            }
        }
        if w.active.as_ref().map(|(fw, _)| fw.bytes).unwrap_or(0) >= self.opts.fragment_max_bytes {
            self.roll(&mut w, partition);
        }
        Ok(first)
    }

    /// Group-commit write path: stage pre-framed records into the
    /// partition's commit queue, then wait for every ticket to resolve
    /// — leading batches ourselves whenever no leader is active.
    fn group_append(
        &self,
        partition: usize,
        items: &[T],
        max_delay_us: u64,
        max_batch: usize,
    ) -> Result<u64> {
        let gs = &self.groups[partition];
        let staged_at = Instant::now();
        let mut payload = Vec::new();
        let (first_ticket, n) = {
            let mut q = gs.q.lock().unwrap();
            let first = q.next_ticket;
            for item in items {
                payload.clear();
                item.encode(&mut payload);
                let mut frame = Vec::with_capacity(payload.len() + 12);
                encode_frame(&mut frame, &payload);
                let ticket = q.next_ticket;
                q.next_ticket += 1;
                q.staged.push_back(Staged { ticket, frame, item: item.clone() });
            }
            (first, items.len() as u64)
        };
        // A delaying leader may be parked waiting for the batch to fill.
        gs.wake.ping();
        let mut first_off: Option<u64> = None;
        let mut failure: Option<FsError> = None;
        for ticket in first_ticket..first_ticket + n {
            // Drain every ticket's result even after a failure — a
            // later ticket may belong to a batch that succeeded, and
            // its entry must leave the results map either way.
            match self.group_wait(partition, ticket, max_delay_us, max_batch) {
                Ok(off) => {
                    if first_off.is_none() {
                        first_off = Some(off);
                    }
                }
                Err(e) => {
                    if failure.is_none() {
                        failure = Some(e);
                    }
                }
            }
        }
        if let Some(m) = &self.metrics {
            m.ack_wait_us.observe(staged_at.elapsed().as_micros() as u64);
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(first_off.expect("non-empty batch resolves to an offset")),
        }
    }

    /// Block until `ticket` resolves. Leader/follower: whenever the
    /// ticket is unresolved and no leader is active, this waiter *is*
    /// the leader — it drives the next batch itself instead of parking.
    fn group_wait(
        &self,
        partition: usize,
        ticket: u64,
        max_delay_us: u64,
        max_batch: usize,
    ) -> Result<u64> {
        let gs = &self.groups[partition];
        let mut seen = 0u64;
        loop {
            let lead = {
                let mut q = gs.q.lock().unwrap();
                if let Some(res) = q.results.remove(&ticket) {
                    return res.map_err(|e| fan_out_err(&e));
                }
                if q.leader {
                    false
                } else {
                    q.leader = true;
                    true
                }
            };
            if lead {
                self.lead_commit(partition, max_delay_us, max_batch);
                // Our ticket may have been in the batch just led — or
                // still be staged behind `max_batch`; loop either way.
            } else {
                seen = gs.wake.wait(seen, Duration::from_millis(50));
            }
        }
    }

    /// Drive one commit batch as the leader: optionally linger for the
    /// batch to fill, drain a ticket-ordered prefix of the queue, write
    /// all frames in one buffered write, issue ONE fsync, mirror into
    /// RAM, publish results and wake the covered waiters. The fragment
    /// roll runs *after* the wake — outside the ack critical path.
    fn lead_commit(&self, partition: usize, max_delay_us: u64, max_batch: usize) {
        let gs = &self.groups[partition];
        let max_batch = if max_batch == 0 { usize::MAX } else { max_batch };
        if max_delay_us > 0 {
            let deadline = Instant::now() + Duration::from_micros(max_delay_us);
            let mut seen = 0u64;
            loop {
                if gs.q.lock().unwrap().staged.len() >= max_batch {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                seen = gs.wake.wait(seen, deadline - now);
            }
        }
        let batch: Vec<Staged<T>> = {
            let mut q = gs.q.lock().unwrap();
            let take = q.staged.len().min(max_batch);
            q.staged.drain(..take).collect()
        };
        if batch.is_empty() {
            // Raced with a concurrent drain of our frames: hand
            // leadership back and let the waiters re-check results.
            gs.q.lock().unwrap().leader = false;
            gs.wake.ping();
            return;
        }
        let mut w = self.writers[partition].lock().unwrap();
        let res = (|| -> Result<()> {
            if w.active.is_none() {
                self.start_fragment(&mut w, partition)?;
            }
            let mut buf = Vec::with_capacity(batch.iter().map(|s| s.frame.len()).sum());
            for s in &batch {
                buf.extend_from_slice(&s.frame);
            }
            let (writer, _) = w.active.as_mut().unwrap();
            writer.append_framed(&buf, batch.len() as u64, true)
        })();
        match res {
            Ok(()) => {
                let count = w.active.as_ref().map(|(fw, _)| fw.count).unwrap_or(0);
                w.covered = count;
                if let Some(m) = &self.metrics {
                    m.sync_total.inc(1);
                    m.group_size.observe(batch.len() as u64);
                }
                // Mirror in ticket order (== file order), then publish
                // and wake exactly the waiters this sync covered.
                let published: Vec<(u64, u64)> = batch
                    .into_iter()
                    .map(|s| (s.ticket, self.mem.append(partition, s.item)))
                    .collect();
                {
                    let mut q = gs.q.lock().unwrap();
                    for (ticket, off) in published {
                        q.results.insert(ticket, Ok(off));
                    }
                    q.leader = false;
                }
                gs.wake.ping();
                // Size-bounded roll after the ack: a slow manifest
                // commit here delays the *next* batch's leader, never
                // the waiters already covered.
                if w.active.as_ref().map(|(fw, _)| fw.bytes).unwrap_or(0)
                    >= self.opts.fragment_max_bytes
                {
                    self.roll(&mut w, partition);
                }
            }
            Err(e) => {
                // The write or the sync failed: none of the batch is
                // acked. Retire the fragment, sealing it at the covered
                // count, so no staged frame is ever recovered as acked.
                self.retire_active(w);
                let shared = Arc::new(e);
                {
                    let mut q = gs.q.lock().unwrap();
                    for s in &batch {
                        q.results.insert(s.ticket, Err(shared.clone()));
                    }
                    q.leader = false;
                }
                gs.wake.ping();
            }
        }
    }

    /// Retire the active fragment after a failed write or sync: the
    /// file may hold torn or staged-but-unsynced bytes, so no later
    /// append may extend it. Seals at the **covered** count — exactly
    /// the frames a completed sync acked — so nothing past the ack
    /// point is ever recovered as data. The manifest commit runs after
    /// the writer lock is dropped: a slow manifest write must not block
    /// appenders staging into the commit queue or a new leader's
    /// election. A racing `start_fragment` seals the same fragment at
    /// the same count (derived from the memory mirror's high-water
    /// mark), so the two commits are idempotent; if even this commit
    /// fails, recovery's valid-prefix read of the still-unsealed
    /// fragment reaches at least the covered frames and re-seals then.
    fn retire_active(&self, mut w: MutexGuard<'_, PartWriter>) {
        let Some((_, file)) = w.active.take() else {
            return;
        };
        let count = w.covered;
        w.covered = 0;
        drop(w);
        let name = self.name.clone();
        let _ = self.manifests.update(move |m| {
            if let Some(lm) = m.logs.get_mut(&name) {
                if let Some(f) = lm.fragments.iter_mut().find(|f| f.file == file && !f.sealed) {
                    f.sealed = true;
                    f.count = count;
                }
            }
        });
    }

    /// Truncate the memory mirror below `offset`. The manifest's
    /// `bases` catch up lazily at the next commit (roll or checkpoint):
    /// replaying a few already-truncated records after a crash is
    /// harmless — sinks are idempotent and cursors are restored — while
    /// an eagerly-advanced base that outran a failed commit would not
    /// be.
    pub fn truncate_below(&self, partition: usize, offset: u64) -> u64 {
        self.mem.truncate_below(partition, offset)
    }

    /// Create the next fragment for `partition` and commit a manifest
    /// generation that (a) seals any previous active fragment at its
    /// acked count and (b) references the new fragment — all before the
    /// first append lands in it.
    fn start_fragment(&self, w: &mut PartWriter, partition: usize) -> Result<()> {
        let base = self.mem.high_water(partition);
        let file = format!("{}-p{partition}-{base:012}.frag", self.prefix);
        let path = self.dir.join(&file);
        let writer = FragmentWriter::create(self.fs.as_ref(), &path, partition, base)?;
        let commit = retry(&self.opts.roll_retry, || {
            self.manifests.update(|m| {
                let lm = m
                    .logs
                    .get_mut(&self.name)
                    .expect("durable log registered in manifest at open");
                for f in lm.fragments.iter_mut() {
                    if f.partition == partition && !f.sealed {
                        f.sealed = true;
                        f.count = base - f.base;
                    }
                }
                lm.fragments.push(FragmentMeta {
                    file: file.clone(),
                    partition,
                    base,
                    sealed: false,
                    count: 0,
                });
                Self::refresh_log(&self.mem, lm);
            })
        });
        match commit {
            Ok(_) => {
                w.active = Some((writer, file));
                w.covered = 0;
                Ok(())
            }
            Err(e) => {
                // Unreferenced and empty: remove eagerly, GC as backstop.
                let _ = self.fs.remove(&path);
                Err(e)
            }
        }
    }

    /// Size-bounded roll. Best-effort: on persistent commit failure the
    /// old (oversized) fragment stays active and the roll is retried by
    /// a later append.
    fn roll(&self, w: &mut PartWriter, partition: usize) {
        let saved = w.active.take();
        let saved_covered = w.covered;
        if let Err(e) = self.start_fragment(w, partition) {
            log::warn!(
                "durable log '{}' p{partition}: fragment roll failed ({e}); \
                 continuing on oversized fragment"
            , self.name);
            w.active = saved;
            w.covered = saved_covered;
        }
    }

    fn refresh_log(mem: &PartitionedLog<T>, lm: &mut super::manifest::LogManifest) {
        for p in 0..lm.partitions.min(lm.bases.len()) {
            let b = mem.base_offset(p);
            if b > lm.bases[p] {
                lm.bases[p] = b;
            }
        }
        let bases = lm.bases.clone();
        lm.fragments.retain(|f| {
            !(f.sealed && f.base + f.count <= bases.get(f.partition).copied().unwrap_or(0))
        });
    }
}

impl<T: LogRecord> LogSection for DurableLog<T> {
    fn refresh(&self, m: &mut Manifest) {
        if let Some(lm) = m.logs.get_mut(&self.name) {
            Self::refresh_log(&self.mem, lm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::{RealFs, VfsFile};
    use crate::testkit::TempDir;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Barrier;

    fn ev(seq: u64, key: &str, ts: i64, v: f32) -> StreamEvent {
        StreamEvent::new(seq, key, ts, v)
    }

    fn open_store(dir: &std::path::Path) -> Arc<ManifestStore> {
        Arc::new(ManifestStore::open(Arc::new(RealFs), dir, 0).unwrap())
    }

    fn open_log(
        ms: &Arc<ManifestStore>,
        opts: DurableLogOptions,
    ) -> Arc<DurableLog<StreamEvent>> {
        DurableLog::open("stream/t", 2, Arc::new(RealFs), ms.clone(), opts).unwrap()
    }

    // ---- counting / fault-arming Vfs ---------------------------------

    /// Passthrough [`Vfs`] that counts `sync` calls on `.frag` files
    /// (the WAL ack syncs — header/manifest syncs are excluded so the
    /// count isolates the append path) and can arm a one-shot sync
    /// failure on the next fragment sync.
    struct CountingFs {
        inner: RealFs,
        frag_syncs: Arc<AtomicU64>,
        fail_next_frag_sync: Arc<AtomicBool>,
    }

    impl CountingFs {
        fn new() -> Arc<CountingFs> {
            Arc::new(CountingFs {
                inner: RealFs,
                frag_syncs: Arc::new(AtomicU64::new(0)),
                fail_next_frag_sync: Arc::new(AtomicBool::new(false)),
            })
        }
        fn frag_syncs(&self) -> u64 {
            self.frag_syncs.load(Ordering::SeqCst)
        }
        fn wrap(&self, f: Box<dyn VfsFile>, path: &Path) -> Box<dyn VfsFile> {
            if path.extension().is_some_and(|e| e == "frag") {
                Box::new(CountingFile {
                    inner: f,
                    syncs: self.frag_syncs.clone(),
                    fail_next: self.fail_next_frag_sync.clone(),
                })
            } else {
                f
            }
        }
    }

    struct CountingFile {
        inner: Box<dyn VfsFile>,
        syncs: Arc<AtomicU64>,
        fail_next: Arc<AtomicBool>,
    }

    impl VfsFile for CountingFile {
        fn append(&mut self, buf: &[u8]) -> Result<()> {
            self.inner.append(buf)
        }
        fn sync(&mut self) -> Result<()> {
            if self.fail_next.swap(false, Ordering::SeqCst) {
                return Err(FsError::InjectedFault("armed sync failure".into()));
            }
            self.syncs.fetch_add(1, Ordering::SeqCst);
            self.inner.sync()
        }
    }

    impl Vfs for CountingFs {
        fn create(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
            Ok(self.wrap(self.inner.create(path)?, path))
        }
        fn open_append(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
            Ok(self.wrap(self.inner.open_append(path)?, path))
        }
        fn read(&self, path: &Path) -> Result<Vec<u8>> {
            self.inner.read(path)
        }
        fn rename(&self, from: &Path, to: &Path) -> Result<()> {
            self.inner.rename(from, to)
        }
        fn remove(&self, path: &Path) -> Result<()> {
            self.inner.remove(path)
        }
        fn list(&self, dir: &Path) -> Result<Vec<PathBuf>> {
            self.inner.list(dir)
        }
        fn sync_dir(&self, dir: &Path) -> Result<()> {
            self.inner.sync_dir(dir)
        }
        fn exists(&self, path: &Path) -> bool {
            self.inner.exists(path)
        }
        fn create_dir_all(&self, dir: &Path) -> Result<()> {
            self.inner.create_dir_all(dir)
        }
    }

    #[test]
    fn stream_event_codec_roundtrips() {
        let e = ev(42, "cust\u{1f}7", -5, 1.25);
        let mut buf = Vec::new();
        e.encode(&mut buf);
        assert_eq!(StreamEvent::decode(&buf).unwrap(), e);
        // Truncations and trailing junk are typed corruption.
        for cut in 0..buf.len() {
            assert!(StreamEvent::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
        let mut long = buf.clone();
        long.push(0);
        assert!(StreamEvent::decode(&long).is_err());
    }

    #[test]
    fn repl_batch_codec_roundtrips() {
        let b = ReplBatch {
            table: "txn:agg".into(),
            records: vec![
                FeatureRecord::new(7, 100, 200, vec![1.0, 2.0]),
                FeatureRecord::new(9, -3, 0, Vec::<f32>::new()),
            ]
            .into(),
            appended_at: 1_234,
        };
        let mut buf = Vec::new();
        b.encode(&mut buf);
        let d = ReplBatch::decode(&buf).unwrap();
        assert_eq!(d.table, b.table);
        assert_eq!(d.appended_at, b.appended_at);
        assert_eq!(d.records.len(), 2);
        assert_eq!(d.records[0].entity, 7);
        assert_eq!(&d.records[0].values[..], &[1.0, 2.0]);
        assert_eq!(d.records[1].version(), (-3, 0));
        for cut in 0..buf.len() {
            assert!(ReplBatch::decode(&buf[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn per_append_is_the_default_policy() {
        assert_eq!(DurableLogOptions::default().sync, SyncPolicy::PerAppend);
    }

    #[test]
    fn append_recover_roundtrip() {
        let dir = TempDir::new("wal");
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, DurableLogOptions::default());
            for i in 0..10u64 {
                let off = log.append((i % 2) as usize, ev(i, "k", i as i64, i as f32)).unwrap();
                assert_eq!(off, i / 2);
            }
        }
        // Reopen from disk only: everything acked comes back, in order.
        let ms = open_store(dir.path());
        let log = open_log(&ms, DurableLogOptions::default());
        for p in 0..2 {
            let got = log.mem().read_from(p, 0, usize::MAX);
            assert_eq!(got.len(), 5, "partition {p}");
            for (i, (off, e)) in got.iter().enumerate() {
                assert_eq!(*off, i as u64);
                assert_eq!(e.seq % 2, p as u64);
            }
        }
        // And the log accepts appends at the recovered high water.
        assert_eq!(log.append(0, ev(100, "k", 0, 0.0)).unwrap(), 5);
    }

    #[test]
    fn group_commit_roundtrip_and_cross_policy_recovery() {
        let dir = TempDir::new("wal-gc");
        let gc = DurableLogOptions {
            sync: SyncPolicy::GroupCommit { max_delay_us: 0, max_batch: 4 },
            ..Default::default()
        };
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, gc.clone());
            for i in 0..10u64 {
                let off = log.append((i % 2) as usize, ev(i, "k", i as i64, i as f32)).unwrap();
                assert_eq!(off, i / 2, "group commit must hand back the real offset");
            }
            // append_many stages as one unit and resolves contiguously.
            let batch: Vec<StreamEvent> = (10..16).map(|i| ev(i, "k", 0, 0.0)).collect();
            assert_eq!(log.append_many(0, &batch).unwrap(), 5);
            assert_eq!(log.mem().high_water(0), 11);
        }
        // A log written under GroupCommit recovers under any policy —
        // the policy shapes syncs, never bytes.
        let ms = open_store(dir.path());
        let log = open_log(&ms, DurableLogOptions::default());
        let seqs: Vec<u64> =
            log.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 2, 4, 6, 8, 10, 11, 12, 13, 14, 15]);
    }

    /// ISSUE 10 acceptance: 16 concurrent appenders to one partition
    /// must produce ≪ 16 fsyncs, and every ack must be covered — the
    /// record is really on disk at its returned offset.
    #[test]
    fn group_commit_coalesces_concurrent_appender_syncs() {
        const APPENDERS: u64 = 16;
        let dir = TempDir::new("wal-coalesce");
        let fs = CountingFs::new();
        let ms =
            Arc::new(ManifestStore::open(fs.clone() as Arc<dyn Vfs>, dir.path(), 0).unwrap());
        let log: Arc<DurableLog<StreamEvent>> = DurableLog::open(
            "t",
            1,
            fs.clone(),
            ms,
            DurableLogOptions {
                sync: SyncPolicy::GroupCommit {
                    max_delay_us: 20_000,
                    max_batch: APPENDERS as usize,
                },
                ..Default::default()
            },
        )
        .unwrap();
        // Warmup creates the fragment (header sync excluded by the
        // counter anyway — it counts only post-create data syncs on
        // .frag files via the same handle, so snapshot after it).
        log.append(0, ev(999, "warm", 0, 0.0)).unwrap();
        let before = fs.frag_syncs();
        let barrier = Arc::new(Barrier::new(APPENDERS as usize));
        let handles: Vec<_> = (0..APPENDERS)
            .map(|i| {
                let log = log.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    log.append(0, ev(i, "k", i as i64, i as f32)).unwrap()
                })
            })
            .collect();
        let offs: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let syncs = fs.frag_syncs() - before;
        assert!(
            syncs <= APPENDERS / 2,
            "16 appenders must share syncs: got {syncs} syncs for {APPENDERS} appends"
        );
        assert!(syncs >= 1, "at least one covering sync must have happened");
        // Every ack covered: reopen from disk and find each record at
        // its returned offset.
        drop(log);
        let ms2 = open_store(dir.path());
        let log2: Arc<DurableLog<StreamEvent>> =
            DurableLog::open("t", 1, Arc::new(RealFs), ms2, DurableLogOptions::default())
                .unwrap();
        let by_off: HashMap<u64, StreamEvent> =
            log2.mem().read_from(0, 0, usize::MAX).into_iter().collect();
        for (i, off) in offs.iter().enumerate() {
            let got = by_off.get(off).unwrap_or_else(|| panic!("ack at offset {off} lost"));
            assert_eq!(got.seq, i as u64, "offset {off} holds the wrong record");
        }
    }

    /// A single caller's batched append shares one sync under the
    /// default per-append policy too.
    #[test]
    fn append_many_shares_one_sync() {
        let dir = TempDir::new("wal-many");
        let fs = CountingFs::new();
        let ms =
            Arc::new(ManifestStore::open(fs.clone() as Arc<dyn Vfs>, dir.path(), 0).unwrap());
        let log: Arc<DurableLog<StreamEvent>> =
            DurableLog::open("t", 1, fs.clone(), ms, DurableLogOptions::default()).unwrap();
        log.append(0, ev(0, "warm", 0, 0.0)).unwrap();
        let before = fs.frag_syncs();
        let batch: Vec<StreamEvent> = (1..9).map(|i| ev(i, "k", 0, 0.0)).collect();
        assert_eq!(log.append_many(0, &batch).unwrap(), 1);
        assert_eq!(fs.frag_syncs() - before, 1, "8 records, one covering sync");
        assert_eq!(log.mem().high_water(0), 9);
        // And the batch really is on disk.
        drop(log);
        let ms2 = open_store(dir.path());
        let log2: Arc<DurableLog<StreamEvent>> =
            DurableLog::open("t", 1, Arc::new(RealFs), ms2, DurableLogOptions::default())
                .unwrap();
        assert_eq!(log2.mem().high_water(0), 9);
    }

    /// A failed covering sync seals the fragment at the *covered* count:
    /// the staged-but-unacked frame is on disk but must never be
    /// recovered — not in this process, not after a restart.
    #[test]
    fn failed_sync_seals_at_covered_count() {
        let dir = TempDir::new("wal-failsync");
        let fs = CountingFs::new();
        let ms =
            Arc::new(ManifestStore::open(fs.clone() as Arc<dyn Vfs>, dir.path(), 0).unwrap());
        let log: Arc<DurableLog<StreamEvent>> = DurableLog::open(
            "t",
            1,
            fs.clone(),
            ms.clone(),
            DurableLogOptions {
                sync: SyncPolicy::GroupCommit { max_delay_us: 0, max_batch: 0 },
                ..Default::default()
            },
        )
        .unwrap();
        log.append(0, ev(0, "k", 0, 0.0)).unwrap();
        log.append(0, ev(1, "k", 0, 0.0)).unwrap();
        fs.fail_next_frag_sync.store(true, Ordering::SeqCst);
        let err = log.append(0, ev(2, "k", 0, 0.0)).unwrap_err();
        assert!(err.is_transient(), "injected sync failure keeps its classification: {err}");
        // The unacked frame is not in memory…
        assert_eq!(log.mem().high_water(0), 2);
        // …and the retired fragment is sealed at the covered count.
        let lm = &ms.current().logs["t"];
        let f = lm.fragments.iter().find(|f| f.file.contains("p0-000000000000")).unwrap();
        assert!(f.sealed && f.count == 2, "sealed at covered count: {f:?}");
        // The log keeps working: the next append opens a new fragment
        // at the acked high water.
        assert_eq!(log.append(0, ev(3, "k", 0, 0.0)).unwrap(), 2);
        // Recovery serves the two acked records and the post-failure
        // append — never the staged frame that missed its sync.
        drop(log);
        let ms2 = open_store(dir.path());
        let log2: Arc<DurableLog<StreamEvent>> =
            DurableLog::open("t", 1, Arc::new(RealFs), ms2, DurableLogOptions::default())
                .unwrap();
        let seqs: Vec<u64> =
            log2.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 3], "staged frame served despite failed sync");
    }

    /// Recovery over a shared pool reproduces the sequential replay
    /// exactly (same records, same offsets, same seals).
    #[test]
    fn parallel_recovery_matches_sequential() {
        let dir = TempDir::new("wal-par-rec");
        {
            let ms = open_store(dir.path());
            let log: Arc<DurableLog<StreamEvent>> = DurableLog::open(
                "t",
                4,
                Arc::new(RealFs),
                ms,
                DurableLogOptions { fragment_max_bytes: 128, ..Default::default() },
            )
            .unwrap();
            for i in 0..40u64 {
                log.append((i % 4) as usize, ev(i, "key", i as i64, i as f32)).unwrap();
            }
        }
        let seq_view = {
            let ms = open_store(dir.path());
            let log: Arc<DurableLog<StreamEvent>> =
                DurableLog::open("t", 4, Arc::new(RealFs), ms, DurableLogOptions::default())
                    .unwrap();
            (0..4).map(|p| log.mem().read_from(p, 0, usize::MAX)).collect::<Vec<_>>()
        };
        let pool = Arc::new(ThreadPool::new(3));
        let ms = open_store(dir.path());
        let log: Arc<DurableLog<StreamEvent>> = DurableLog::open(
            "t",
            4,
            Arc::new(RealFs),
            ms,
            DurableLogOptions { recovery_pool: Some(pool), ..Default::default() },
        )
        .unwrap();
        for (p, expect) in seq_view.iter().enumerate() {
            assert_eq!(&log.mem().read_from(p, 0, usize::MAX), expect, "partition {p}");
        }
    }

    #[test]
    fn size_bounded_rolls_seal_fragments() {
        let dir = TempDir::new("wal-roll");
        let opts = DurableLogOptions { fragment_max_bytes: 64, ..Default::default() };
        let ms = open_store(dir.path());
        let log = open_log(&ms, opts.clone());
        for i in 0..20u64 {
            log.append(0, ev(i, "key", 0, 0.0)).unwrap();
        }
        let m = ms.current();
        let lm = &m.logs["stream/t"];
        let sealed = lm.fragments.iter().filter(|f| f.sealed).count();
        assert!(sealed >= 2, "small cap must have rolled, got {:?}", lm.fragments);
        assert_eq!(
            lm.fragments.iter().filter(|f| !f.sealed && f.partition == 0).count(),
            1,
            "exactly one active fragment per appending partition"
        );
        // Sealed counts tile the offset space contiguously.
        let mut frags: Vec<_> =
            lm.fragments.iter().filter(|f| f.partition == 0).collect();
        frags.sort_by_key(|f| f.base);
        let mut expect = 0u64;
        for f in frags.iter().filter(|f| f.sealed) {
            assert_eq!(f.base, expect);
            expect += f.count;
        }
        // Recovery across many fragments reproduces the full history.
        drop(log);
        let ms2 = open_store(dir.path());
        let log2 = open_log(&ms2, opts);
        let seqs: Vec<u64> =
            log2.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn torn_active_tail_recovers_prefix_and_seals() {
        let dir = TempDir::new("wal-torn");
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, DurableLogOptions::default());
            for i in 0..4u64 {
                log.append(0, ev(i, "k", 0, 0.0)).unwrap();
            }
        }
        // Tear the active fragment's last frame (crash mid-append).
        let frag = dir.file("stream_t-p0-000000000000.frag");
        let bytes = std::fs::read(&frag).unwrap();
        std::fs::write(&frag, &bytes[..bytes.len() - 3]).unwrap();
        let ms = open_store(dir.path());
        let log = open_log(&ms, DurableLogOptions::default());
        let seqs: Vec<u64> =
            log.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "valid prefix only, never a torn record");
        // Recovery sealed the torn fragment at the recovered count…
        let lm = &ms.current().logs["stream/t"];
        let f = lm.fragments.iter().find(|f| f.file.ends_with("p0-000000000000.frag")).unwrap();
        assert!(f.sealed && f.count == 3, "{f:?}");
        // …so appends land in a new fragment and a second recovery
        // still sees a consistent log.
        log.append(0, ev(9, "k", 0, 0.0)).unwrap();
        drop(log);
        let ms2 = open_store(dir.path());
        let log2 = open_log(&ms2, DurableLogOptions::default());
        let seqs: Vec<u64> =
            log2.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 9]);
    }

    #[test]
    fn truncation_floor_survives_restart_lazily() {
        let dir = TempDir::new("wal-trunc");
        let opts = DurableLogOptions { fragment_max_bytes: 64, ..Default::default() };
        {
            let ms = open_store(dir.path());
            let log = open_log(&ms, opts.clone());
            for i in 0..12u64 {
                log.append(0, ev(i, "key", 0, 0.0)).unwrap();
            }
            assert!(log.truncate_below(0, 9) > 0);
            // Force a manifest commit carrying the new base (what a
            // checkpoint or the next roll does).
            ms.update(|m| LogSection::refresh(log.as_ref(), m)).unwrap();
            let lm = &ms.current().logs["stream/t"];
            assert_eq!(lm.bases[0], 9);
            assert!(
                lm.fragments.iter().all(|f| !f.sealed || f.base + f.count > 9),
                "fully-reclaimed sealed fragments leave the manifest: {:?}",
                lm.fragments
            );
        }
        let ms = open_store(dir.path());
        let log = open_log(&ms, opts);
        assert_eq!(log.mem().base_offset(0), 9);
        let seqs: Vec<u64> =
            log.mem().read_from(0, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, vec![9, 10, 11], "offsets below the floor stay truncated");
        assert_eq!(log.mem().high_water(0), 12);
    }
}
