//! Size-bounded, checksummed WAL fragment files.
//!
//! A fragment is one append-only run of a log partition:
//!
//! ```text
//! header  : magic "GFRAG1\0\0" (8) | partition u32 | base u64
//! frame*  : len u32 | fnv1a(payload) u64 | payload
//! ```
//!
//! Record offset = `base + frame index`, so fragments compose into the
//! partition's dense offset space without any per-record offset field.
//! Frames are checksummed individually but may land in one buffered
//! write ([`FragmentWriter::append_framed`] — the group-commit path
//! writes a whole staged batch at once); the fsync is the **ack
//! point**: a record is durable iff a completed sync covers its frame.
//! Frames written but not yet covered by a sync are *staged*, not
//! acked — a failed sync seals the fragment at the covered count so a
//! staged-only frame can never be recovered as acked.
//!
//! Reading distinguishes two cases (see `storage` module docs):
//!
//! * **Sealed** fragments carry an authoritative frame `count` in the
//!   manifest. Exactly that many valid frames must decode; anything
//!   less is corruption (fail closed, typed [`FsError::Corrupt`]).
//!   Trailing junk past `count` frames is ignored — it is the torn tail
//!   of the crash that sealed the fragment.
//! * The **active** (unsealed) fragment may legitimately end in a torn
//!   frame (crash mid-append past the last ack). Reading stops at the
//!   first short/invalid frame and returns the valid prefix.

use std::path::Path;

use super::vfs::{corrupt, fnv1a, Vfs, VfsFile};
use crate::types::Result;

pub const FRAG_MAGIC: &[u8; 8] = b"GFRAG1\0\0";
const HEADER_LEN: usize = 8 + 4 + 8;
const FRAME_HEADER_LEN: usize = 4 + 8;
/// Guard against decoding an implausible length from torn bytes.
const MAX_FRAME_LEN: usize = 64 << 20;

/// One fragment's identity as recorded in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FragmentMeta {
    /// File name (relative to the store directory).
    pub file: String,
    pub partition: usize,
    /// Offset of the fragment's first record.
    pub base: u64,
    /// Sealed fragments never receive another append; `count` is then
    /// the authoritative number of frames.
    pub sealed: bool,
    pub count: u64,
}

/// Append-side handle for the active fragment of one partition.
pub struct FragmentWriter {
    file: Box<dyn VfsFile>,
    /// Bytes written so far (header + frames) — drives size-bounded rolls.
    pub bytes: u64,
    /// Frames written so far.
    pub count: u64,
}

impl FragmentWriter {
    /// Create the fragment file: write + fsync the header, then fsync
    /// the parent directory so the file itself survives a crash. The
    /// caller commits a manifest referencing the fragment **before**
    /// appending any record to it — a crash in between leaves only an
    /// unreferenced, record-free file for GC.
    pub fn create(fs: &dyn Vfs, path: &Path, partition: usize, base: u64) -> Result<FragmentWriter> {
        let mut file = fs.create(path)?;
        let mut hdr = Vec::with_capacity(HEADER_LEN);
        hdr.extend_from_slice(FRAG_MAGIC);
        hdr.extend_from_slice(&(partition as u32).to_le_bytes());
        hdr.extend_from_slice(&base.to_le_bytes());
        file.append(&hdr)?;
        file.sync()?;
        if let Some(parent) = path.parent() {
            fs.sync_dir(parent)?;
        }
        Ok(FragmentWriter { file, bytes: HEADER_LEN as u64, count: 0 })
    }

    /// Append one framed payload; with `fsync`, the record is acked
    /// durable on return.
    pub fn append(&mut self, payload: &[u8], fsync: bool) -> Result<()> {
        let mut frame = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
        encode_frame(&mut frame, payload);
        self.append_framed(&frame, 1, fsync)
    }

    /// Append `frames` pre-framed payloads (built with [`encode_frame`])
    /// in **one** buffered write; with `fsync`, one sync then covers the
    /// whole batch — the group-commit amortization in a single call.
    pub fn append_framed(&mut self, buf: &[u8], frames: u64, fsync: bool) -> Result<()> {
        self.file.append(buf)?;
        if fsync {
            self.file.sync()?;
        }
        self.bytes += buf.len() as u64;
        self.count += frames;
        Ok(())
    }

    pub fn sync(&mut self) -> Result<()> {
        self.file.sync()
    }
}

/// Frame one payload (`len u32 | fnv1a u64 | payload`) into `out`.
/// Appenders encode off the write path, so the group-commit leader only
/// concatenates pre-built frames.
pub fn encode_frame(out: &mut Vec<u8>, payload: &[u8]) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// A fragment's decoded contents.
#[derive(Debug)]
pub struct FragmentData {
    pub partition: usize,
    pub base: u64,
    pub payloads: Vec<Vec<u8>>,
}

/// Read a fragment. `sealed_count: Some(n)` enforces exactly `n` valid
/// frames (corruption inside a sealed fragment fails closed);
/// `None` reads the valid prefix of an active fragment, tolerating a
/// torn tail.
pub fn read_fragment(
    fs: &dyn Vfs,
    path: &Path,
    sealed_count: Option<u64>,
) -> Result<FragmentData> {
    let bytes = fs.read(path)?;
    if bytes.len() < HEADER_LEN {
        return Err(corrupt(format!("fragment {path:?}: short header")));
    }
    if &bytes[..8] != FRAG_MAGIC {
        return Err(corrupt(format!("fragment {path:?}: bad magic")));
    }
    let partition = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let base = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let mut payloads = Vec::new();
    let mut pos = HEADER_LEN;
    loop {
        if let Some(n) = sealed_count {
            if payloads.len() as u64 == n {
                break; // trailing junk past the sealed count is ignored
            }
        }
        if pos == bytes.len() {
            break; // clean EOF
        }
        match decode_frame(&bytes[pos..]) {
            Some((payload, consumed)) => {
                payloads.push(payload);
                pos += consumed;
            }
            None => {
                if sealed_count.is_some() {
                    return Err(corrupt(format!(
                        "fragment {path:?}: torn frame {} inside sealed fragment",
                        payloads.len()
                    )));
                }
                break; // active fragment: torn tail, keep the valid prefix
            }
        }
    }
    if let Some(n) = sealed_count {
        if (payloads.len() as u64) < n {
            return Err(corrupt(format!(
                "fragment {path:?}: sealed count {n} but only {} valid frames",
                payloads.len()
            )));
        }
    }
    Ok(FragmentData { partition, base, payloads })
}

/// Decode one frame from `bytes`; `None` if short or checksum-mismatched.
fn decode_frame(bytes: &[u8]) -> Option<(Vec<u8>, usize)> {
    if bytes.len() < FRAME_HEADER_LEN {
        return None;
    }
    let len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN || bytes.len() < FRAME_HEADER_LEN + len {
        return None;
    }
    let sum = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    let payload = &bytes[FRAME_HEADER_LEN..FRAME_HEADER_LEN + len];
    if fnv1a(payload) != sum {
        return None;
    }
    Some((payload.to_vec(), FRAME_HEADER_LEN + len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::RealFs;
    use crate::testkit::TempDir;
    use crate::types::FsError;

    fn write_frames(path: &Path, base: u64, payloads: &[&[u8]]) {
        let mut w = FragmentWriter::create(&RealFs, path, 1, base).unwrap();
        for p in payloads {
            w.append(p, false).unwrap();
        }
        w.sync().unwrap();
    }

    #[test]
    fn roundtrip_and_offsets() {
        let dir = TempDir::new("frag");
        let path = dir.file("a.frag");
        write_frames(&path, 40, &[b"alpha", b"", b"gamma"]);
        let d = read_fragment(&RealFs, &path, Some(3)).unwrap();
        assert_eq!(d.partition, 1);
        assert_eq!(d.base, 40);
        assert_eq!(d.payloads, vec![b"alpha".to_vec(), b"".to_vec(), b"gamma".to_vec()]);
    }

    #[test]
    fn active_fragment_tolerates_torn_tail() {
        let dir = TempDir::new("frag-torn");
        let path = dir.file("a.frag");
        write_frames(&path, 0, &[b"one", b"two"]);
        let full = std::fs::read(&path).unwrap();
        // Truncate at every byte boundary inside the second frame: the
        // valid prefix (one frame) must always be recovered.
        let second_frame_start = HEADER_LEN + FRAME_HEADER_LEN + 3;
        for cut in second_frame_start..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let d = read_fragment(&RealFs, &path, None).unwrap();
            assert_eq!(d.payloads, vec![b"one".to_vec()], "cut at {cut}");
        }
    }

    #[test]
    fn sealed_fragment_fails_closed_on_missing_frames() {
        let dir = TempDir::new("frag-sealed");
        let path = dir.file("a.frag");
        write_frames(&path, 0, &[b"one", b"two"]);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        let err = read_fragment(&RealFs, &path, Some(2)).unwrap_err();
        assert!(matches!(err, FsError::Corrupt(_)), "{err}");
        // But the sealed count also *bounds* the read: junk past the
        // count is the sealing crash's torn tail and is ignored.
        std::fs::write(&path, [&full[..], &b"junkjunkjunk"[..]].concat()).unwrap();
        let d = read_fragment(&RealFs, &path, Some(2)).unwrap();
        assert_eq!(d.payloads.len(), 2);
    }

    #[test]
    fn bit_flips_are_detected() {
        let dir = TempDir::new("frag-flip");
        let path = dir.file("a.frag");
        write_frames(&path, 0, &[b"payload-bytes"]);
        let full = std::fs::read(&path).unwrap();
        // Flip a payload byte: checksum must catch it in sealed mode,
        // and active mode must not serve the torn record.
        let mut bad = full.clone();
        let idx = HEADER_LEN + FRAME_HEADER_LEN + 4;
        bad[idx] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        assert!(read_fragment(&RealFs, &path, Some(1)).is_err());
        let d = read_fragment(&RealFs, &path, None).unwrap();
        assert!(d.payloads.is_empty(), "torn record must never be served");
        // Bad magic fails closed either way.
        let mut bad = full;
        bad[0] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(read_fragment(&RealFs, &path, None), Err(FsError::Corrupt(_))));
    }

    #[test]
    fn implausible_length_is_rejected_not_alloc() {
        let dir = TempDir::new("frag-len");
        let path = dir.file("a.frag");
        write_frames(&path, 0, &[]);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let d = read_fragment(&RealFs, &path, None).unwrap();
        assert!(d.payloads.is_empty());
        assert!(read_fragment(&RealFs, &path, Some(1)).is_err());
    }
}
