//! Manifest-addressed durable storage: crash-safe WAL fragments,
//! generation-numbered manifests, and snapshot GC.
//!
//! This layer gives the feature store's two RAM-resident logs — the
//! geo-replication fabric's `PartitionedLog<ReplBatch>` and the stream
//! `EventLog` — a write-ahead durable form, and replaces "checkpoint =
//! full segment dump" with *manifest + tail replay* recovery. It is
//! organized wal3-style: bytes in checksummed, size-bounded **fragment**
//! files; truth in an atomically-replaced **manifest** chain; space
//! reclaimed by a mark-then-sweep **GC** that only trusts the manifest.
//!
//! # Manifest format
//!
//! `MANIFEST.<generation>` (10-digit, zero-padded) is a checksummed JSON
//! document (`magic | payload | fnv1a(payload)`) recording, atomically:
//!
//! * **fragment set** — per durable log: partition count, per-partition
//!   truncation `bases`, and every fragment file with `{file, partition,
//!   base, sealed, count}`. Sealed fragments carry an authoritative
//!   frame count; the at-most-one unsealed fragment per partition is the
//!   active tail.
//! * **segment set** — the `.gfseg` offline-store segments of the last
//!   checkpoint, `{file, table}` each.
//! * **cursor positions** — per-region replication apply cursors, the
//!   fabric checkpoint floor, the stream consumers' checkpoint entries,
//!   and the scheduler's materialization coverage.
//!
//! Manifests are never modified in place: each commit writes generation
//! `g+1` via the shared temp-file + rename + fsync-parent idiom
//! ([`vfs::atomic_write_parts`]) and leaves generation `g` as fallback.
//!
//! # Recovery protocol
//!
//! 1. **Root.** Load the newest `MANIFEST.*` whose magic + checksum +
//!    decode all verify; fall back generation by generation. Manifests
//!    present but none valid ⇒ fail closed ([`crate::FsError::Corrupt`])
//!    — the store never silently restarts empty over corrupted state.
//! 2. **Log replay.** Per partition, read fragments in base order
//!    (continuity checked). Sealed fragments must yield exactly `count`
//!    frames — a torn frame inside one is corruption, fail closed. The
//!    active fragment may end torn (crash past the last acked fsync):
//!    its valid prefix is recovered and it is immediately re-sealed at
//!    that count, so torn bytes are never re-read as data. Offsets below
//!    the manifest `bases` were truncated pre-crash and are skipped.
//! 3. **Positions.** Replica cursors, the checkpoint floor, consumer
//!    checkpoints and scheduler coverage come straight from the
//!    manifest; the serving tail is re-derived by replaying the log
//!    above those cursors — no full segment dump is ever needed.
//!
//! The ack invariant: a record is *acked* once **a completed sync
//! covers its frame**. Under [`wal::SyncPolicy::PerAppend`] that sync
//! is the appender's own per-frame fsync; under
//! [`wal::SyncPolicy::GroupCommit`] one leader-issued fsync covers a
//! whole staged batch — the frames share a single buffered write and
//! the waiters are woken only once the covering sync completes, so the
//! guarantee is identical and only the sync *rate* changes. Frames
//! written but not yet covered are *staged*, not acked: a failed sync
//! seals the fragment at the last covered count, so a staged-only
//! frame can never be recovered as acked. Every acked record is either
//! in a sealed fragment (count covers it) or in the active fragment's
//! valid prefix — recovery returns all of them, and nothing below the
//! ack point is lost. Records past the last ack may or may not survive
//! (at-least-once); downstream sinks are idempotent.
//!
//! # GC safety argument
//!
//! GC deletes a file only if **(a)** it is referenced by neither of the
//! two newest valid manifest generations (nor is one of those manifest
//! files), and **(b)** it was already unreferenced on a *previous* GC
//! pass (two-pass mark/sweep, [`gc`]). (a) protects the fallback root:
//! even a crash between "write new manifest" and "first reference
//! settles" leaves a pinned previous generation. (b) closes the
//! create-before-commit window: a fragment or segment file exists
//! briefly before the manifest commit that references it, but by the
//! *next* GC pass that commit has either landed (file is live) or its
//! writer crashed (file is a true orphan — it holds no acked data,
//! because appends only begin after the commit). `.tmp` files are swept
//! only at open time, when no writer can be mid-rename.

pub mod fragment;
pub mod gc;
pub mod manifest;
pub mod vfs;
pub mod wal;

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::types::Result;
use crate::util::json::Json;

pub use gc::{GcDriver, GcStats};
pub use manifest::{Manifest, ManifestStore, SegmentRef};
pub use vfs::{atomic_write, RealFs, Vfs};
pub use wal::{DurableLog, DurableLogOptions, LogRecord, LogSection, SyncPolicy};

/// One durable store directory: the manifest chain plus every fragment
/// and segment file, with a registry of open logs so checkpoint commits
/// capture fresh per-log state.
pub struct DurableStore {
    fs: Arc<dyn Vfs>,
    dir: PathBuf,
    manifests: Arc<ManifestStore>,
    sections: Mutex<Vec<Arc<dyn LogSection>>>,
    /// GC mark set (files seen unreferenced once; see [`gc`]).
    gc_pending: Mutex<HashSet<String>>,
    next_snapshot: AtomicU64,
}

impl DurableStore {
    /// Open (or create) a durable store at `dir`: sweep stranded `.tmp`
    /// files (no writer is live at open), then load the manifest chain.
    pub fn open(fs: Arc<dyn Vfs>, dir: &Path, now: i64) -> Result<Arc<DurableStore>> {
        fs.create_dir_all(dir)?;
        for path in fs.list(dir)? {
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                let _ = fs.remove(&path);
            }
        }
        let manifests = Arc::new(ManifestStore::open(fs.clone(), dir, now)?);
        // Seed the snapshot-id allocator past anything on disk *or* in
        // the manifest, so a crashed checkpoint's orphan segment is
        // never overwritten before GC reaps it.
        let mut next = 1;
        for s in &manifests.current().segments {
            if let Some(id) = parse_snapshot_id(&s.file) {
                next = next.max(id + 1);
            }
        }
        for path in fs.list(dir)? {
            if let Some(id) =
                path.file_name().and_then(|n| n.to_str()).and_then(parse_snapshot_id)
            {
                next = next.max(id + 1);
            }
        }
        Ok(Arc::new(DurableStore {
            fs,
            dir: dir.to_path_buf(),
            manifests,
            sections: Mutex::new(Vec::new()),
            gc_pending: Mutex::new(HashSet::new()),
            next_snapshot: AtomicU64::new(next),
        }))
    }

    pub fn fs(&self) -> &Arc<dyn Vfs> {
        &self.fs
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifests(&self) -> &Arc<ManifestStore> {
        &self.manifests
    }

    /// Snapshot of the committed manifest.
    pub fn manifest(&self) -> Manifest {
        self.manifests.current()
    }

    pub(crate) fn gc_pending(&self) -> &Mutex<HashSet<String>> {
        &self.gc_pending
    }

    /// Open a durable log in this store and register it so checkpoint
    /// commits refresh its manifest section.
    pub fn open_log<T: LogRecord>(
        self: &Arc<Self>,
        name: &str,
        partitions: usize,
        opts: DurableLogOptions,
    ) -> Result<Arc<DurableLog<T>>> {
        let log =
            DurableLog::open(name, partitions, self.fs.clone(), self.manifests.clone(), opts)?;
        self.sections.lock().unwrap().push(log.clone());
        Ok(log)
    }

    /// Allocate a fresh checkpoint-snapshot id (monotone across
    /// restarts and crashed checkpoints).
    pub fn alloc_snapshot_id(&self) -> u64 {
        self.next_snapshot.fetch_add(1, Ordering::Relaxed)
    }

    /// File name for a checkpointed offline segment.
    pub fn segment_file_name(id: u64, table: &str) -> String {
        let safe: String =
            table.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
        format!("seg-s{id:06}-{safe}.gfseg")
    }

    /// Commit a checkpoint manifest generation: every registered log's
    /// section is refreshed (fresh truncation bases, dead fragments
    /// dropped), then `f` records the checkpoint payload (segments,
    /// cursors, floor, consumer checkpoints, coverage). Returns the
    /// committed generation.
    pub fn commit_checkpoint(
        &self,
        now: i64,
        f: impl FnOnce(&mut Manifest),
    ) -> Result<u64> {
        let sections: Vec<Arc<dyn LogSection>> = self.sections.lock().unwrap().clone();
        self.manifests.update(|m| {
            for s in &sections {
                s.refresh(m);
            }
            m.created_at = now;
            f(m);
        })
    }

    /// One GC pass (see [`gc::collect`]).
    pub fn gc(&self) -> Result<GcStats> {
        gc::collect(self)
    }

    /// Recovered-state audit: what the manifest pins vs. what is on
    /// disk. Uploaded as a CI artifact by the torture harness.
    pub fn audit(&self) -> Result<Json> {
        let m = self.manifest();
        let live = self.manifests.live_files();
        let mut on_disk: Vec<String> = self
            .fs
            .list(&self.dir)?
            .into_iter()
            .filter_map(|p| p.file_name().and_then(|n| n.to_str()).map(str::to_string))
            .collect();
        on_disk.sort();
        let orphans: Vec<Json> = on_disk
            .iter()
            .filter(|n| !live.contains(*n) && !n.ends_with(".tmp"))
            .map(|n| Json::str(n.clone()))
            .collect();
        let logs = Json::Obj(
            m.logs
                .iter()
                .map(|(name, lm)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("partitions", Json::num(lm.partitions as f64)),
                            (
                                "bases",
                                Json::Arr(
                                    lm.bases.iter().map(|&b| Json::num(b as f64)).collect(),
                                ),
                            ),
                            ("fragments", Json::num(lm.fragments.len() as f64)),
                            (
                                "sealed",
                                Json::num(
                                    lm.fragments.iter().filter(|f| f.sealed).count() as f64,
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        Ok(Json::obj(vec![
            ("generation", Json::num(m.generation as f64)),
            ("created_at", Json::num(m.created_at as f64)),
            ("logs", logs),
            ("segments", Json::num(m.segments.len() as f64)),
            ("files_on_disk", Json::num(on_disk.len() as f64)),
            ("live_files", Json::num(live.len() as f64)),
            ("orphans", Json::Arr(orphans)),
        ]))
    }
}

fn parse_snapshot_id(file: &str) -> Option<u64> {
    let rest = file.strip_prefix("seg-s")?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::log::StreamEvent;
    use crate::testkit::TempDir;

    fn open(dir: &Path) -> Arc<DurableStore> {
        DurableStore::open(Arc::new(RealFs), dir, 0).unwrap()
    }

    #[test]
    fn open_sweeps_tmp_files() {
        let dir = TempDir::new("store-tmp");
        std::fs::write(dir.file("x.frag.tmp"), b"stranded").unwrap();
        let store = open(dir.path());
        assert!(!dir.file("x.frag.tmp").exists());
        assert_eq!(store.manifest().generation, 0);
    }

    #[test]
    fn snapshot_ids_are_monotone_across_restarts_and_orphans() {
        let dir = TempDir::new("store-snap");
        let store = open(dir.path());
        let a = store.alloc_snapshot_id();
        let b = store.alloc_snapshot_id();
        assert!(b > a);
        // An orphan segment from a crashed checkpoint advances the seed.
        std::fs::write(dir.file(&DurableStore::segment_file_name(17, "t")), b"x").unwrap();
        let store2 = open(dir.path());
        assert!(store2.alloc_snapshot_id() > 17);
    }

    #[test]
    fn two_pass_gc_reaps_orphans_but_spares_live_and_fresh_files() {
        let dir = TempDir::new("store-gc");
        let store = open(dir.path());
        let log = store
            .open_log::<StreamEvent>("l", 1, DurableLogOptions::default())
            .unwrap();
        log.append(0, StreamEvent::new(0, "k", 0, 1.0)).unwrap();
        // An orphan fragment (crashed pre-commit) and an orphan segment.
        std::fs::write(dir.file("l-p0-999999999999.frag"), b"orphan").unwrap();
        std::fs::write(dir.file("seg-s000099-dead.gfseg"), b"orphan").unwrap();
        let first = store.gc().unwrap();
        assert_eq!(first.removed, 0, "first sight only marks");
        assert!(first.pending >= 2, "{first:?}");
        let second = store.gc().unwrap();
        assert!(second.removed >= 2, "still-unreferenced files reaped: {second:?}");
        assert!(!dir.file("l-p0-999999999999.frag").exists());
        assert!(!dir.file("seg-s000099-dead.gfseg").exists());
        // The live fragment and manifest chain survive.
        assert!(dir.file("l-p0-000000000000.frag").exists());
        let third = store.gc().unwrap();
        assert_eq!(third.removed, 0);
        // Old manifest generations beyond the two newest get reaped too.
        for i in 0..4 {
            store.commit_checkpoint(i, |_| {}).unwrap();
        }
        store.gc().unwrap();
        let reaped = store.gc().unwrap();
        assert!(reaped.removed > 0, "stale manifest generations are garbage");
        let gen = store.manifest().generation;
        assert!(dir.file(&manifest::manifest_file_name(gen)).exists());
        assert!(dir.file(&manifest::manifest_file_name(gen - 1)).exists());
    }

    #[test]
    fn commit_checkpoint_refreshes_registered_logs() {
        let dir = TempDir::new("store-ckpt");
        let store = open(dir.path());
        let log = store
            .open_log::<StreamEvent>("l", 1, DurableLogOptions::default())
            .unwrap();
        for i in 0..5u64 {
            log.append(0, StreamEvent::new(i, "k", 0, 0.0)).unwrap();
        }
        log.truncate_below(0, 3);
        let gen = store
            .commit_checkpoint(42, |m| {
                m.cursors.insert("eu".into(), vec![3]);
            })
            .unwrap();
        let m = store.manifest();
        assert_eq!(m.generation, gen);
        assert_eq!(m.created_at, 42);
        assert_eq!(m.logs["l"].bases, vec![3], "checkpoint pulls fresh truncation floors");
        assert_eq!(m.cursors["eu"], vec![3]);
    }

    #[test]
    fn audit_reports_orphans_and_generation() {
        let dir = TempDir::new("store-audit");
        let store = open(dir.path());
        std::fs::write(dir.file("stray.gfseg"), b"x").unwrap();
        let a = store.audit().unwrap();
        assert_eq!(a.get("generation").as_i64(), Some(0));
        let orphans = a.get("orphans").as_arr().unwrap();
        assert!(orphans.iter().any(|o| o.as_str() == Some("stray.gfseg")), "{a}");
    }
}
