//! Generation-numbered manifests: the durable root of the store.
//!
//! A manifest is one checksummed JSON document naming every file and
//! position the store needs to recover:
//!
//! ```text
//! MANIFEST.<gen>  :=  magic "GFMAN1\0\0" | json payload | fnv1a(payload) u64
//! json            :=  { generation, created_at,
//!                       logs:   { name → { partitions, bases[], fragments[] } },
//!                       segments: [ { file, table } ],
//!                       cursors:  { region → [u64] },
//!                       checkpoint_floor: null | [u64],
//!                       consumer_checkpoints: <CheckpointStore entries>,
//!                       coverage: [ { table, windows: [{start,end}] } ] }
//! ```
//!
//! Manifests are immutable once written: every commit writes a **new**
//! generation via the shared temp-file + rename idiom and leaves the
//! previous generation on disk as the fallback root. Recovery loads the
//! newest generation whose checksum verifies; a torn or bit-flipped
//! newest manifest falls back to the previous one, and only if *every*
//! present manifest fails validation does open fail closed with
//! [`FsError::Corrupt`] — the store never guesses at state.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::fragment::FragmentMeta;
use super::vfs::{atomic_write_parts, corrupt, fnv1a, Vfs};
use crate::types::window::FeatureWindow;
use crate::types::Result;
use crate::util::json::Json;

pub const MANIFEST_MAGIC: &[u8; 8] = b"GFMAN1\0\0";
pub const MANIFEST_PREFIX: &str = "MANIFEST.";

/// One durable log's section of the manifest.
#[derive(Debug, Clone, Default)]
pub struct LogManifest {
    pub partitions: usize,
    /// Per-partition truncation floor: offsets below are reclaimed.
    pub bases: Vec<u64>,
    pub fragments: Vec<FragmentMeta>,
}

/// One persisted offline segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentRef {
    pub file: String,
    pub table: String,
}

/// The full recovery root (see module docs for the format).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub generation: u64,
    pub created_at: i64,
    pub logs: BTreeMap<String, LogManifest>,
    pub segments: Vec<SegmentRef>,
    pub cursors: BTreeMap<String, Vec<u64>>,
    pub checkpoint_floor: Option<Vec<u64>>,
    /// Stream consumer checkpoints, in `CheckpointStore`'s entry shape.
    pub consumer_checkpoints: Json,
    /// Scheduler materialization coverage at checkpoint time.
    pub coverage: Vec<(String, Vec<FeatureWindow>)>,
}

impl Manifest {
    pub fn empty(now: i64) -> Manifest {
        Manifest {
            generation: 0,
            created_at: now,
            logs: BTreeMap::new(),
            segments: Vec::new(),
            cursors: BTreeMap::new(),
            checkpoint_floor: None,
            consumer_checkpoints: Json::Null,
            coverage: Vec::new(),
        }
    }

    /// Every data file this manifest references (names relative to the
    /// store directory) — the GC live set contribution.
    pub fn referenced_files(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for lm in self.logs.values() {
            out.extend(lm.fragments.iter().map(|f| f.file.clone()));
        }
        out.extend(self.segments.iter().map(|s| s.file.clone()));
        out
    }

    fn to_json(&self) -> Json {
        let logs = Json::Obj(
            self.logs
                .iter()
                .map(|(name, lm)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("partitions", Json::num(lm.partitions as f64)),
                            (
                                "bases",
                                Json::Arr(lm.bases.iter().map(|&b| Json::num(b as f64)).collect()),
                            ),
                            (
                                "fragments",
                                Json::Arr(
                                    lm.fragments
                                        .iter()
                                        .map(|f| {
                                            Json::obj(vec![
                                                ("file", Json::str(&f.file)),
                                                ("partition", Json::num(f.partition as f64)),
                                                ("base", Json::num(f.base as f64)),
                                                ("sealed", Json::Bool(f.sealed)),
                                                ("count", Json::num(f.count as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let segments = Json::Arr(
            self.segments
                .iter()
                .map(|s| {
                    Json::obj(vec![("file", Json::str(&s.file)), ("table", Json::str(&s.table))])
                })
                .collect(),
        );
        let cursors = Json::Obj(
            self.cursors
                .iter()
                .map(|(region, cs)| {
                    (
                        region.clone(),
                        Json::Arr(cs.iter().map(|&c| Json::num(c as f64)).collect()),
                    )
                })
                .collect(),
        );
        let floor = match &self.checkpoint_floor {
            None => Json::Null,
            Some(fl) => Json::Arr(fl.iter().map(|&c| Json::num(c as f64)).collect()),
        };
        let coverage = Json::Arr(
            self.coverage
                .iter()
                .map(|(table, windows)| {
                    Json::obj(vec![
                        ("table", Json::str(table)),
                        (
                            "windows",
                            Json::Arr(
                                windows
                                    .iter()
                                    .map(|w| {
                                        Json::obj(vec![
                                            ("start", Json::num(w.start as f64)),
                                            ("end", Json::num(w.end as f64)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("generation", Json::num(self.generation as f64)),
            ("created_at", Json::num(self.created_at as f64)),
            ("logs", logs),
            ("segments", segments),
            ("cursors", cursors),
            ("checkpoint_floor", floor),
            ("consumer_checkpoints", self.consumer_checkpoints.clone()),
            ("coverage", coverage),
        ])
    }

    fn from_json(v: &Json) -> Result<Manifest> {
        let generation = as_u64(v.get("generation"))
            .ok_or_else(|| corrupt("manifest missing 'generation'"))?;
        let created_at =
            v.get("created_at").as_i64().ok_or_else(|| corrupt("manifest missing 'created_at'"))?;
        let mut logs = BTreeMap::new();
        if let Some(obj) = v.get("logs").as_obj() {
            for (name, lv) in obj {
                let partitions = lv
                    .get("partitions")
                    .as_usize()
                    .ok_or_else(|| corrupt(format!("log '{name}': bad 'partitions'")))?;
                let bases = u64_array(lv.get("bases"))
                    .ok_or_else(|| corrupt(format!("log '{name}': bad 'bases'")))?;
                let mut fragments = Vec::new();
                for fv in lv.get("fragments").as_arr().unwrap_or(&[]) {
                    fragments.push(FragmentMeta {
                        file: fv
                            .get("file")
                            .as_str()
                            .ok_or_else(|| corrupt("fragment missing 'file'"))?
                            .to_string(),
                        partition: fv
                            .get("partition")
                            .as_usize()
                            .ok_or_else(|| corrupt("fragment missing 'partition'"))?,
                        base: as_u64(fv.get("base"))
                            .ok_or_else(|| corrupt("fragment missing 'base'"))?,
                        sealed: fv.get("sealed").as_bool().unwrap_or(false),
                        count: as_u64(fv.get("count")).unwrap_or(0),
                    });
                }
                logs.insert(name.clone(), LogManifest { partitions, bases, fragments });
            }
        }
        let mut segments = Vec::new();
        for sv in v.get("segments").as_arr().unwrap_or(&[]) {
            segments.push(SegmentRef {
                file: sv
                    .get("file")
                    .as_str()
                    .ok_or_else(|| corrupt("segment missing 'file'"))?
                    .to_string(),
                table: sv
                    .get("table")
                    .as_str()
                    .ok_or_else(|| corrupt("segment missing 'table'"))?
                    .to_string(),
            });
        }
        let mut cursors = BTreeMap::new();
        if let Some(obj) = v.get("cursors").as_obj() {
            for (region, cv) in obj {
                let cs = u64_array(cv)
                    .ok_or_else(|| corrupt(format!("cursors for '{region}' malformed")))?;
                cursors.insert(region.clone(), cs);
            }
        }
        let checkpoint_floor = match v.get("checkpoint_floor") {
            Json::Null => None,
            other => {
                Some(u64_array(other).ok_or_else(|| corrupt("bad 'checkpoint_floor'"))?)
            }
        };
        let mut coverage = Vec::new();
        for cv in v.get("coverage").as_arr().unwrap_or(&[]) {
            let table = cv
                .get("table")
                .as_str()
                .ok_or_else(|| corrupt("coverage entry missing 'table'"))?
                .to_string();
            let mut windows = Vec::new();
            for wv in cv.get("windows").as_arr().unwrap_or(&[]) {
                let (start, end) = match (wv.get("start").as_i64(), wv.get("end").as_i64()) {
                    (Some(s), Some(e)) => (s, e),
                    _ => return Err(corrupt("coverage window missing bounds")),
                };
                windows.push(FeatureWindow::new(start, end));
            }
            coverage.push((table, windows));
        }
        Ok(Manifest {
            generation,
            created_at,
            logs,
            segments,
            cursors,
            checkpoint_floor,
            consumer_checkpoints: v.get("consumer_checkpoints").clone(),
            coverage,
        })
    }
}

fn as_u64(v: &Json) -> Option<u64> {
    v.as_f64().and_then(|f| if f >= 0.0 { Some(f as u64) } else { None })
}

fn u64_array(v: &Json) -> Option<Vec<u64>> {
    v.as_arr().map(|a| a.iter().filter_map(as_u64).collect::<Vec<u64>>()).and_then(|out| {
        (out.len() == v.as_arr().map(|a| a.len()).unwrap_or(0)).then_some(out)
    })
}

/// The on-disk file name of one manifest generation (zero-padded so a
/// lexicographic directory sort is a generation sort).
pub fn manifest_file_name(generation: u64) -> String {
    format!("{MANIFEST_PREFIX}{generation:010}")
}

fn parse_generation(name: &str) -> Option<u64> {
    name.strip_prefix(MANIFEST_PREFIX)?.parse().ok()
}

/// Serialize + checksum + atomically write one manifest generation.
fn write_manifest(fs: &dyn Vfs, dir: &Path, m: &Manifest) -> Result<()> {
    let payload = m.to_json().to_string().into_bytes();
    let sum = fnv1a(&payload).to_le_bytes();
    atomic_write_parts(fs, &dir.join(manifest_file_name(m.generation)), &[
        MANIFEST_MAGIC,
        &payload,
        &sum,
    ])
}

/// Read + validate one manifest file (magic, checksum, decode).
pub fn load_manifest_file(fs: &dyn Vfs, path: &Path) -> Result<Manifest> {
    let bytes = fs.read(path)?;
    if bytes.len() < 8 + 8 {
        return Err(corrupt(format!("manifest {path:?}: truncated")));
    }
    if &bytes[..8] != MANIFEST_MAGIC {
        return Err(corrupt(format!("manifest {path:?}: bad magic")));
    }
    let payload = &bytes[8..bytes.len() - 8];
    let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a(payload) != sum {
        return Err(corrupt(format!("manifest {path:?}: checksum mismatch")));
    }
    let text = std::str::from_utf8(payload)
        .map_err(|_| corrupt(format!("manifest {path:?}: invalid utf-8")))?;
    let v = Json::parse(text).map_err(|e| corrupt(format!("manifest {path:?}: {e}")))?;
    Manifest::from_json(&v)
}

struct StoreState {
    current: Manifest,
    /// The generation committed immediately before `current` — still in
    /// the GC live set so a crash mid-commit always leaves a valid root.
    prev: Option<Manifest>,
}

/// Serialized access to the manifest chain: one committer at a time,
/// every commit a new generation.
pub struct ManifestStore {
    fs: Arc<dyn Vfs>,
    dir: PathBuf,
    state: Mutex<StoreState>,
}

impl ManifestStore {
    /// Open the store directory: load the newest valid manifest
    /// generation, falling back across invalid ones; a directory with
    /// manifests but no valid one fails closed. A fresh directory
    /// commits generation 0 so GC always has a live root.
    pub fn open(fs: Arc<dyn Vfs>, dir: &Path, now: i64) -> Result<ManifestStore> {
        fs.create_dir_all(dir)?;
        let mut gens: Vec<u64> = fs
            .list(dir)?
            .into_iter()
            .filter_map(|p| {
                p.file_name().and_then(|n| n.to_str()).and_then(parse_generation)
            })
            .collect();
        gens.sort_unstable_by(|a, b| b.cmp(a));
        let mut current = None;
        let mut prev = None;
        let mut last_err = None;
        for &gen in &gens {
            match load_manifest_file(fs.as_ref(), &dir.join(manifest_file_name(gen))) {
                Ok(m) if current.is_none() => current = Some(m),
                Ok(m) => {
                    prev = Some(m);
                    break;
                }
                Err(e) => {
                    log::warn!("skipping invalid manifest generation {gen}: {e}");
                    last_err = Some(e);
                }
            }
        }
        let current = match current {
            Some(m) => m,
            None if gens.is_empty() => {
                let m = Manifest::empty(now);
                write_manifest(fs.as_ref(), dir, &m)?;
                m
            }
            None => {
                return Err(last_err
                    .unwrap_or_else(|| corrupt("manifest directory has no valid manifest")))
            }
        };
        Ok(ManifestStore {
            fs,
            dir: dir.to_path_buf(),
            state: Mutex::new(StoreState { current, prev }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot of the committed manifest.
    pub fn current(&self) -> Manifest {
        self.state.lock().unwrap().current.clone()
    }

    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().current.generation
    }

    /// Commit a new generation: clone the current manifest, apply `f`,
    /// bump the generation, atomically write the new file. On write
    /// failure the in-memory state is unchanged (the old generation
    /// remains the root). Returns the committed generation.
    pub fn update(&self, f: impl FnOnce(&mut Manifest)) -> Result<u64> {
        let mut st = self.state.lock().unwrap();
        let mut next = st.current.clone();
        f(&mut next);
        next.generation = st.current.generation + 1;
        write_manifest(self.fs.as_ref(), &self.dir, &next)?;
        let gen = next.generation;
        st.prev = Some(std::mem::replace(&mut st.current, next));
        Ok(gen)
    }

    /// Every file name the live manifest chain pins: data files of the
    /// two newest generations plus those manifest files themselves.
    pub fn live_files(&self) -> std::collections::HashSet<String> {
        let st = self.state.lock().unwrap();
        let mut live: std::collections::HashSet<String> =
            st.current.referenced_files().into_iter().collect();
        live.insert(manifest_file_name(st.current.generation));
        if let Some(prev) = &st.prev {
            live.extend(prev.referenced_files());
            live.insert(manifest_file_name(prev.generation));
        }
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::vfs::RealFs;
    use crate::testkit::TempDir;
    use crate::types::FsError;

    fn open(dir: &Path) -> ManifestStore {
        ManifestStore::open(Arc::new(RealFs), dir, 100).unwrap()
    }

    #[test]
    fn fresh_dir_commits_generation_zero() {
        let dir = TempDir::new("man");
        let ms = open(dir.path());
        assert_eq!(ms.generation(), 0);
        assert!(dir.file("MANIFEST.0000000000").exists());
        // Reopen finds it.
        let ms2 = open(dir.path());
        assert_eq!(ms2.generation(), 0);
        assert_eq!(ms2.current().created_at, 100);
    }

    #[test]
    fn update_roundtrips_all_fields() {
        let dir = TempDir::new("man-rt");
        let ms = open(dir.path());
        let gen = ms
            .update(|m| {
                m.created_at = 500;
                m.logs.insert(
                    "fabric".into(),
                    LogManifest {
                        partitions: 2,
                        bases: vec![3, 0],
                        fragments: vec![FragmentMeta {
                            file: "fabric-p0-3.frag".into(),
                            partition: 0,
                            base: 3,
                            sealed: true,
                            count: 9,
                        }],
                    },
                );
                m.segments.push(SegmentRef { file: "seg-s1-t.gfseg".into(), table: "t".into() });
                m.cursors.insert("eu".into(), vec![7, 1]);
                m.checkpoint_floor = Some(vec![8, 2]);
                m.consumer_checkpoints = Json::obj(vec![("checkpoints", Json::Arr(vec![]))]);
                m.coverage.push(("t".into(), vec![FeatureWindow::new(0, 3_600)]));
            })
            .unwrap();
        assert_eq!(gen, 1);
        let re = open(dir.path()).current();
        assert_eq!(re.generation, 1);
        assert_eq!(re.created_at, 500);
        let lm = &re.logs["fabric"];
        assert_eq!((lm.partitions, lm.bases.clone()), (2, vec![3, 0]));
        assert_eq!(lm.fragments[0].file, "fabric-p0-3.frag");
        assert!(lm.fragments[0].sealed);
        assert_eq!(lm.fragments[0].count, 9);
        assert_eq!(re.segments[0], SegmentRef { file: "seg-s1-t.gfseg".into(), table: "t".into() });
        assert_eq!(re.cursors["eu"], vec![7, 1]);
        assert_eq!(re.checkpoint_floor, Some(vec![8, 2]));
        assert_eq!(re.coverage, vec![("t".to_string(), vec![FeatureWindow::new(0, 3_600)])]);
        assert_ne!(re.consumer_checkpoints, Json::Null);
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous_generation() {
        let dir = TempDir::new("man-fall");
        let ms = open(dir.path());
        ms.update(|m| m.created_at = 1).unwrap();
        ms.update(|m| m.created_at = 2).unwrap();
        // Bit-flip the newest manifest: recovery must land on gen 1.
        let newest = dir.file(&manifest_file_name(2));
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&newest, &bytes).unwrap();
        let re = open(dir.path());
        assert_eq!(re.generation(), 1);
        assert_eq!(re.current().created_at, 1);
        // The next commit supersedes the corrupt generation.
        assert_eq!(re.update(|_| {}).unwrap(), 2);
        assert_eq!(open(dir.path()).current().created_at, 1);
    }

    #[test]
    fn all_invalid_manifests_fail_closed() {
        let dir = TempDir::new("man-closed");
        open(dir.path());
        // Corrupt the only manifest at every byte: open must never
        // fabricate a fresh store over a directory that *had* state.
        let path = dir.file(&manifest_file_name(0));
        let orig = std::fs::read(&path).unwrap();
        for idx in [0usize, 8, orig.len() / 2, orig.len() - 1] {
            let mut bytes = orig.clone();
            bytes[idx] ^= 0xff;
            std::fs::write(&path, &bytes).unwrap();
            let err = ManifestStore::open(Arc::new(RealFs), dir.path(), 0).unwrap_err();
            assert!(matches!(err, FsError::Corrupt(_)), "byte {idx}: {err}");
        }
        // Truncation at every boundary also fails closed.
        for cut in 0..orig.len() {
            std::fs::write(&path, &orig[..cut]).unwrap();
            assert!(
                ManifestStore::open(Arc::new(RealFs), dir.path(), 0).is_err(),
                "cut at {cut} must not load"
            );
        }
    }

    #[test]
    fn live_files_pin_two_generations() {
        let dir = TempDir::new("man-live");
        let ms = open(dir.path());
        ms.update(|m| {
            m.segments.push(SegmentRef { file: "old.gfseg".into(), table: "t".into() })
        })
        .unwrap();
        ms.update(|m| {
            m.segments.clear();
            m.segments.push(SegmentRef { file: "new.gfseg".into(), table: "t".into() });
        })
        .unwrap();
        let live = ms.live_files();
        assert!(live.contains("new.gfseg"));
        assert!(live.contains("old.gfseg"), "previous generation still pinned");
        assert!(live.contains(&manifest_file_name(2)));
        assert!(live.contains(&manifest_file_name(1)));
        assert!(!live.contains(&manifest_file_name(0)));
    }
}
