//! Filesystem seam under the durable storage layer.
//!
//! Every byte the storage layer persists flows through the [`Vfs`]
//! trait instead of `std::fs`, so the fault-injection filesystem
//! (`testkit::faultfs`) can sit *underneath* the fragment writers, the
//! manifest store and the atomic-write helper — torn writes, transient
//! I/O errors and crash points then exercise exactly the code paths
//! production runs, not a parallel test-only implementation.
//!
//! [`atomic_write_parts`] is the one shared implementation of the
//! temp-file + rename idiom: write to `<name>.tmp`, fsync the file,
//! rename over the target, fsync the parent directory (the rename
//! itself is not durable until the directory entry is). Both the
//! offline segment writer (`offline_store::segment`) and the stream
//! checkpoint store (`stream::consumer`) call through here so a fix to
//! the durability protocol lands everywhere at once.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::types::{FsError, Result};

/// An open writable file handle. Append-only: the storage layer never
/// seeks — fragments and manifests are written front to back.
pub trait VfsFile: Send {
    fn append(&mut self, buf: &[u8]) -> Result<()>;
    /// Flush to stable storage (fsync). The ack point for durability.
    fn sync(&mut self) -> Result<()>;
}

/// Minimal filesystem surface the storage layer needs. Object-safe so a
/// store can hold `Arc<dyn Vfs>` and tests can swap in a fault injector.
pub trait Vfs: Send + Sync {
    /// Create (truncate) a file for writing.
    fn create(&self, path: &Path) -> Result<Box<dyn VfsFile>>;
    /// Open an existing file for appending.
    fn open_append(&self, path: &Path) -> Result<Box<dyn VfsFile>>;
    /// Read a whole file.
    fn read(&self, path: &Path) -> Result<Vec<u8>>;
    /// Atomically rename `from` to `to` (same directory).
    fn rename(&self, from: &Path, to: &Path) -> Result<()>;
    /// Delete a file.
    fn remove(&self, path: &Path) -> Result<()>;
    /// List regular files in a directory (full paths, unsorted).
    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>>;
    /// fsync a directory (makes renames/creates in it durable).
    fn sync_dir(&self, dir: &Path) -> Result<()>;
    fn exists(&self, path: &Path) -> bool;
    fn create_dir_all(&self, dir: &Path) -> Result<()>;
}

/// The production [`Vfs`]: thin passthrough to `std::fs`.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealFs;

struct RealFile(fs::File);

impl VfsFile for RealFile {
    fn append(&mut self, buf: &[u8]) -> Result<()> {
        self.0.write_all(buf)?;
        Ok(())
    }
    fn sync(&mut self) -> Result<()> {
        self.0.sync_all()?;
        Ok(())
    }
}

impl Vfs for RealFs {
    fn create(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(fs::File::create(path)?)))
    }
    fn open_append(&self, path: &Path) -> Result<Box<dyn VfsFile>> {
        Ok(Box::new(RealFile(fs::OpenOptions::new().append(true).open(path)?)))
    }
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        Ok(fs::read(path)?)
    }
    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        fs::rename(from, to)?;
        Ok(())
    }
    fn remove(&self, path: &Path) -> Result<()> {
        fs::remove_file(path)?;
        Ok(())
    }
    fn list(&self, dir: &Path) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_file() {
                out.push(path);
            }
        }
        Ok(out)
    }
    fn sync_dir(&self, dir: &Path) -> Result<()> {
        // Directory fsync: open the directory and sync it. On platforms
        // where directories cannot be opened for sync this degrades to a
        // no-op rather than failing the write path.
        if let Ok(d) = fs::File::open(dir) {
            d.sync_all()?;
        }
        Ok(())
    }
    fn exists(&self, path: &Path) -> bool {
        path.exists()
    }
    fn create_dir_all(&self, dir: &Path) -> Result<()> {
        fs::create_dir_all(dir)?;
        Ok(())
    }
}

/// The sibling temp path a crash may strand: `<file_name>.tmp` in the
/// same directory (appended, not substituted, so `MANIFEST.0000000007`
/// and `MANIFEST.0000000008` never collide on one temp name).
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically replace `path` with the concatenation of `parts`:
/// temp file → write → fsync file → rename → fsync parent directory.
/// A crash at any point leaves either the old file intact or the new
/// file complete — never a torn target. Strands at most one `.tmp`
/// sibling, which the storage layer's open-time sweep removes.
pub fn atomic_write_parts(fs: &dyn Vfs, path: &Path, parts: &[&[u8]]) -> Result<()> {
    let tmp = tmp_path(path);
    let mut f = fs.create(&tmp)?;
    for part in parts {
        f.append(part)?;
    }
    f.sync()?;
    drop(f);
    fs.rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        fs.sync_dir(parent)?;
    }
    Ok(())
}

/// [`atomic_write_parts`] over the real filesystem — the shared
/// temp-file + rename entry point for callers outside the storage
/// layer (offline segments, stream checkpoint files).
pub fn atomic_write(path: &Path, parts: &[&[u8]]) -> Result<()> {
    atomic_write_parts(&RealFs, path, parts)
}

/// FNV-1a over a byte slice — the same checksum the offline segment
/// format uses, shared by fragment frames and manifest payloads.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Typed corruption error with a uniform prefix (tests assert on it).
pub(crate) fn corrupt(msg: impl Into<String>) -> FsError {
    FsError::Corrupt(msg.into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let dir = TempDir::new("vfs");
        let path = dir.file("target.bin");
        atomic_write(&path, &[b"hello ", b"world"]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello world");
        assert!(!tmp_path(&path).exists(), "tmp must be renamed away");
        // Overwrite goes through the same protocol.
        atomic_write(&path, &[b"v2"]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"v2");
    }

    #[test]
    fn tmp_path_appends_suffix() {
        let p = Path::new("/x/MANIFEST.0000000007");
        assert_eq!(tmp_path(p), Path::new("/x/MANIFEST.0000000007.tmp"));
        // Distinct targets never share a temp name (unlike with_extension).
        assert_ne!(tmp_path(Path::new("/x/MANIFEST.0000000008")), tmp_path(p));
    }

    #[test]
    fn realfs_roundtrip_and_list() {
        let dir = TempDir::new("vfs-real");
        let fs = RealFs;
        let p = dir.file("a.frag");
        let mut f = fs.create(&p).unwrap();
        f.append(b"abc").unwrap();
        f.sync().unwrap();
        drop(f);
        let mut f = fs.open_append(&p).unwrap();
        f.append(b"def").unwrap();
        f.sync().unwrap();
        drop(f);
        assert_eq!(fs.read(&p).unwrap(), b"abcdef");
        assert!(fs.exists(&p));
        let listed = fs.list(dir.path()).unwrap();
        assert_eq!(listed, vec![p.clone()]);
        fs.remove(&p).unwrap();
        assert!(!fs.exists(&p));
    }

    #[test]
    fn fnv1a_matches_reference() {
        // Same constants as the offline segment checksum.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
