//! The churn workload: customers, transactions, complaints; feature sets
//! over both; an observation spine with labels for training.

use std::sync::Arc;

use crate::coordinator::FeatureStore;
use crate::governance::rbac::{Grant, Principal, Role};
use crate::metadata::assets::{EntitySpec, FeatureSetSpec, SourceSpec};
use crate::query::spec::FeatureRef;
use crate::source::synthetic::SyntheticSource;
use crate::types::time::{Granularity, DAY, HOUR};
use crate::types::{Result, Timestamp};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ChurnWorkloadConfig {
    pub customers: usize,
    /// Days of event history.
    pub days: i64,
    pub seed: u64,
    /// Rolling window (bins) for the daily transaction feature set.
    pub txn_window_days: usize,
    /// Rolling window (bins) for the hourly interaction feature set.
    pub hourly_window: usize,
}

impl Default for ChurnWorkloadConfig {
    fn default() -> Self {
        ChurnWorkloadConfig { customers: 64, days: 14, seed: 42, txn_window_days: 30, hourly_window: 24 }
    }
}

/// Handles to everything the scenario registered.
pub struct ChurnWorkload {
    pub cfg: ChurnWorkloadConfig,
    /// Daily 30-day transaction aggregates table ref.
    pub txn_table: String,
    /// Hourly 24-hour interaction aggregates table ref.
    pub interactions_table: String,
    pub principal: Principal,
}

impl ChurnWorkload {
    /// Register entities, feature sets and sources on an opened store.
    pub fn install(fs: &FeatureStore, cfg: ChurnWorkloadConfig) -> Result<ChurnWorkload> {
        fs.create_store("churn-fs")?;
        fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"]))?;

        // Feature set 1: 30-day rolling transaction aggregates, daily bins
        // (the paper's 30day_transactions_sum).
        let mut txn_spec = FeatureSetSpec::rolling(
            "txn_30d",
            1,
            "customer",
            SourceSpec::synthetic(cfg.seed),
            Granularity::daily(),
            cfg.txn_window_days,
        );
        txn_spec.description = "30-day rolling customer transaction aggregates".into();
        txn_spec.tags = vec!["churn".into()];
        let txn_source = Arc::new(
            SyntheticSource::new(cfg.seed, cfg.customers).with_rate(0.5), // ~12 txns/day
        );
        let txn_table = fs.register_feature_set(txn_spec, txn_source, 0)?;

        // Feature set 2: 24-hour rolling interaction aggregates, hourly
        // bins (support contacts / complaints).
        let mut ix_spec = FeatureSetSpec::rolling(
            "interactions_24h",
            1,
            "customer",
            SourceSpec::synthetic(cfg.seed + 1),
            Granularity::hourly(),
            cfg.hourly_window,
        );
        ix_spec.description = "24-hour rolling customer interaction aggregates".into();
        ix_spec.tags = vec!["churn".into()];
        let ix_source =
            Arc::new(SyntheticSource::new(cfg.seed + 1, cfg.customers).with_rate(0.15));
        let interactions_table = fs.register_feature_set(ix_spec, ix_source, 0)?;

        // A data-scientist principal with producer rights.
        let principal = Principal("ds-alice".into());
        fs.rbac.grant(Grant {
            principal: principal.clone(),
            store: "churn-fs".into(),
            role: Role::Admin,
            workspace: "churn-ws".into(),
            workspace_region: fs.config.home_region().to_string(),
        });

        Ok(ChurnWorkload { cfg, txn_table, interactions_table, principal })
    }

    /// The feature columns the churn model consumes.
    pub fn model_features(&self) -> Vec<FeatureRef> {
        let w_txn = self.cfg.txn_window_days * 24;
        let w_ix = self.cfg.hourly_window;
        [
            format!("txn_30d:1:{w_txn}h_sum"),
            format!("txn_30d:1:{w_txn}h_cnt"),
            format!("txn_30d:1:{w_txn}h_mean"),
            format!("interactions_24h:1:{w_ix}h_cnt"),
            format!("interactions_24h:1:{w_ix}h_max"),
        ]
        .iter()
        .map(|s| FeatureRef::parse(s).unwrap())
        .collect()
    }

    /// Observation spine + synthetic churn labels: one observation per
    /// customer at a random time in the back half of the history.
    pub fn observation_spine(&self, n: usize) -> Vec<(String, Timestamp, bool)> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x5eed);
        let half = self.cfg.days * DAY / 2;
        (0..n)
            .map(|_i| {
                let cust = rng.below(self.cfg.customers as u64);
                let ts = half + rng.range(0, self.cfg.days * DAY - half - HOUR);
                // Label correlates with customer id parity (a learnable
                // synthetic signal, not used by correctness tests).
                let label = cust % 3 == 0 || rng.bool(0.1);
                (format!("cust_{cust:05}"), ts, label)
            })
            .collect()
    }

    /// Serving trace: (customer_key, consumer_region) lookups.
    pub fn serving_trace(&self, n: usize, regions: &[String]) -> Vec<(String, String)> {
        let mut rng = Rng::new(self.cfg.seed ^ 0x7ace);
        (0..n)
            .map(|_| {
                let cust = rng.below(self.cfg.customers as u64);
                let region = rng.pick(regions).clone();
                (format!("cust_{cust:05}"), region)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::OpenOptions;

    #[test]
    fn installs_and_produces_consistent_fixture() {
        let fs = crate::coordinator::FeatureStore::open(
            Config::default_local(),
            OpenOptions { with_engine: false, ..Default::default() },
        )
        .unwrap();
        let w = ChurnWorkload::install(&fs, ChurnWorkloadConfig::default()).unwrap();
        assert_eq!(w.txn_table, "txn_30d:1");
        assert_eq!(w.interactions_table, "interactions_24h:1");
        assert_eq!(w.model_features().len(), 5);
        // Feature refs resolve against the registered specs.
        let specs = fs.feature_set_specs();
        for f in w.model_features() {
            let spec = &specs[&f.feature_set];
            assert!(f.column_index(spec).is_ok(), "{f} must resolve");
        }
        let spine = w.observation_spine(100);
        assert_eq!(spine.len(), 100);
        assert!(spine.iter().any(|(_, _, l)| *l) && spine.iter().any(|(_, _, l)| !*l));
        let trace = w.serving_trace(50, &["local".to_string()]);
        assert_eq!(trace.len(), 50);
    }

    #[test]
    fn search_finds_churn_assets() {
        let fs = crate::coordinator::FeatureStore::open(
            Config::default_local(),
            OpenOptions { with_engine: false, ..Default::default() },
        )
        .unwrap();
        ChurnWorkload::install(&fs, ChurnWorkloadConfig::default()).unwrap();
        let hits = fs.catalog.search(&crate::metadata::catalog::SearchQuery::tag("churn"));
        assert_eq!(hits.len(), 2);
    }
}
