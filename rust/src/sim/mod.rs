//! Workload simulation: the customer-churn scenario from the paper's
//! introduction (`30day_transactions_sum`, `30day_complaints_sum`)
//! packaged as a reusable fixture for examples, integration tests and
//! benches.

pub mod workload;

pub use workload::{ChurnWorkload, ChurnWorkloadConfig};
