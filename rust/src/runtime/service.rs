//! Compute service: thread-owned PJRT engines behind a channel.
//!
//! The `xla` crate's `PjRtClient`/`PjRtLoadedExecutable` are `!Send`
//! (`Rc` + raw pointers), so engines cannot be shared across the worker
//! pool directly.  The compute service gives each of `n` dedicated
//! threads its own [`Engine`] (own client, own executable cache) and
//! exposes a cloneable, `Send + Sync` [`ComputeHandle`] that dispatches
//! rolling-aggregation requests round-robin — the paper's §3.1.5 managed
//! compute, sized by configuration.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::manifest::Manifest;
use super::tensor::{BinPlanes, RollPlanes};
use super::{Engine, Variant};
use crate::types::{FsError, Result};

struct Request {
    variant: Variant,
    planes: BinPlanes,
    window: usize,
    reply: Sender<Result<RollPlanes>>,
}

/// Owns the engine threads; dropping it stops them.
pub struct ComputeService {
    senders: Vec<Sender<Request>>,
    threads: Vec<JoinHandle<()>>,
    manifest: Arc<Manifest>,
}

impl ComputeService {
    /// Start `threads` engine threads over the artifact directory.
    pub fn start(artifacts_dir: impl AsRef<std::path::Path>, threads: usize) -> Result<ComputeService> {
        assert!(threads > 0);
        let dir = artifacts_dir.as_ref().to_path_buf();
        // Validate the manifest up front (fail fast on a bad dir).
        let manifest = Arc::new(Manifest::load(&dir)?);
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for i in 0..threads {
            let (tx, rx) = channel::<Request>();
            let dir = dir.clone();
            let handle = std::thread::Builder::new()
                .name(format!("geofs-compute-{i}"))
                .spawn(move || {
                    let engine = match Engine::load(&dir) {
                        Ok(e) => e,
                        Err(e) => {
                            log::error!("compute thread {i}: engine init failed: {e}");
                            // Drain requests with errors so callers unblock.
                            while let Ok(req) = rx.recv() {
                                let _ = req
                                    .reply
                                    .send(Err(FsError::Runtime(format!("engine init failed: {e}"))));
                            }
                            return;
                        }
                    };
                    while let Ok(req) = rx.recv() {
                        let out = engine.rolling(req.variant, &req.planes, req.window);
                        let _ = req.reply.send(out);
                    }
                })
                .map_err(|e| FsError::Runtime(format!("spawn compute thread: {e}")))?;
            senders.push(tx);
            handles.push(handle);
        }
        Ok(ComputeService { senders, threads: handles, manifest })
    }

    pub fn handle(&self) -> ComputeHandle {
        ComputeHandle {
            senders: Arc::new(Mutex::new(self.senders.clone())),
            next: Arc::new(AtomicUsize::new(0)),
            manifest: self.manifest.clone(),
        }
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; threads exit
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Cloneable dispatch handle (Send + Sync).
#[derive(Clone)]
pub struct ComputeHandle {
    senders: Arc<Mutex<Vec<Sender<Request>>>>,
    next: Arc<AtomicUsize>,
    manifest: Arc<Manifest>,
}

impl std::fmt::Debug for ComputeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ComputeHandle(threads={})", self.senders.lock().unwrap().len())
    }
}

impl ComputeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute the rolling program (round-robin across engine threads;
    /// blocks until the result is ready).
    pub fn rolling(&self, variant: Variant, planes: &BinPlanes, window: usize) -> Result<RollPlanes> {
        let (reply_tx, reply_rx) = channel();
        let sender = {
            let senders = self.senders.lock().unwrap();
            if senders.is_empty() {
                return Err(FsError::Runtime("compute service stopped".into()));
            }
            let i = self.next.fetch_add(1, Ordering::Relaxed) % senders.len();
            senders[i].clone()
        };
        sender
            .send(Request { variant, planes: planes.clone(), window, reply: reply_tx })
            .map_err(|_| FsError::Runtime("compute thread gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| FsError::Runtime("compute thread dropped reply".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::rolling_reference;
    use crate::util::rng::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn planes(seed: u64, e: usize, t_pad: usize) -> BinPlanes {
        let mut rng = Rng::new(seed);
        let mut b = BinPlanes::empty(e, t_pad);
        for ei in 0..e {
            for bi in 0..t_pad {
                if rng.bool(0.7) {
                    b.add_event(ei, bi, rng.f32() * 10.0 - 5.0);
                }
            }
        }
        b
    }

    #[test]
    fn dispatches_and_matches_reference() {
        let svc = ComputeService::start(artifacts_dir(), 1).unwrap();
        let h = svc.handle();
        let p = planes(1, 8, 16 + 3);
        let got = h.rolling(Variant::Dsl, &p, 4).unwrap();
        let want = rolling_reference(&p, 4);
        for i in 0..got.sum.data.len() {
            assert!((got.sum.data[i] - want.sum.data[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn handle_works_from_many_threads() {
        let svc = ComputeService::start(artifacts_dir(), 2).unwrap();
        let h = svc.handle();
        let results: Vec<_> = std::thread::scope(|s| {
            (0..8u64)
                .map(|i| {
                    let h = h.clone();
                    s.spawn(move || {
                        let p = planes(i, 8, 10 + 3);
                        h.rolling(Variant::Dsl, &p, 4).map(|r| r.sum.data)
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|j| j.join().unwrap())
                .collect()
        });
        for (i, r) in results.into_iter().enumerate() {
            let want = rolling_reference(&planes(i as u64, 8, 13), 4);
            let got = r.unwrap();
            for (g, w) in got.iter().zip(&want.sum.data) {
                assert!((g - w).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn bad_dir_fails_fast() {
        assert!(ComputeService::start("/nonexistent-geofs", 1).is_err());
    }

    #[test]
    fn errors_propagate() {
        let svc = ComputeService::start(artifacts_dir(), 1).unwrap();
        let h = svc.handle();
        // No artifact compiled for window=7 → typed error through the
        // channel (oversized workloads chunk instead of failing).
        let p = BinPlanes::empty(8, 40);
        assert!(h.rolling(Variant::Dsl, &p, 7).is_err());
    }
}
